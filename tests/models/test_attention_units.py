"""Attention substrate unit tests: RoPE properties, masks, MLA cache size,
window-write equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (_causal_mask, write_window, GQAttention,
                                    MLAttention)
from repro.nn.rope import apply_rope


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 6, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    y = apply_rope(x, pos)
    # rotation preserves per-head norms
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on (m - n)
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.asarray([[m]]))
        kn = apply_rope(k, jnp.asarray([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 1), rel=1e-3)


def test_causal_and_sliding_masks():
    q = jnp.arange(6)
    k = jnp.arange(6)
    m = _causal_mask(q, k)
    assert bool(m[3, 3]) and bool(m[3, 0]) and not bool(m[3, 4])
    mw = _causal_mask(q, k, window=2)
    assert bool(mw[4, 3]) and bool(mw[4, 4])
    assert not bool(mw[4, 2])          # outside window
    assert not bool(mw[4, 5])          # future


def test_write_window_matches_dus():
    """Mask-write (§Perf C3) must equal per-sequence dynamic_update_slice."""
    key = jax.random.PRNGKey(0)
    buf = jax.random.normal(key, (3, 20, 4))
    new = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 4))
    lens = jnp.asarray([0, 7, 15])
    got = write_window(buf, new, lens)
    want = jax.vmap(
        lambda b, n, o: jax.lax.dynamic_update_slice_in_dim(b, n, o, 0)
    )(buf, new, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_mla_cache_is_latent_sized():
    """MLA's decode cache must store the compressed latent, not per-head
    K/V — the whole point of MLA (DeepSeek-V3)."""
    from repro.configs import get_config
    cfg = get_config("deepseek-v3-671b")
    cache = jax.eval_shape(lambda: MLAttention.init_cache(cfg, 1, 1000))
    per_tok = sum(np.prod(v.shape[1:]) / 1000 * v.dtype.itemsize
                  for v in jax.tree.leaves(cache))
    # latent 512 + rope 64 floats vs GQA-equivalent 128 heads x 128 x 2
    assert per_tok <= (cfg.kv_lora_rank + cfg.qk_rope_dim) * 4 + 1
    gqa_equiv = 2 * cfg.n_heads * cfg.head_dim * 4
    assert per_tok < gqa_equiv / 25


def test_gqa_window_one_token_matches_full_last_position():
    from repro.configs import get_config
    cfg = get_config("gemma-2b", reduced=True)
    p = GQAttention.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, cfg.d_model))
    full = GQAttention.full(p, x, cfg)
    cache = GQAttention.init_cache(cfg, 1, 16)
    clen = jnp.zeros((1,), jnp.int32)
    outs = []
    for t in range(9):
        y, cache = GQAttention.window(p, x[:, t:t + 1], cfg, cache, clen)
        outs.append(y)
        clen = clen + 1
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-5)
