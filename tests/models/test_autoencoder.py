"""Discrete autoencoder (§4.2): shapes, ST gradient, training, latent ARM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.autoencoder import AutoencoderConfig, DiscreteAutoencoder as AE

CFG = AutoencoderConfig(height=16, width=16, channels=3, width_filters=16,
                        latent_channels=2, latent_categories=8)


def test_shapes_roundtrip():
    params = AE.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3),
                           minval=-1, maxval=1)
    xhat, z = AE.reconstruct(params, x, CFG)
    assert xhat.shape == x.shape
    assert z.shape == (2, 4, 4, 2)
    assert z.dtype == jnp.int32
    assert int(z.min()) >= 0 and int(z.max()) < 8


def test_straight_through_gradient_flows():
    params = AE.init(jax.random.PRNGKey(0), CFG)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 16, 16, 3),
                           minval=-1, maxval=1)
    g = jax.grad(lambda p: AE.mse_loss(p, x, CFG))(params)
    # encoder must receive gradient through the quantizer
    enc_leaves = jax.tree.leaves(g["enc"])
    assert any(float(jnp.abs(l).max()) > 0 for l in enc_leaves)


def test_training_reduces_mse():
    from repro import optim
    from repro.data.synthetic import quantized_textures
    params = AE.init(jax.random.PRNGKey(0), CFG)
    imgs = quantized_textures(32, 16, 16, 3, categories=256, seed=0)
    x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0
    opt = optim.adamw(2e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        l, g = jax.value_and_grad(lambda p: AE.mse_loss(p, x, CFG))(params)
        u, state2 = opt.update(g, state, params)
        return optim.apply_updates(params, u), state2, l

    l0 = None
    for _ in range(25):
        params, state, l = step(params, state)
        l0 = float(l) if l0 is None else l0
    assert float(l) < l0 * 0.9, (l0, float(l))


def test_latent_arm_predictive_sampling():
    """End-to-end §4.2: PixelCNN over the AE latent space, FPI exactness."""
    from repro.core import predictive_sampling as ps
    from repro.core import reparam
    from repro.models.pixelcnn import PixelCNN, PixelCNNConfig

    lat_cfg = PixelCNNConfig(height=4, width=4, channels=2, categories=8,
                             filters=8, n_res=1, first_kernel=3)
    arm_params = PixelCNN.init(jax.random.PRNGKey(3), lat_cfg)
    arm_fn = PixelCNN.make_arm_fn(arm_params, lat_cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(4), (2, lat_cfg.d, 8))
    z_ref, _ = ps.ancestral_sample(arm_fn, eps)
    z_fpi, stats = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    np.testing.assert_array_equal(np.asarray(z_ref), np.asarray(z_fpi))
    # decode sampled latents
    ae = AE.init(jax.random.PRNGKey(5), CFG)
    z_img = z_fpi.reshape(2, 4, 4, 2)
    oh = jax.nn.one_hot(z_img, 8)
    xhat = AE.decode(ae, oh, CFG)
    assert xhat.shape == (2, 16, 16, 3)
    assert bool(jnp.all(jnp.isfinite(xhat)))
