"""``decode_window_paged`` vs the dense gather->decode->view path, at the
model level, across mixer families: pure GQA (qwen), sliding-window local
(gemma3), MLA latent (deepseek), and a recurrent hybrid (jamba — recurrent
states ride un-paged next to paged attention leaves).

The gather-view fallback must be BITWISE identical to gathering the dense
view and running ``decode_window`` (it is literally the same op sequence on
the same values); the Pallas kernel path re-orders the softmax reduction so
it gets a tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import PagedView, TransformerLM

ARCHS = ["qwen3-1.7b", "gemma3-1b", "deepseek-v3-671b",
         "jamba-1.5-large-398b"]


def _randomized_paged(cfg, batch, num_blocks, block_size, key):
    """A paged cache whose every leaf is random — simulates arbitrary prior
    rounds; both paths read the same physical values."""
    paged = TransformerLM.init_paged_cache(cfg, batch, num_blocks,
                                           block_size)
    leaves, treedef = jax.tree.flatten(paged)
    keys = jax.random.split(key, len(leaves))
    leaves = [0.1 * jax.random.normal(k, l.shape, l.dtype)
              for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_window_paged_matches_dense_view(arch):
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    B, W, bs, nb = 2, 4, 4, 6
    num_blocks = 1 + B * nb
    paged = _randomized_paged(cfg, B, num_blocks, bs,
                              jax.random.PRNGKey(1))
    tables = jnp.asarray(np.arange(1, num_blocks).reshape(B, nb), jnp.int32)
    rows = jnp.arange(B)
    cache_len = jnp.asarray([3, 7], jnp.int32)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, W), 0, cfg.vocab)

    view = TransformerLM.gather_paged(cfg, paged, tables, rows)
    logits_d, _, _ = TransformerLM.decode_window(params, cfg, tokens, view,
                                                 cache_len)
    logits_p, _, _ = TransformerLM.decode_window_paged(
        params, cfg, tokens, paged, PagedView(tables, rows,
                                              use_kernel=False), cache_len)
    np.testing.assert_array_equal(np.asarray(logits_p), np.asarray(logits_d))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_window_paged_kernel_close_to_fallback(arch):
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    B, W, bs, nb = 2, 4, 4, 6
    num_blocks = 1 + B * nb
    paged = _randomized_paged(cfg, B, num_blocks, bs,
                              jax.random.PRNGKey(1))
    tables = jnp.asarray(np.arange(1, num_blocks).reshape(B, nb), jnp.int32)
    rows = jnp.arange(B)
    cache_len = jnp.asarray([3, 7], jnp.int32)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, W), 0, cfg.vocab)

    logits_f, _, _ = TransformerLM.decode_window_paged(
        params, cfg, tokens, paged, PagedView(tables, rows,
                                              use_kernel=False), cache_len)
    logits_k, _, _ = TransformerLM.decode_window_paged(
        params, cfg, tokens, paged,
        PagedView(tables, rows, use_kernel=True, interpret=True), cache_len)
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits_f),
                               rtol=2e-4, atol=2e-4)
