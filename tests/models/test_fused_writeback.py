"""Hypothesis sweep (satellite): the aliased window writeback — the fused
kernel epilogue's commit, shared with ``paged_window_write`` — is BITWISE
equal to the separate ``write_window_paged`` scatter it replaced, at the
model level, across attn (qwen) / sliding-window local (gemma3) / MLA
latent (deepseek) / recurrent hybrid (jamba) stacks and ragged tails
(random per-row cache lengths, partially filled tail blocks).

Method: run ``decode_window_paged`` twice on identical inputs — once with
the aliased pallas writeback (the production fallback path) and once with
the module monkeypatched to the reference scatter. The attention math is
identical on both runs, so every pool leaf of the returned cache must match
bit-for-bit (excluding the reserved sink block 0, garbage by design) — any
divergence would be the writeback kernel mis-addressing a block. The fused
*kernel* epilogue is held to the same bitwise bar at tile granularity in
tests/kernels/test_kernel_properties.py; this sweep closes the loop at the
whole-stack level where scanned segments, per-layer tables, and un-paged
recurrent states ride along.
"""
import functools

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.paged_attention.ref import write_window_paged
from repro.models import attention as attention_mod
from repro.models.transformer import PagedView, TransformerLM

ARCHS = ["qwen3-1.7b", "gemma3-1b", "deepseek-v3-671b",
         "jamba-1.5-large-398b"]
B, W, bs, nb = 2, 4, 4, 6


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _attn_leaves(cfg, cache):
    """(stacked, leaf) for every attention pool leaf, in pytree order."""
    out = []

    def grab(stacked, leaf):
        out.append((stacked, leaf))
        return leaf

    TransformerLM._map_paged(cfg, (cache,), grab,
                             lambda stacked, leaf: leaf)
    return out


def _decode(cfg, params, paged, tables, cache_len, tokens):
    rows = jnp.arange(B)
    return TransformerLM.decode_window_paged(
        params, cfg, tokens, paged, PagedView(tables, rows,
                                              use_kernel=False), cache_len)


@pytest.mark.parametrize("arch", ARCHS)
@settings(deadline=None, max_examples=3)
@given(st.integers(0, 2**31 - 1))
def test_aliased_writeback_bitwise_vs_reference_scatter(arch, seed):
    cfg, params = _setup(arch)
    num_blocks = 1 + B * nb
    key = jax.random.PRNGKey(seed)
    paged = TransformerLM.init_paged_cache(cfg, B, num_blocks, bs)
    leaves, treedef = jax.tree.flatten(paged)
    keys = jax.random.split(key, len(leaves) + 2)
    paged = jax.tree.unflatten(
        treedef, [0.1 * jax.random.normal(k, l.shape, l.dtype)
                  for k, l in zip(keys[2:], leaves)])
    tables = jnp.asarray(np.arange(1, num_blocks).reshape(B, nb), jnp.int32)
    # ragged tails: any per-row length leaving room for the W window keys
    cache_len = jax.random.randint(keys[0], (B,), 1, nb * bs - W)
    tokens = jax.random.randint(keys[1], (B, W), 0, cfg.vocab)

    logits_a, _, nc_aliased = _decode(cfg, params, paged, tables, cache_len,
                                      tokens)
    orig = attention_mod.paged_window_write
    try:
        attention_mod.paged_window_write = \
            lambda pool, new, tables, start, active=None, interpret=None: \
            write_window_paged(pool, new, tables, start, active)
        logits_r, _, nc_ref = _decode(cfg, params, paged, tables, cache_len,
                                      tokens)
    finally:
        attention_mod.paged_window_write = orig

    np.testing.assert_array_equal(np.asarray(logits_a),
                                  np.asarray(logits_r))
    got, want = _attn_leaves(cfg, nc_aliased), _attn_leaves(cfg, nc_ref)
    assert len(got) == len(want) and got
    for (stacked, g), (_, w) in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        if stacked:                      # (L, P, bs, ...): drop sink per L
            np.testing.assert_array_equal(g[:, 1:], w[:, 1:])
        else:
            np.testing.assert_array_equal(g[1:], w[1:])
