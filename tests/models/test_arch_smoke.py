"""Per-architecture smoke tests: reduced variant of each assigned family runs
one forward + one train step on CPU; output shapes correct, no NaNs.

Also checks the decode_window path agrees with the full forward (prefix
consistency) for every family — the property predictive sampling relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, get_config
from repro.models import frontends
from repro.models.losses import lm_loss
from repro.models.transformer import TransformerLM

B, S = 2, 16


def _setup(arch):
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    prefix = frontends.random_prefix(jax.random.PRNGKey(2), cfg, B)
    return cfg, params, tokens, prefix


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg, params, tokens, prefix = _setup(arch)
    logits, h, aux = TransformerLM.apply(params, cfg, tokens, prefix)
    S_tot = S + cfg.n_prefix_tokens
    assert logits.shape == (B, S_tot, cfg.vocab)
    assert h.shape == (B, S_tot, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg, params, tokens, prefix = _setup(arch)
    opt = optim.adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        (l, m), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, prefix), has_aux=True)(params)
        g = optim.zero_frozen(g)
        u, state2 = opt.update(g, state, params)
        return optim.apply_updates(params, u), state2, l

    l0 = None
    for _ in range(5):
        params, state, l = step(params, state)
        assert bool(jnp.isfinite(l)), f"{arch}: loss went non-finite"
        l0 = float(l) if l0 is None else l0
    assert float(l) < l0, f"{arch}: loss did not decrease ({l0} -> {float(l)})"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_window_matches_full_forward(arch):
    """Running the sequence through cached windows must reproduce the full
    forward's logits (strict prefix equivalence -> predictive sampling is
    exact for every architecture family)."""
    cfg, params, tokens, _ = _setup(arch)
    # full forward (no prefix for decode comparison)
    full_logits, _, _ = TransformerLM.apply(params, cfg, tokens, None)

    W = 4
    cache = TransformerLM.init_cache(cfg, B, S + W, dtype=jnp.float32)
    cache_len = jnp.zeros((B,), jnp.int32)
    got = []
    for s0 in range(0, S, W):
        win = tokens[:, s0:s0 + W]
        logits_w, h_w, new_cache = TransformerLM.decode_window(
            params, cfg, win, cache, cache_len)
        got.append(logits_w)
        accept = jnp.full((B,), W, jnp.int32)  # accept everything
        cache = TransformerLM.select_states(cfg, new_cache, accept)
        cache_len = cache_len + W
    got = jnp.concatenate(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)
