"""SSM substrate units: chunked-scan equivalence (hypothesis), window/full
consistency, decay ranges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.ssm import Mamba, RWKV6TimeMix


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1), st.sampled_from([256, 320, 512]))
def test_mamba_chunked_equals_plain(seed, T):
    cfg = get_config("jamba-1.5-large-398b", reduced=True)
    p = Mamba.init(jax.random.PRNGKey(seed), cfg)
    x = 0.2 * jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                                (1, T, cfg.d_model))
    conv0 = jnp.zeros((1, Mamba.D_CONV - 1, 2 * cfg.d_model))
    h0 = jnp.zeros((1, 2 * cfg.d_model, cfg.ssm_state))
    y_plain, _, _, _ = Mamba._run(p, x, cfg, conv0, h0)
    y_chunk = Mamba.full(p, x, cfg)    # T >= 256 -> chunked path
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_plain),
                               rtol=2e-4, atol=2e-5)


def test_rwkv_decay_in_unit_interval():
    cfg = get_config("rwkv6-7b", reduced=True)
    p = RWKV6TimeMix.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    _, _, _, w, _ = RWKV6TimeMix._project(p, x, x_prev, cfg)
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0


def test_mamba_window_continuation_matches_full():
    """Two consecutive windows from carried state == one full pass."""
    cfg = get_config("jamba-1.5-large-398b", reduced=True)
    p = Mamba.init(jax.random.PRNGKey(2), cfg)
    x = 0.2 * jax.random.normal(jax.random.PRNGKey(3), (2, 12, cfg.d_model))
    full = Mamba.full(p, x, cfg)
    st0 = Mamba.init_state(cfg, 2)
    y1, pp1 = Mamba.window(p, x[:, :6], cfg, st0)
    st1 = jax.tree.map(lambda a: a[:, -1], pp1)   # adopt last position
    y2, _ = Mamba.window(p, x[:, 6:], cfg, st1)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_rwkv_window_continuation_matches_full():
    cfg = get_config("rwkv6-7b", reduced=True)
    p = RWKV6TimeMix.init(jax.random.PRNGKey(4), cfg)
    x = 0.2 * jax.random.normal(jax.random.PRNGKey(5), (2, 10, cfg.d_model))
    full = RWKV6TimeMix.full(p, x, cfg)
    st0 = RWKV6TimeMix.init_state(cfg, 2)
    y1, pp1 = RWKV6TimeMix.window(p, x[:, :5], cfg, st0)
    st1 = jax.tree.map(lambda a: a[:, -1], pp1)
    y2, _ = RWKV6TimeMix.window(p, x[:, 5:], cfg, st1)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-5)
