"""PixelCNN: strict-triangular causality, likelihoods, FPI exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig

CFG_BIN = PixelCNNConfig(height=6, width=6, channels=1, categories=2,
                         filters=8, n_res=2, first_kernel=5)
CFG_RGB = PixelCNNConfig(height=4, width=4, channels=3, categories=4,
                         filters=12, n_res=2, first_kernel=3)


@pytest.mark.parametrize("cfg", [CFG_BIN, CFG_RGB], ids=["bin", "rgb"])
def test_strict_triangular_dependence(cfg):
    """Perturbing flat position j must leave logits at positions <= j
    unchanged (logits[i] depends only on x_{<i})."""
    key = jax.random.PRNGKey(0)
    params = PixelCNN.init(key, cfg)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.d), 0,
                           cfg.categories)
    base, _ = arm_fn(x)
    rng = np.random.default_rng(0)
    for j in rng.choice(cfg.d, size=min(8, cfg.d), replace=False):
        x2 = x.at[0, j].set((x[0, j] + 1) % cfg.categories)
        pert, _ = arm_fn(x2)
        diff = np.abs(np.asarray(base - pert))[0].max(axis=-1)  # (d,)
        assert diff[: j + 1].max() == pytest.approx(0.0, abs=1e-6), \
            f"position {j} leaked backwards"
        # and the perturbation must actually reach SOME later position
        if j < cfg.d - 1:
            assert diff[j + 1:].max() > 0, f"position {j} has no effect at all"


def test_bpd_uniform_at_init_is_sane():
    params = PixelCNN.init(jax.random.PRNGKey(0), CFG_BIN)
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 6, 6, 1), 0, 2)
    bpd = float(PixelCNN.bpd(params, x, CFG_BIN))
    assert 0.5 < bpd < 3.0  # near 1 bit/dim at random init


def test_fpi_exactness_pixelcnn():
    """Predictive sampling of a PixelCNN is bit-identical to ancestral."""
    cfg = CFG_RGB
    params = PixelCNN.init(jax.random.PRNGKey(2), cfg)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(3), (2, cfg.d, cfg.categories))
    x_ref, _ = ps.ancestral_sample(arm_fn, eps)
    x_fpi, stats = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_fpi))
    assert int(stats.arm_calls) <= cfg.d


def test_training_reduces_bpd():
    """A few Adam steps on structured data must reduce bits/dim."""
    from repro import optim
    from repro.data.synthetic import binary_strokes

    cfg = PixelCNNConfig(height=8, width=8, channels=1, categories=2,
                         filters=8, n_res=1, first_kernel=5)
    params = PixelCNN.init(jax.random.PRNGKey(0), cfg)
    data = jnp.asarray(binary_strokes(64, 8, 8, seed=0))
    opt = optim.adamw(5e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        def loss(p):
            return PixelCNN.bpd(p, batch, cfg)
        l, g = jax.value_and_grad(loss)(params)
        g = optim.zero_frozen(g)
        u, state2 = opt.update(g, state, params)
        return optim.apply_updates(params, u), state2, l

    first = None
    for it in range(30):
        params, state, l = step(params, state, data)
        if first is None:
            first = float(l)
    assert float(l) < first * 0.8, (first, float(l))
    # masks must be untouched
    m = params["in_conv"]["_mask"]
    assert set(np.unique(np.asarray(m))) <= {0.0, 1.0}
