"""rwkv_wkv kernel vs scan oracle + vs the model's time-mix internals."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv_wkv.ops import rwkv_wkv
from repro.kernels.rwkv_wkv.ref import rwkv_wkv_ref


@pytest.mark.parametrize("T,hd,chunk", [(32, 16, 8), (100, 32, 32),
                                        (64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(T, hd, chunk, dtype):
    key = jax.random.PRNGKey(T + hd)
    B, H = 2, 2
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd)).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))).astype(dtype)
    u = (0.5 * jax.random.normal(ks[4], (H, hd))).astype(dtype)
    got = rwkv_wkv(r, k, v, w, u, chunk=chunk)
    want = rwkv_wkv(r, k, v, w, u, use_kernel=False)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_matches_model_wkv_scan():
    """Kernel must agree with RWKV6TimeMix._wkv_scan used by the model."""
    from repro.models.ssm import RWKV6TimeMix
    B, T, H, hd = 1, 24, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
    u = 0.5 * jax.random.normal(ks[4], (H, hd))
    S0 = jnp.zeros((B, H, hd, hd))
    want, _ = RWKV6TimeMix._wkv_scan(r, k, v, w, u, S0)
    got = rwkv_wkv(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
