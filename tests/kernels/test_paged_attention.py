"""Paged flash-decode kernel (fused window-writeback epilogue) vs its
oracle, the dense decode kernel, and the dense decode reference (interpret
mode).

The load-bearing invariants:
* fused kernel == fused ref (reference ``write_window_paged`` scatter +
  gather view + plain softmax) across block sizes, ragged lengths with
  partially filled tail blocks, and W in {1, 4, 16} — on the attention
  output AND bitwise on the committed pools (excluding the reserved sink
  block 0, whose contents are garbage by design);
* with matching tile sizes the fused kernel is BITWISE identical to the
  dense ``decode_attention_kernel`` run over the post-write gathered view —
  the same online-softmax op sequence, only the addressing (and the fused
  commit) differs;
* the standalone aliased writeback (``paged_window_write``) is bitwise
  identical to the reference scatter, including inactive-row sink routing;
* block tables with shared prefix blocks (prefix-cache hits) read the same
  physical memory from both sequences and the epilogue never writes them;
* table entries past the allocation point (sink block 0) never contribute.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_latent_attention,
                                               paged_window_write)
from repro.kernels.paged_attention.ref import (gather_view,
                                              paged_attention_ref,
                                              paged_latent_ref,
                                              write_window_paged)


def _pool_and_tables(key, P, bs, nb, KV, d, B, dtype=jnp.float32,
                     shared_prefix=0):
    """Random pools plus per-sequence tables over distinct physical blocks;
    the first ``shared_prefix`` logical blocks alias the same physical
    blocks across all sequences (prefix-cache shape). Remaining table slots
    past each row's allocation stay 0 (the sink block)."""
    kk, kv = jax.random.split(key)
    k_pool = jax.random.normal(kk, (P, bs, KV, d)).astype(dtype)
    v_pool = jax.random.normal(kv, (P, bs, KV, d)).astype(dtype)
    ids = np.arange(1, P)                     # block 0 reserved sink
    tables = np.zeros((B, nb), np.int32)
    tables[:, :shared_prefix] = ids[:shared_prefix]
    nxt = shared_prefix
    for b in range(B):
        own = nb - shared_prefix
        tables[b, shared_prefix:] = ids[nxt:nxt + own]
        nxt += own
    return k_pool, v_pool, jnp.asarray(tables)


def _window_kv(key, B, W, KV, d, dtype=jnp.float32):
    kk, kv = jax.random.split(key)
    return (jax.random.normal(kk, (B, W, KV, d)).astype(dtype),
            jax.random.normal(kv, (B, W, KV, d)).astype(dtype))


@pytest.mark.parametrize("bs", [16, 64, 128])
@pytest.mark.parametrize("W", [1, 4, 16])
def test_fused_kernel_matches_ref_and_dense(bs, W):
    B, H, KV, d, nb = 2, 4, 2, 32, 3
    P = 1 + B * nb
    key = jax.random.PRNGKey(bs * 31 + W)
    kq, kp, kl, kn = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, W, H, d))
    k_pool, v_pool, tables = _pool_and_tables(kp, P, bs, nb, KV, d, B)
    k_new, v_new = _window_kv(kn, B, W, KV, d)
    # ragged: partially filled tail blocks, room left for the W window keys
    lengths = jax.random.randint(kl, (B,), 1, nb * bs - W)

    got, kp2, vp2 = paged_attention(q, k_pool, v_pool, k_new, v_new, tables,
                                    lengths, interpret=True)
    # the fused commit is bitwise the reference scatter (sink excluded)
    rk = write_window_paged(k_pool, k_new, tables, lengths)
    rv = write_window_paged(v_pool, v_new, tables, lengths)
    np.testing.assert_array_equal(np.asarray(kp2)[1:], np.asarray(rk)[1:])
    np.testing.assert_array_equal(np.asarray(vp2)[1:], np.asarray(rv)[1:])
    want = paged_attention_ref(q, rk, rv, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # vs the dense op over the post-write gathered view (allclose: tiling)
    kd, vd = gather_view(rk, tables), gather_view(rv, tables)
    dense = decode_attention(q, kd, vd, lengths, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_fused_kernel_bitwise_vs_dense_kernel():
    """Same tile size -> identical online-softmax op sequence: the fused
    paged kernel must reproduce the dense flash-decode kernel (run over the
    post-write gathered view) bit-for-bit."""
    B, W, H, KV, d, bs, nb = 2, 8, 4, 2, 32, 32, 4
    P = 1 + B * nb
    key = jax.random.PRNGKey(7)
    kq, kp, kl, kn = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, W, H, d))
    k_pool, v_pool, tables = _pool_and_tables(kp, P, bs, nb, KV, d, B)
    k_new, v_new = _window_kv(kn, B, W, KV, d)
    lengths = jax.random.randint(kl, (B,), 1, nb * bs - W)

    paged, kp2, vp2 = paged_attention(q, k_pool, v_pool, k_new, v_new,
                                      tables, lengths, interpret=True)
    G = H // KV
    kd = jnp.repeat(gather_view(kp2, tables), G, axis=2)
    vd = jnp.repeat(gather_view(vp2, tables), G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, W, d)
    kf = kd.transpose(0, 2, 1, 3).reshape(B * H, nb * bs, d)
    vf = vd.transpose(0, 2, 1, 3).reshape(B * H, nb * bs, d)
    dense = decode_attention_kernel(qf, kf, vf, jnp.repeat(lengths, H),
                                    block_k=bs, interpret=True)
    dense = dense.reshape(B, H, W, d).transpose(0, 2, 1, 3)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


@pytest.mark.parametrize("window", [0, 24])
def test_fused_kernel_sliding_window(window):
    B, W, H, KV, d, bs, nb = 2, 4, 4, 1, 32, 16, 4
    P = 1 + B * nb
    key = jax.random.PRNGKey(window + 1)
    kq, kp, kn = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, W, H, d))
    k_pool, v_pool, tables = _pool_and_tables(kp, P, bs, nb, KV, d, B)
    k_new, v_new = _window_kv(kn, B, W, KV, d)
    lengths = jnp.asarray([37, 11])
    got, kp2, vp2 = paged_attention(q, k_pool, v_pool, k_new, v_new, tables,
                                    lengths, window=window, interpret=True)
    rk = write_window_paged(k_pool, k_new, tables, lengths)
    rv = write_window_paged(v_pool, v_new, tables, lengths)
    want = paged_attention_ref(q, rk, rv, tables, lengths, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(kp2)[1:], np.asarray(rk)[1:])


def test_shared_prefix_blocks_read_identically_and_stay_unwritten():
    """Two sequences whose tables alias the same physical prefix blocks and
    have equal lengths must produce identical outputs for identical queries
    — the prefix-cache sharing contract at the kernel level — and the fused
    epilogue must never write a shared prefix block (they sit strictly
    below the window span)."""
    B, W, H, KV, d, bs, nb = 2, 4, 2, 2, 16, 8, 3
    P = 1 + 2 + B * 1                         # 2 shared + 1 private each
    key = jax.random.PRNGKey(3)
    kq, kp, kn = jax.random.split(key, 3)
    q1 = jax.random.normal(kq, (1, W, H, d))
    q = jnp.concatenate([q1, q1], axis=0)
    k_pool, v_pool, tables = _pool_and_tables(kp, P, bs, nb, KV, d, B,
                                              shared_prefix=2)
    kn1, vn1 = _window_kv(kn, 1, W, KV, d)
    k_new = jnp.concatenate([kn1, kn1], axis=0)
    v_new = jnp.concatenate([vn1, vn1], axis=0)
    assert (np.asarray(tables[0, :2]) == np.asarray(tables[1, :2])).all()
    assert tables[0, 2] != tables[1, 2]
    # q_pos tops out at lengths + W - 1 = 15: every attended key lives in
    # the shared prefix blocks... except the window itself (merged)
    lengths = jnp.asarray([2 * bs - W, 2 * bs - W])
    out, kp2, vp2 = paged_attention(q, k_pool, v_pool, k_new, v_new, tables,
                                    lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    # shared prefix blocks strictly below the window stayed untouched
    shared = np.asarray(tables[0, :1])        # block 0 covers pos < 8 < 12
    np.testing.assert_array_equal(np.asarray(kp2)[shared],
                                  np.asarray(k_pool)[shared])
    np.testing.assert_array_equal(np.asarray(vp2)[shared],
                                  np.asarray(v_pool)[shared])


def test_sink_tail_blocks_never_contribute():
    """Table entries past the allocation point alias sink block 0: poisoning
    the sink must not change the output (causal masking kills the tail)."""
    B, W, H, KV, d, bs, nb = 1, 4, 2, 1, 16, 8, 4
    P = 1 + nb
    key = jax.random.PRNGKey(11)
    kq, kp, kn = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, W, H, d))
    k_pool, v_pool, _ = _pool_and_tables(kp, P, bs, nb, KV, d, B)
    k_new, v_new = _window_kv(kn, B, W, KV, d)
    tables = jnp.asarray([[1, 2, 0, 0]], jnp.int32)   # 2 real blocks + sink
    lengths = jnp.asarray([2 * bs - W], jnp.int32)
    base, _, _ = paged_attention(q, k_pool, v_pool, k_new, v_new, tables,
                                 lengths, interpret=True)
    poisoned_k = k_pool.at[0].set(1e9)
    poisoned_v = v_pool.at[0].set(-1e9)
    got, _, _ = paged_attention(q, poisoned_k, poisoned_v, k_new, v_new,
                                tables, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


@pytest.mark.parametrize("W", [1, 4])
def test_fused_latent_kernel_matches_ref(W):
    B, H, r, dr, bs, nb = 2, 4, 24, 16, 16, 3
    P = 1 + B * nb
    key = jax.random.PRNGKey(W)
    k1, k2, k3, k4, kl, kn = jax.random.split(key, 6)
    q_lat = jax.random.normal(k1, (B, W, H, r))
    q_rope = jax.random.normal(k2, (B, W, H, dr))
    c_pool = jax.random.normal(k3, (P, bs, r))
    kr_pool = jax.random.normal(k4, (P, bs, dr))
    c_new = jax.random.normal(kn, (B, W, r))
    kr_new = jax.random.normal(jax.random.fold_in(kn, 1), (B, W, dr))
    ids = np.arange(1, P).reshape(B, nb)
    tables = jnp.asarray(ids, jnp.int32)
    lengths = jax.random.randint(kl, (B,), 1, nb * bs - W)
    scale = 1.0 / np.sqrt(r + dr)
    got, c2, kr2 = paged_latent_attention(q_lat, q_rope, c_pool, kr_pool,
                                          c_new, kr_new, tables, lengths,
                                          scale, interpret=True)
    rc = write_window_paged(c_pool, c_new, tables, lengths)
    rkr = write_window_paged(kr_pool, kr_new, tables, lengths)
    want = paged_latent_ref(q_lat, q_rope, rc, rkr, tables, lengths,
                            scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # both latent pools committed bitwise (sink excluded)
    np.testing.assert_array_equal(np.asarray(c2)[1:], np.asarray(rc)[1:])
    np.testing.assert_array_equal(np.asarray(kr2)[1:], np.asarray(rkr)[1:])


def test_paged_window_write_bitwise_and_inactive_routing():
    """The standalone aliased writeback is bitwise the reference scatter:
    window rows land at table-resolved physical offsets; rows whose table
    is all-zero (cleared slots) land in the sink block; inactive rows never
    touch their real blocks."""
    P, bs, KV, d = 7, 4, 1, 8
    B, W, nb = 3, 3, 3
    key = jax.random.PRNGKey(17)
    pool = jax.random.normal(key, (P, bs, KV, d))
    new = jax.random.normal(jax.random.fold_in(key, 1), (B, W, KV, d))
    tables = jnp.asarray([[2, 3, 4], [5, 6, 0], [0, 0, 0]], jnp.int32)
    cache_len = jnp.asarray([3, 0, 0], jnp.int32)   # row 0 straddles blocks
    got = paged_window_write(pool, new, tables, cache_len, interpret=True)
    want = write_window_paged(pool, new, tables, cache_len)
    np.testing.assert_array_equal(np.asarray(got)[1:], np.asarray(want)[1:])

    active = jnp.asarray([1, 0, 1], jnp.int32)
    got_a = paged_window_write(pool, new, tables, cache_len, active=active,
                               interpret=True)
    want_a = write_window_paged(pool, new, tables, cache_len,
                                active=jnp.asarray([True, False, True]))
    np.testing.assert_array_equal(np.asarray(got_a)[1:],
                                  np.asarray(want_a)[1:])
    # the inactive row's real blocks kept their old contents
    np.testing.assert_array_equal(np.asarray(got_a)[5:7],
                                  np.asarray(pool)[5:7])


def test_write_window_paged_targets_physical_slots():
    """Reference semantics anchor: window rows land at table-resolved
    physical offsets; rows whose table is all-zero (cleared slots) land in
    the sink block."""
    P, bs, KV, d = 5, 4, 1, 8
    B, W, nb = 2, 3, 3
    pool = jnp.zeros((P, bs, KV, d))
    new = jnp.ones((B, W, KV, d)) * jnp.arange(1, B * W + 1).reshape(
        B, W, 1, 1)
    tables = jnp.asarray([[2, 3, 4], [0, 0, 0]], jnp.int32)
    cache_len = jnp.asarray([3, 0], jnp.int32)   # row 0 straddles blocks
    out = np.asarray(write_window_paged(pool, new, tables, cache_len))
    # row 0: positions 3,4,5 -> block 2 slot 3, block 3 slots 0,1
    assert out[2, 3, 0, 0] == 1 and out[3, 0, 0, 0] == 2
    assert out[3, 1, 0, 0] == 3
    # row 1 (cleared): positions 0..2 -> sink block 0
    assert (out[0, :3, 0, 0] == [4, 5, 6]).all()
    # untouched slots stay zero
    assert out[4].sum() == 0 and out[2, :3].sum() == 0


def test_dense_decode_kernel_ragged_tail_no_pad():
    """Satellite: S not divisible by block_k must be masked in-kernel (the
    old path jnp.pad'ed a full cache copy); oracle equality at a ragged S."""
    B, W, H, KV, d, S = 2, 4, 2, 1, 32, 150
    key = jax.random.PRNGKey(5)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, W, H, d))
    k = jax.random.normal(kk, (B, S, KV, d))
    v = jax.random.normal(kv, (B, S, KV, d))
    lengths = jax.random.randint(kl, (B,), 1, S - W)
    got = decode_attention(q, k, v, lengths, block_k=64, interpret=True)
    want = decode_attention(q, k, v, lengths, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
