"""Hypothesis property sweeps for the Pallas kernels (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_window_write)
from repro.kernels.paged_attention.ref import (gather_view,
                                              paged_attention_ref,
                                              write_window_paged)
from repro.kernels.spec_verify.ops import spec_verify


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 2**31 - 1), st.integers(1, 9), st.integers(2, 700),
       st.sampled_from([64, 128, 256, 333]))
def test_spec_verify_property(seed, R, V, bv):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    logits = 4.0 * jax.random.normal(k1, (R, V))
    eps = jax.random.gumbel(k2, (R, V))
    got = spec_verify(logits, eps, block_rows=4, block_vocab=bv)
    want = jnp.argmax(logits + eps, axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48, 65]),
       st.sampled_from([16, 32]), st.sampled_from([0, 24]))
def test_flash_attention_property(seed, S, d, window):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    B, H, KV = 1, 2, 1
    q = jax.random.normal(kq, (B, S, H, d))
    k = jax.random.normal(kk, (B, S, KV, d))
    v = jax.random.normal(kv, (B, S, KV, d))
    got = flash_attention(q, k, v, window=window, block_q=16, block_k=16)
    want = flash_attention(q, k, v, window=window, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(deadline=None, max_examples=12)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 128]),
       st.sampled_from([1, 4, 16]), st.integers(2, 4),
       st.integers(0, 2), st.sampled_from([0, 24]))
def test_paged_attention_property(seed, bs, W, nb, shared, window):
    """fused paged kernel == (reference scatter -> paged ref) == dense
    decode_attention over the post-write gathered view, across block sizes,
    ragged per-sequence lengths (partially filled tail blocks), window
    sizes, and tables with shared prefix blocks — and the fused epilogue's
    pool commit is BITWISE the separate ``write_window_paged`` scatter
    (excluding the reserved sink block 0, garbage by design)."""
    B, H, KV, d = 2, 4, 2, 16
    shared = min(shared, nb - 1)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kl = jax.random.split(key, 4)
    P = 1 + shared + B * (nb - shared)
    q = jax.random.normal(kq, (B, W, H, d))
    k_pool = jax.random.normal(kk, (P, bs, KV, d))
    v_pool = jax.random.normal(kv, (P, bs, KV, d))
    k_new = jax.random.normal(jax.random.fold_in(kk, 1), (B, W, KV, d))
    v_new = jax.random.normal(jax.random.fold_in(kv, 1), (B, W, KV, d))
    ids = np.arange(1, P)
    tables = np.zeros((B, nb), np.int32)
    tables[:, :shared] = ids[:shared]
    nxt = shared
    for b in range(B):
        tables[b, shared:] = ids[nxt:nxt + nb - shared]
        nxt += nb - shared
    tables = jnp.asarray(tables)
    # window spans start at `lengths`: keep them strictly above the shared
    # prefix blocks, the engine invariant (shareable blocks cover positions
    # < L_p - 1 <= n - 1) that makes shared blocks read-only by construction
    lengths = jax.random.randint(kl, (B,), max(1, shared * bs),
                                 nb * bs - W + 1)

    got, kp2, vp2 = paged_attention(q, k_pool, v_pool, k_new, v_new,
                                    tables, lengths, window=window,
                                    interpret=True)
    rk = write_window_paged(k_pool, k_new, tables, lengths)
    rv = write_window_paged(v_pool, v_new, tables, lengths)
    np.testing.assert_array_equal(np.asarray(kp2)[1:], np.asarray(rk)[1:])
    np.testing.assert_array_equal(np.asarray(vp2)[1:], np.asarray(rv)[1:])
    # the standalone aliased writeback is the same commit, bitwise
    pw = paged_window_write(k_pool, k_new, tables, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(pw)[1:], np.asarray(rk)[1:])
    want = paged_attention_ref(q, rk, rv, tables, lengths, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    dense = decode_attention(q, gather_view(rk, tables),
                             gather_view(rv, tables), lengths,
                             window=window, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=3e-5, atol=3e-5)
