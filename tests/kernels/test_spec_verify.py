"""spec_verify kernel vs jnp oracle: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spec_verify.ops import spec_verify
from repro.kernels.spec_verify.ref import spec_verify_ref


@pytest.mark.parametrize("R,V", [(1, 128), (8, 1024), (5, 300), (16, 4096),
                                 (3, 151936 // 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(R, V, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(R * 1000 + V))
    logits = (5.0 * jax.random.normal(k1, (R, V))).astype(dtype)
    eps = jax.random.gumbel(k2, (R, V)).astype(dtype)
    got = spec_verify(logits, eps, block_rows=4, block_vocab=256)
    want = spec_verify_ref(logits.reshape(-1, V), eps.reshape(-1, V))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_shapes():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (2, 3, 512))
    eps = jax.random.gumbel(jax.random.fold_in(k, 1), (2, 3, 512))
    got = spec_verify(logits, eps)
    want = jnp.argmax(logits + eps, axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tie_breaking_matches_first_occurrence():
    """Duplicated maxima must resolve to the lowest index, like jnp.argmax —
    including across tile boundaries."""
    R, V = 4, 512
    logits = jnp.zeros((R, V))
    eps = jnp.zeros((R, V))
    # equal maxima at (row, [70, 300]) — different tiles with block_vocab=256
    logits = logits.at[:, 70].set(5.0).at[:, 300].set(5.0)
    got = spec_verify(logits, eps, block_vocab=256)
    assert (np.asarray(got) == 70).all()
