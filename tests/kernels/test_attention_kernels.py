"""flash_attention / decode_attention kernels vs jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("S,d,H,KV", [(64, 32, 2, 2), (96, 64, 4, 2),
                                      (130, 32, 2, 1)])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(S, d, H, KV, window, dtype):
    key = jax.random.PRNGKey(S + d)
    kq, kk, kv = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(kq, (B, S, H, d)).astype(dtype)
    k = jax.random.normal(kk, (B, S, KV, d)).astype(dtype)
    v = jax.random.normal(kv, (B, S, KV, d)).astype(dtype)
    got = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    want = flash_attention(q, k, v, window=window, use_kernel=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("S,W,d", [(128, 1, 32), (256, 8, 64), (200, 4, 32)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_oracle(S, W, d, window, dtype):
    key = jax.random.PRNGKey(S * 7 + W)
    B, H, KV = 2, 4, 2
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, W, H, d)).astype(dtype)
    k = jax.random.normal(kk, (B, S, KV, d)).astype(dtype)
    v = jax.random.normal(kv, (B, S, KV, d)).astype(dtype)
    lengths = jax.random.randint(kl, (B,), 1, S - W)
    got = decode_attention(q, k, v, lengths, window=window, block_k=64)
    want = decode_attention(q, k, v, lengths, window=window,
                            use_kernel=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_matches_model_attention_semantics():
    """decode kernel must agree with the model's _sdpa window path."""
    from repro.models.attention import _causal_mask, _sdpa
    B, W, H, KV, d, S = 2, 4, 4, 2, 32, 96
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, W, H, d))
    k = jax.random.normal(kk, (B, S, KV, d))
    v = jax.random.normal(kv, (B, S, KV, d))
    lengths = jnp.asarray([10, 40])
    pos = lengths[:, None] + jnp.arange(W)[None, :]
    k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = _causal_mask(pos, k_pos)
    want = _sdpa(q, k, v, mask, 1.0 / d ** 0.5)
    got = decode_attention(q, k, v, lengths, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
