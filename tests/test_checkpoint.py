"""Checkpoint round-trip: nested dicts/lists/tuples of arrays."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_pytree, save_pytree


def test_roundtrip(tmp_path):
    tree = {
        "embed": {"table": jnp.arange(12.0).reshape(3, 4)},
        "blocks": [{"w": jnp.ones((2, 2)), "b": jnp.zeros(2)},
                   {"w": 2 * jnp.ones((2, 2)), "b": jnp.ones(2)}],
        "empty": [],
        "scalar": jnp.asarray(3),
    }
    save_pytree(tree, str(tmp_path), step=7)
    assert latest_step(str(tmp_path)) == 7
    back = restore_pytree(str(tmp_path), 7)
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(back)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert back["empty"] == []
    assert isinstance(back["blocks"], list) and len(back["blocks"]) == 2


def test_model_params_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    save_pytree(params, str(tmp_path), step=1)
    back = restore_pytree(str(tmp_path), 1)
    tok = jnp.zeros((1, 4), jnp.int32)
    a, _, _ = TransformerLM.apply(params, cfg, tok)
    b, _, _ = TransformerLM.apply(back, cfg, tok)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
