"""Data generators + pipeline: determinism, shapes, category bounds."""
import numpy as np
import pytest

from repro.data.synthetic import (binary_strokes, quantized_textures,
                                  repetitive_tokens, synthetic_tokens,
                                  token_batches)


def test_binary_strokes():
    a = binary_strokes(8, 16, 16, seed=3)
    b = binary_strokes(8, 16, 16, seed=3)
    np.testing.assert_array_equal(a, b)           # deterministic
    assert a.shape == (8, 16, 16, 1)
    assert set(np.unique(a)) <= {0, 1}
    assert 0.02 < a.mean() < 0.6                  # sparse strokes


@pytest.mark.parametrize("K", [2, 16, 256])
def test_quantized_textures(K):
    a = quantized_textures(4, 8, 8, 3, categories=K, seed=1)
    assert a.shape == (4, 8, 8, 3)
    assert a.min() >= 0 and a.max() < K
    # smooth fields: neighbouring pixels mostly close
    d = np.abs(np.diff(a.astype(int), axis=2)).mean()
    assert d < K * 0.35


def test_token_generators():
    t = synthetic_tokens(4, 32, 1000, seed=0)
    assert t.shape == (4, 32) and t.min() >= 0 and t.max() < 1000
    r = repetitive_tokens(4, 32, 1000, seed=0, motif_len=8)
    # motif repetition: strong lag-8 autocorrelation
    agree = (r[:, 8:] == r[:, :-8]).mean()
    assert agree > 0.8


def test_token_batches_stream():
    it = token_batches(32, 8, 16, 100, seed=0)
    b1, b2 = next(it), next(it)
    assert b1.shape == (8, 16)
    assert not np.array_equal(b1, b2)


def test_hlo_collective_parser():
    from repro.analysis import parse_collective_bytes
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather(%a, %b), dims={0}
  %nope = f32[2,2]{1,0} add(%p, %q)
  %a2a = u8[1024]{0} all-to-all(%m), dims={0}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"] == {"bytes": 16 * 128 * 4, "count": 1}
    assert out["all-gather"] == {"bytes": 2 * 4 * 8 * 2, "count": 1}
    assert out["all-to-all"] == {"bytes": 1024, "count": 1}
    assert out["reduce-scatter"]["count"] == 0
