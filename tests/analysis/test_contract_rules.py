"""One deliberately-violating fixture per contract rule (DESIGN.md §17):
the engine must be shown to CATCH, not just pass. Each fixture asserts
the contract fails with a structured report naming the offending eqn /
HLO line."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Contract, ContractViolationError,
                            DonationAliasCovers, MaxLiveBytes, NoCollectives,
                            NoF64Leaks, NoHostCallbacks, NoPoolRankedScatters,
                            Program, RecompileHazard, check_program, require)

_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def test_host_callback_fixture_fails():
    def fn(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    rep = check_program(fn, (jnp.ones((3,)),),
                        Contract("T", [NoHostCallbacks()]))
    assert not rep.ok
    v = rep.violations[0]
    assert v.rule == "NoHostCallbacks"
    assert "pure_callback" in v.evidence["eqn"]
    assert rep.metrics["host_callbacks"] == 1


def test_pool_ranked_scatter_fixture_fails_with_rank_evidence():
    def fn(pool, i, val):
        return pool.at[i].set(val)
    rep = check_program(
        fn, (jnp.zeros((4, 2, 8)), jnp.asarray([1]), jnp.ones((1, 2, 8))),
        Contract("T", [NoPoolRankedScatters(min_rank=3)]))
    assert not rep.ok
    v = rep.violations[0]
    assert v.rule == "NoPoolRankedScatters" and v.evidence["rank"] == 3
    assert "scatter" in v.evidence["eqn"]
    # the same program passes a rank-4 threshold: rule is parameterized
    assert check_program(
        fn, (jnp.zeros((4, 2, 8)), jnp.asarray([1]), jnp.ones((1, 2, 8))),
        Contract("T", [NoPoolRankedScatters(min_rank=4)])).ok


def test_unaliased_donation_fixture_fails():
    def fn(pool, x):
        return pool + x, x * 2
    args = (jnp.zeros((64, 64)), jnp.ones((1,)))
    # donated: aliasing established, rule passes
    donated = jax.jit(fn, donate_argnums=(0,))
    assert check_program(donated, args,
                         Contract("T", [DonationAliasCovers((0,))])).ok
    # NOT donated: zero aliasing, the contract must fail with byte evidence
    rep = check_program(jax.jit(fn), args,
                        Contract("T", [DonationAliasCovers((0,))]))
    assert not rep.ok
    v = rep.violations[0]
    assert v.rule == "DonationAliasCovers"
    assert v.evidence["alias_bytes"] == 0
    assert v.evidence["pool_bytes"] == 64 * 64 * 4


def test_f64_leak_fixture_fails():
    def fn(x):
        return x.astype("float64") * 2.0
    with jax.experimental.enable_x64():
        rep = check_program(fn, (jnp.ones((3,), jnp.float32),),
                            Contract("T", [NoF64Leaks()]))
    assert not rep.ok
    assert all(v.rule == "NoF64Leaks" for v in rep.violations)
    assert any("f64" in v.evidence["eqn"] for v in rep.violations)


def test_max_live_bytes_budget():
    def fn(x):
        return x @ x
    args = (jnp.ones((64, 64)),)
    assert check_program(fn, args,
                         Contract("T", [MaxLiveBytes(1 << 30)])).ok
    rep = check_program(fn, args, Contract("T", [MaxLiveBytes(100)]))
    assert not rep.ok
    v = rep.violations[0]
    assert v.rule == "MaxLiveBytes" and v.evidence["live_bytes"] > 100


def test_recompile_hazard_trips_on_shape_churn():
    rule = RecompileHazard(max_shapes=3)
    label = "test-recompile-hazard-fixture"
    contract = Contract("T", [rule])

    def fn(x):
        return x * 2
    reports = [check_program(fn, (jnp.ones((n,)),), contract, label=label)
               for n in range(1, 6)]
    assert all(r.ok for r in reports[:3])      # within budget
    assert not reports[-1].ok                  # 5th distinct shape trips
    v = reports[-1].violations[0]
    assert v.rule == "RecompileHazard"
    assert v.evidence["distinct_shapes"] == 5


def test_require_raises_structured_error():
    def fn(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    rep = check_program(fn, (jnp.ones((3,)),),
                        Contract("T", [NoHostCallbacks()]))
    with pytest.raises(ContractViolationError) as ei:
        require(rep)
    assert "NoHostCallbacks" in str(ei.value)
    assert ei.value.report is rep
    # and it is an AssertionError subclass for legacy harnesses
    assert isinstance(ei.value, AssertionError)


COLLECTIVE_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.analysis import Contract, NoCollectives, check_program
    from repro.sharding.api import shard_map

    mesh = Mesh(np.array(jax.devices()).reshape(2,), ("data",))

    def fn(x):
        return shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P())(x)

    rep = check_program(fn, (jnp.arange(8, dtype=jnp.float32),),
                        Contract("SEEDED", [NoCollectives()]),
                        label="seeded-collective")
    print(json.dumps({
        "ok": rep.ok,
        "rules": [v.rule for v in rep.violations],
        "sites": [v.site for v in rep.violations],
        "bytes": [v.evidence["bytes"] for v in rep.violations]}))
""")


def test_seeded_collective_fixture_fails_with_hlo_line():
    """A psum under shard_map on 2 forced devices MUST trip NoCollectives,
    and the violation names the HLO line (subprocess: the main test
    process keeps its single-device view)."""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", COLLECTIVE_SCRIPT], env=env,
                         capture_output=True, text=True, cwd=_ROOT)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert not rec["ok"]
    assert "NoCollectives" in rec["rules"]
    assert any("HLO line" in s and "all-reduce" in s for s in rec["sites"])
    assert all(b > 0 for b in rec["bytes"])


def test_program_hlo_only_fixture_rejects_jaxpr_rules():
    prog = Program(hlo_text="ENTRY main {}", label="hlo-only")
    with pytest.raises(ValueError):
        _ = prog.jaxpr


def test_scatter_pool_shape_targeting_spares_non_pool_writes():
    """MoE dispatch buffers and recurrent state rows are high-rank
    scatters the round runs by design; targeting the rule at the exact
    pool leaf shapes must spare them while the SAME program's real
    pool-shaped scatter still fails."""
    def fn(pool, state, i, pv, sv):
        return pool.at[i].set(pv), state.at[i].set(sv)
    args = (jnp.zeros((4, 2, 8)), jnp.zeros((4, 1, 16)),
            jnp.asarray([1]), jnp.ones((1, 2, 8)), jnp.ones((1, 1, 16)))
    rep = check_program(fn, args, Contract("T", [NoPoolRankedScatters()]))
    assert len(rep.violations) == 2      # rank proxy: both rank-3 writes
    rep = check_program(fn, args, Contract("T", [
        NoPoolRankedScatters(pool_shapes={(4, 2, 8)})]))
    assert len(rep.violations) == 1      # state write spared, pool caught
    assert rep.violations[0].evidence["shape"] == [4, 2, 8]
    # empty pool-shape set (pure-recurrent arch: no KV pool) passes all
    assert check_program(fn, args, Contract("T", [
        NoPoolRankedScatters(pool_shapes=frozenset())])).ok
