"""The four named contracts against the REAL engine programs
(DESIGN.md §17): round / staged-round / prefill / migration-copy, on a
single device inline and on a data=2 mesh in a subprocess — plus the
env-gated ``maybe_check`` engine seam."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CONTRACTS, MIGRATION_COPY_CONTRACT,
                            PREFILL_CONTRACT, check_engine_round,
                            check_program, contracts_enabled, maybe_check)
from repro.analysis import contracts as contracts_mod
from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine

_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    base = dict(batch=2, window_max=4, max_len=32, block_size=4,
                eps_key=jax.random.PRNGKey(3), adaptive=False)
    base.update(kw)
    return ServingEngine(cfg, params, **base)


def test_contract_registry_names():
    assert set(CONTRACTS) == {"ROUND_CONTRACT", "STAGED_ROUND_CONTRACT",
                              "PREFILL_CONTRACT", "MIGRATION_COPY_CONTRACT"}
    for c in CONTRACTS.values():
        assert "NoHostCallbacks" in c.rule_names()
        assert "NoF64Leaks" in c.rule_names()
    # hot-path-only rules stay off the admission/migration programs
    assert "NoCollectives" not in CONTRACTS["PREFILL_CONTRACT"].rule_names()
    assert "NoPoolRankedScatters" not in \
        CONTRACTS["MIGRATION_COPY_CONTRACT"].rule_names()


def test_round_contract_passes_on_real_round(cfg_params):
    cfg, params = cfg_params
    rep = check_engine_round(_engine(cfg, params))
    assert rep.ok, rep
    assert rep.contract == "ROUND_CONTRACT"
    assert rep.metrics["n_args"] == 9
    assert rep.metrics["pallas_calls"] >= 1
    assert rep.metrics["pool_scatters"] == 0
    assert all(c == 0 for c in rep.metrics["collectives"].values())


def test_staged_round_contract_passes_on_real_staged_round(cfg_params):
    cfg, params = cfg_params
    eng = _engine(cfg, params, staging_slots=2, adaptive_rounds=False,
                  rounds_per_sync=4)
    rep = check_engine_round(eng)
    assert rep.ok, rep
    assert rep.contract == "STAGED_ROUND_CONTRACT"
    assert rep.metrics["n_args"] == 19       # the §15 ABI
    assert rep.metrics["pool_scatters"] == 0


def test_prefill_contract_passes_on_real_prefill(cfg_params):
    cfg, params = cfg_params
    eng = _engine(cfg, params)
    C = 4
    fn = eng._prefill_fn(C)
    args = (eng.params, eng.paged,
            jnp.asarray(eng.tables[0:1] + eng._table_offset(0)),
            jnp.asarray([0], jnp.int32), jnp.zeros((1, C), jnp.int32),
            jnp.asarray([0], jnp.int32))
    rep = check_program(fn, args, PREFILL_CONTRACT, label="prefill-ut")
    assert rep.ok, rep
    assert rep.metrics["host_callbacks"] == 0


def test_migration_copy_contract_passes_on_real_copy(cfg_params):
    cfg, params = cfg_params
    eng = _engine(cfg, params)
    fn = eng._copy_blocks_fn()
    args = (eng.paged, jnp.zeros(eng.nb, jnp.int32),
            jnp.zeros(eng.nb, jnp.int32), jnp.asarray(0, jnp.int32),
            jnp.asarray(1, jnp.int32))
    rep = check_program(fn, args, MIGRATION_COPY_CONTRACT, label="copy-ut")
    assert rep.ok, rep


def test_undonated_engine_skips_donation_rule(cfg_params):
    cfg, params = cfg_params
    rep = check_engine_round(_engine(cfg, params, donate=False))
    assert rep.ok, rep                  # no false DonationAliasCovers hit


def test_maybe_check_env_gate_and_dedup(cfg_params, monkeypatch):
    cfg, params = cfg_params
    eng = _engine(cfg, params)
    fn = eng._round_loop_fn(eng.controller.window, eng.rounds_per_sync)
    args = eng._round_args()
    monkeypatch.setenv("REPRO_CHECK_CONTRACTS", "0")
    assert not contracts_enabled()
    before = len(contracts_mod._CHECKED)
    maybe_check("round", fn, args)                     # gated off: no-op
    assert len(contracts_mod._CHECKED) == before
    monkeypatch.setenv("REPRO_CHECK_CONTRACTS", "1")
    maybe_check("round", fn, args, label="seam-ut")    # checks + records
    assert len(contracts_mod._CHECKED) == before + 1
    maybe_check("round", fn, args, label="seam-ut")    # dedup: no growth
    assert len(contracts_mod._CHECKED) == before + 1


def test_engine_serves_with_contracts_on(cfg_params, monkeypatch):
    """End-to-end seam: with REPRO_CHECK_CONTRACTS=1 a real engine admits
    and serves traffic — every program it compiles passes its contract at
    first dispatch (a violation would raise ContractViolationError)."""
    monkeypatch.setenv("REPRO_CHECK_CONTRACTS", "1")
    cfg, params = cfg_params
    eng = _engine(cfg, params)
    rng = np.random.default_rng(7)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5),
                           new_tokens=4))
    done = {r.uid: r.result for r in eng.run()}
    assert len(done) == 2 and all(v is not None for v in done.values())


MESH_SCRIPT = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.analysis import (MIGRATION_COPY_CONTRACT, PREFILL_CONTRACT,
                                check_engine_round, check_program)
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    topo = ServingTopology(make_host_mesh(2, 1))
    kw = dict(batch=4, window_max=4, max_len=32, block_size=4,
              eps_key=jax.random.PRNGKey(3), adaptive=False, topology=topo)
    rec = {}

    for staged in (0, 2):
        eng = ServingEngine(cfg, params, staging_slots=staged,
                            **(dict(kw, adaptive_rounds=False)
                               if staged else kw))
        rng = np.random.default_rng(5)
        for i in range(4):
            eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5),
                               new_tokens=6))
        eng.step()
        rep = check_engine_round(eng)
        key = "staged_round" if staged else "round"
        rec[key] = {"ok": rep.ok, "violations": [str(v) for v in
                                                 rep.violations]}
        if not staged:
            C = 4
            fn = eng._prefill_fn(C)
            args = (eng.params, eng.paged,
                    jnp.asarray(eng.tables[0:1] + eng._table_offset(0)),
                    jnp.asarray([0], jnp.int32),
                    jnp.zeros((1, C), jnp.int32),
                    jnp.asarray([0], jnp.int32))
            rp = check_program(fn, args, PREFILL_CONTRACT,
                               label="prefill-mesh")
            rec["prefill"] = {"ok": rp.ok,
                              "violations": [str(v) for v in rp.violations]}
            cf = eng._copy_blocks_fn()
            cargs = (eng.paged, jnp.zeros(eng.nb, jnp.int32),
                     jnp.zeros(eng.nb, jnp.int32),
                     jnp.asarray(0, jnp.int32), jnp.asarray(2, jnp.int32))
            rc = check_program(cf, cargs, MIGRATION_COPY_CONTRACT,
                               label="copy-mesh")
            rec["migration_copy"] = {
                "ok": rc.ok, "violations": [str(v) for v in rc.violations]}
    print(json.dumps(rec))
""")


def test_all_contracts_pass_on_data2_mesh():
    """Acceptance: all four named contracts hold on the real programs of a
    data=2 mesh engine (subprocess: 8 forced host devices)."""
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu", REPRO_CHECK_CONTRACTS="1")
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, cwd=_ROOT)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    for kind in ("round", "staged_round", "prefill", "migration_copy"):
        assert rec[kind]["ok"], (kind, rec[kind]["violations"])


def test_select_contract_relaxations():
    """The engine-variant refinements: TP drops the data-axis-only rules,
    donate=False drops aliasing only, pool-shape targeting reconfigures
    (not drops) the scatter rule."""
    from repro.analysis import select_contract
    assert (select_contract("round").rule_names()
            == CONTRACTS["ROUND_CONTRACT"].rule_names())
    tp = select_contract("round", tensor_parallel=True)
    assert "NoCollectives" not in tp.rule_names()
    assert "DonationAliasCovers" not in tp.rule_names()
    assert "NoPoolRankedScatters" in tp.rule_names()
    nod = select_contract("staged_round", donate=False)
    assert "DonationAliasCovers" not in nod.rule_names()
    assert "NoCollectives" in nod.rule_names()
    rec = select_contract("round", pool_scatter_shapes={(2, 1, 256)})
    rule = [r for r in rec.rules if r.name == "NoPoolRankedScatters"][0]
    assert (2, 1, 256) in rule.pool_shapes and rule.min_rank == 3


def test_round_contract_passes_on_recurrent_arch():
    """A recurrent engine's round scatters its per-slot state rows (rank
    3/5, riding next to the pool) — pool-shape targeting spares exactly
    those, so the contract passes while the raw census still sees the
    state scatters (proving the rule filters by shape, not rank)."""
    cfg = get_config("rwkv6-7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    eng = _engine(cfg, params)
    ex = eng._contract_exemptions()
    assert ex["pool_scatter_shapes"] == frozenset()   # no KV pool at all
    rep = check_engine_round(eng)
    assert rep.ok, rep
    assert rep.metrics["pool_scatters"] >= 1      # raw census: state rows
