"""Units for the AST host-sync / determinism linter (DESIGN.md §17):
hot-function discovery (decorator, round-loop-builder nesting, transitive
same-module calls), the three rules, inline suppressions, and the CLI
exit code."""
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis.lint import Finding, lint_file, lint_paths, main


def _write(tmp_path, name, src):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return p


def _rules(findings):
    return sorted({f.rule for f in findings})


def test_host_sync_flagged_in_hot_path_decorated_fn(tmp_path):
    p = _write(tmp_path, "mod.py", """
        import numpy as np
        from repro.analysis import hot_path

        @hot_path
        def round_fn(x):
            return float(np.asarray(x).sum())

        def cold_fn(x):
            return float(np.asarray(x).sum())   # NOT hot: no finding
    """)
    findings = lint_file(p)
    assert _rules(findings) == ["host-sync"]
    assert all(f.line <= 8 for f in findings), findings
    assert any("np.asarray" in f.message for f in findings)
    assert any("float()" in f.message for f in findings)


def test_host_sync_flagged_under_round_loop_builder(tmp_path):
    p = _write(tmp_path, "eng.py", """
        def _round_loop_fn(self, W, k):
            def loop(args):
                n = args[0].item()
                return n
            return loop
    """)
    findings = lint_file(p)
    assert _rules(findings) == ["host-sync"]
    assert ".item()" in findings[0].message


def test_host_sync_follows_same_module_calls(tmp_path):
    p = _write(tmp_path, "mod.py", """
        import numpy as np
        from repro.analysis import hot_path

        def helper(x):
            return bool(x)            # reached FROM a hot fn

        @hot_path
        def round_fn(x):
            return helper(x)
    """)
    findings = lint_file(p)
    assert _rules(findings) == ["host-sync"]
    assert "helper" in findings[0].message


def test_suppression_on_line_and_def(tmp_path):
    p = _write(tmp_path, "mod.py", """
        import numpy as np
        from repro.analysis import hot_path

        @hot_path
        def a(x):
            return x.item()           # repro: allow(host-sync)

        @hot_path
        def b(x):                     # repro: allow(host-sync)
            return x.item()
    """)
    assert lint_file(p) == []


def test_nondet_in_deterministic_module(tmp_path):
    p = _write(tmp_path, "serving/journal.py", """
        import random
        import time

        def stamp():
            return time.time(), random.random()

        def seeded(key):
            import jax
            return jax.random.uniform(key)    # seeded stream: fine
    """)
    findings = lint_file(p, root=tmp_path)
    assert _rules(findings) == ["nondet"]
    assert len(findings) == 2
    p2 = _write(tmp_path, "launch/bench.py", """
        import time

        def wall():
            return time.time()        # NOT a deterministic module: fine
    """)
    assert lint_file(p2, root=tmp_path) == []


def test_bare_except_flagged_everywhere(tmp_path):
    p = _write(tmp_path, "anywhere.py", """
        def f():
            try:
                g()
            except:
                pass

        def g():
            try:
                f()
            except ValueError:
                pass                  # typed: fine
    """)
    findings = lint_file(p)
    assert _rules(findings) == ["bare-except"]
    assert len(findings) == 1
    assert "RequestError" in findings[0].message


def test_finding_str_is_clickable():
    f = Finding("src/repro/x.py", 12, "host-sync", "msg")
    assert str(f) == "src/repro/x.py:12: [host-sync] msg"


def test_cli_exit_codes(tmp_path, capsys):
    clean = _write(tmp_path, "clean/ok.py", "x = 1\n")
    assert main([str(clean.parent)]) == 0
    dirty = _write(tmp_path, "dirty/bad.py", """
        try:
            pass
        except:
            pass
    """)
    assert main([str(dirty.parent)]) == 1
    out = capsys.readouterr().out
    assert "bare-except" in out and "1 finding(s)" in out


def test_repo_linter_runs_clean_via_module_cli():
    """The CI gate: `python -m repro.analysis.lint` over src/repro exits 0
    (pre-existing findings fixed or suppressed inline)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint"],
        capture_output=True, text=True,
        cwd=str(Path(__file__).resolve().parents[2]),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stdout


def test_lint_paths_recurses_directories(tmp_path):
    _write(tmp_path, "pkg/a.py", "x = 1\n")
    _write(tmp_path, "pkg/sub/b.py", """
        try:
            pass
        except:
            pass
    """)
    findings = lint_paths([str(tmp_path / "pkg")])
    assert len(findings) == 1 and findings[0].rule == "bare-except"
    assert findings[0].path.endswith("b.py")
