"""Units for the contract engine's measurement layer (DESIGN.md §17):
jaxpr primitive census with sub-jaxpr recursion + rank filtering, dtype
byte parsing, and the async-collective HLO regression."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (EqnSite, count_jaxpr_primitives, find_collectives,
                            find_dtype_leaks, find_jaxpr_primitives,
                            parse_collective_bytes, parse_shape_bytes)


def _jaxpr(fn, *args):
    return jax.jit(fn).trace(*args).jaxpr


# ---------------------------------------------------------------------------
# sub-jaxpr recursion
# ---------------------------------------------------------------------------

def test_counts_recurse_into_while_loop():
    def fn(pool):
        def body(c):
            i, p = c
            return i + 1, p.at[i].set(p[i] + 1.0)
        return jax.lax.while_loop(lambda c: c[0] < 3, body,
                                  (jnp.int32(0), pool))
    counts = count_jaxpr_primitives(_jaxpr(fn, jnp.zeros((4, 2, 8))),
                                    ("scatter",), min_rank=3)
    assert counts["scatter"] == 1


def test_counts_recurse_into_scan():
    def fn(pool, idx):
        def step(p, i):
            return p.at[i].set(0.0), i
        out, _ = jax.lax.scan(step, pool, idx)
        return out
    counts = count_jaxpr_primitives(
        _jaxpr(fn, jnp.zeros((4, 2, 8)), jnp.arange(3)),
        ("scatter",), min_rank=3)
    assert counts["scatter"] == 1


def test_counts_recurse_into_pjit():
    inner = jax.jit(lambda p, i: p.at[i].set(1.0))

    def fn(pool, i):
        return inner(pool, i)
    sites = find_jaxpr_primitives(
        _jaxpr(fn, jnp.zeros((4, 2, 8)), jnp.int32(1)),
        ("scatter",), min_rank=3)
    assert len(sites) == 1
    assert "pjit" in sites[0].path       # evidence names the nesting


def test_counts_recurse_into_pallas_body():
    pl = pytest.importorskip("jax.experimental.pallas")

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)
    jx = _jaxpr(fn, jnp.ones((8, 8)))
    assert count_jaxpr_primitives(jx, ("pallas_call",))["pallas_call"] == 1
    # the kernel body's mul is found THROUGH the pallas_call sub-jaxpr
    sites = find_jaxpr_primitives(jx, ("mul",))
    assert any("pallas_call" in s.path for s in sites)


# ---------------------------------------------------------------------------
# rank filtering + evidence records
# ---------------------------------------------------------------------------

def test_rank_filter_separates_pool_from_bookkeeping():
    def fn(pool, row, i):
        return pool.at[i].set(1.0), row.at[i].set(2)
    jx = _jaxpr(fn, jnp.zeros((4, 2, 8)), jnp.zeros((4,), jnp.int32),
                jnp.int32(1))
    assert count_jaxpr_primitives(jx, ("scatter",))["scatter"] == 2
    assert count_jaxpr_primitives(jx, ("scatter",), min_rank=3)[
        "scatter"] == 1
    sites = find_jaxpr_primitives(jx, ("scatter",), min_rank=3)
    assert [s.rank for s in sites] == [3]
    assert isinstance(sites[0], EqnSite) and "scatter" in str(sites[0])


def test_find_dtype_leaks_under_x64():
    def fn(x):
        return x.astype("float64") * 2.0
    with jax.experimental.enable_x64():
        jx = jax.jit(fn).trace(jnp.ones((3,), jnp.float32)).jaxpr
    leaks = find_dtype_leaks(jx)
    assert leaks and all("float64" not in s.primitive for s in leaks)
    assert find_dtype_leaks(_jaxpr(lambda x: x * 2, jnp.ones(3))) == []


# ---------------------------------------------------------------------------
# dtype-byte parsing
# ---------------------------------------------------------------------------

def test_parse_shape_bytes_dtypes():
    assert parse_shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert parse_shape_bytes("bf16[4,8]") == 4 * 8 * 2
    assert parse_shape_bytes("(s32[10], u8[3])") == 40 + 3
    assert parse_shape_bytes("pred[7]") == 7
    assert parse_shape_bytes("f64[2]") == 16
    assert parse_shape_bytes("opaque[]") == 0


# ---------------------------------------------------------------------------
# async collective regression (the PR 10 parser fix)
# ---------------------------------------------------------------------------

ASYNC_HLO = """
ENTRY main {
  p0 = f32[16,128]{1,0} parameter(0)
  p1 = bf16[4,8]{1,0} parameter(1)
  ars = f32[16,128]{1,0} all-reduce-start(p0), to_apply=add
  ard = f32[16,128]{1,0} all-reduce-done(ars)
  ags = (bf16[4,8]{1,0}, bf16[8,8]{1,0}) all-gather-start(p1), dimensions={0}
  agd = bf16[8,8]{1,0} all-gather-done(ags)
  cps = f32[16,128]{1,0} collective-permute-start(ard), source_target_pairs={{0,1}}
  cpd = f32[16,128]{1,0} collective-permute-done(cps)
  ROOT out = f32[16,128]{1,0} add(ard, cpd)
}
"""


def test_async_collectives_fold_into_sync_counts():
    out = parse_collective_bytes(ASYNC_HLO)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 16 * 128 * 4
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == (4 * 8 + 8 * 8) * 2
    assert out["collective-permute"]["count"] == 1
    # -done ops consume the handle, not new bytes: never double-counted
    assert sum(v["count"] for v in out.values()) == 3


def test_find_collectives_names_the_hlo_line():
    recs = find_collectives(ASYNC_HLO)
    ops = {r["op"] for r in recs}
    assert ops == {"all-reduce-start", "all-gather-start",
                   "collective-permute-start"}
    ar = next(r for r in recs if r["op"] == "all-reduce-start")
    assert ar["line_no"] == 5 and "all-reduce-start" in ar["line"]


def test_sync_collectives_still_parse():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %rs = f32[8]{0} reduce-scatter(%y), dimensions={0}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["reduce-scatter"] == {"bytes": 32, "count": 1}
