"""End-to-end behaviour tests for the whole system (paper pipeline +
framework substrate wired together)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.data.synthetic import binary_strokes, repetitive_tokens
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig


def test_paper_pipeline_end_to_end():
    """Train ARM -> FPI sampling -> exactness -> call savings; the paper's
    core loop as one test."""
    cfg = PixelCNNConfig(height=8, width=8, channels=1, categories=2,
                         filters=12, n_res=1, first_kernel=5)
    data = jnp.asarray(binary_strokes(64, 8, 8, seed=0))
    params = PixelCNN.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(
            lambda p: PixelCNN.bpd(p, batch, cfg))(params)
        g = optim.zero_frozen(g)
        u, state = opt.update(g, state, params)
        return optim.apply_updates(params, u), state, l

    for _ in range(60):
        params, state, l = step(params, state, data)

    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(1), (2, cfg.d, cfg.categories))
    x_ref, st_ref = ps.ancestral_sample(arm_fn, eps)
    x_fpi, st_fpi = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_fpi))
    assert int(st_fpi.arm_calls) < int(st_ref.arm_calls) // 2


def test_serving_pipeline_end_to_end():
    """Train LM -> engine generation windows 1 vs 8 -> exactness + savings."""
    from repro.configs import get_config
    from repro.engine import PredictiveSampler
    from repro.models.losses import lm_loss
    from repro.models.transformer import TransformerLM

    cfg = get_config("gemma3-1b", reduced=True)
    data = repetitive_tokens(64, 48, cfg.vocab, seed=0)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        g = optim.zero_frozen(g)
        u, state = opt.update(g, state, params)
        return optim.apply_updates(params, u), state, l

    rng = np.random.default_rng(0)
    for _ in range(80):
        params, state, l = step(params, state,
                                jnp.asarray(data[rng.integers(0, 64, 8)]))

    prompts = jnp.asarray(repetitive_tokens(2, 6, cfg.vocab, seed=9))
    ek = jax.random.PRNGKey(3)
    t1, s1 = PredictiveSampler(cfg, params, window=1, max_len=64,
                               eps_key=ek).generate(prompts, 20)
    t8, s8 = PredictiveSampler(cfg, params, window=8, max_len=64,
                               eps_key=ek).generate(prompts, 20)
    np.testing.assert_array_equal(np.asarray(t1[:, :26]),
                                  np.asarray(t8[:, :26]))
    assert s8["rounds"] < s1["rounds"]


def test_no_tp_rules_shard_everything_validly():
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _leaf_spec_no_tp

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = get_config("internvl2-1b")
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        spec = _leaf_spec_no_tp(names, leaf, FakeMesh())
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            n = 256 if isinstance(ax, tuple) else 16
            assert leaf.shape[dim] % n == 0, (names, leaf.shape, spec)
