"""Contracts are ON by default in tests (DESIGN.md §17): every serving
program an engine compiles during the suite is checked against its named
contract at first dispatch. Explicitly exported env (e.g. a job that
sets ``REPRO_CHECK_CONTRACTS=0`` to measure compile time) wins."""
import os

os.environ.setdefault("REPRO_CHECK_CONTRACTS", "1")
