"""Deliverable-integrity checks: the dry-run artifact set matches the
assigned (architecture x shape x mesh) matrix and every record is complete.

Skips gracefully if the sweep hasn't been run in this checkout."""
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(ART) or len(os.listdir(ART)) < 80,
    reason="dry-run sweep artifacts not present (run launch/dryrun --all)")


def _load_all():
    return {f: json.load(open(os.path.join(ART, f)))
            for f in os.listdir(ART) if f.endswith(".json")}


def test_full_matrix_covered():
    from repro.configs import ARCHS, SHAPES
    recs = _load_all()
    assert len(recs) == len(ARCHS) * len(SHAPES) * 2   # 10 x 4 x 2 meshes
    for a in ARCHS:
        for s in SHAPES:
            for m in ("pod16x16", "pod2x16x16"):
                assert f"{a}__{s}__{m}.json" in recs


def test_all_runnable_pairs_compiled_ok():
    from repro.configs import shape_applicable
    recs = _load_all()
    for name, r in recs.items():
        runnable, _ = shape_applicable(r["arch"], r["shape"])
        if runnable:
            assert r["status"] == "ok", (name, r.get("error", "")[:200])
            assert r["flops"] > 0
            assert r["bytes_accessed"] > 0
            assert "collectives" in r and "memory" in r
        else:
            assert r["status"] == "skipped"
            assert r["reason"]


def test_multipod_shards_the_pod_axis():
    """2-pod records must exist for every runnable pair and train flops per
    device should not exceed the single-pod value (batch split over pods)."""
    recs = _load_all()
    for name, r in recs.items():
        if r["status"] != "ok" or r["mesh"] != "pod2x16x16":
            continue
        single = recs[name.replace("pod2x16x16", "pod16x16")]
        if single["status"] != "ok" or r["shape"] != "train_4k":
            continue
        assert r["flops"] <= single["flops"] * 1.1, name
