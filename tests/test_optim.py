"""Optimizers/schedules: convergence on a quadratic, shapes, factored states."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


@pytest.mark.parametrize("make", [
    lambda: optim.adamw(0.1),
    lambda: optim.adafactor(0.5),
    lambda: optim.sgd(0.05),
], ids=["adamw", "adafactor", "sgd"])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((4, 8))}
    target = {"w": jnp.asarray([1.0, 1.0]), "m": jnp.zeros((4, 8))}
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params)
        params = optim.apply_updates(params, u)
    assert float(loss(params)) < l0 * 0.05


def test_adafactor_state_is_factored():
    opt = optim.adafactor(0.1)
    params = {"big": jnp.zeros((128, 256)), "vec": jnp.zeros((64,))}
    state = opt.init(params)
    assert state["v"]["big"]["vr"].shape == (128,)
    assert state["v"]["big"]["vc"].shape == (256,)
    assert state["v"]["vec"]["v"].shape == (64,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    norm = float(jnp.linalg.norm(clipped["a"]))
    assert norm == pytest.approx(1.0, rel=1e-4)
    assert float(gn) == pytest.approx(100.0 * np.sqrt(10), rel=1e-4)


def test_schedules():
    from repro.optim import (constant_schedule, cosine_schedule,
                             linear_warmup_cosine)
    s = linear_warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(s(jnp.asarray(100))) < 0.2
    c = cosine_schedule(2.0, 50)
    assert float(c(jnp.asarray(0))) == pytest.approx(2.0)
    assert float(constant_schedule(0.3)(jnp.asarray(7))) == pytest.approx(0.3)


def test_zero_frozen_through_containers():
    tree = ({"_mask": jnp.ones(3), "w": jnp.ones(3)},
            [{"_buf": jnp.ones(2), "b": jnp.ones(2)}])
    z = optim.zero_frozen(tree)
    assert float(z[0]["_mask"].sum()) == 0.0
    assert float(z[0]["w"].sum()) == 3.0
    assert float(z[1][0]["_buf"].sum()) == 0.0
    assert float(z[1][0]["b"].sum()) == 2.0


def test_gradient_accumulation_matches_full_batch():
    """accum=2 must produce (numerically) the same update as full batch."""
    from repro.configs import get_config
    from repro.launch.train import make_train_step
    from repro.models.transformer import TransformerLM

    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    opt = optim.sgd(0.1)
    batch = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)

    s1 = make_train_step(cfg, opt, remat=False, accum_steps=1)
    s2 = make_train_step(cfg, opt, remat=False, accum_steps=2)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
