"""Sharding rules: every param/cache leaf of every arch gets a valid spec
(divisible or replicated) on the production mesh axes sizes."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.api import Rules


class FakeMesh:
    """Only .shape and .axis_names are consulted by the rules."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "jamba-1.5-large-398b",
                                  "mistral-large-123b", "gemma3-1b",
                                  "rwkv6-7b"])
def test_param_specs_divisible(arch):
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _leaf_spec

    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    sizes = {"data": 16, "model": 16}
    n_sharded = 0
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        spec = _leaf_spec(names, leaf, mesh)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, (names, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 10  # rules actually shard things


def test_expert_leaves_get_model_on_expert_dim():
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _leaf_spec

    cfg = get_config("deepseek-v3-671b")
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    leaf = params["blocks"][0]["ffn"]["experts"]["up"]
    spec = _leaf_spec(["blocks", "0", "ffn", "experts", "up"], leaf, mesh)
    # (n_blocks, E, D, F): scan axis unsharded, E -> model, D -> data
    assert spec == P(None, "model", "data", None)
    # router replicated (shard_map contract)
    rspec = _leaf_spec(["blocks", "0", "ffn", "router", "w"],
                       params["blocks"][0]["ffn"]["router"]["w"], mesh)
    assert rspec == P()


def test_rules_spec_dedups_axes():
    r = Rules({"batch": ("pod", "data"), "embed": "model",
               "heads": "model"})
    # second use of "model" in one spec must be dropped
    assert r.spec(("batch", "heads", "embed")) == P(("pod", "data"),
                                                    "model", None)


def test_cache_specs_prefer_batch_dp():
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _cache_leaf_spec

    cfg = get_config("qwen3-1.7b")
    mesh = FakeMesh({"data": 16, "model": 16})
    cache = jax.eval_shape(
        lambda: TransformerLM.init_cache(cfg, 128, 32776))
    kleaf = cache["blocks"][0]["mixer"]["k"]  # (n_blocks, B, S, KV, hd)
    spec = _cache_leaf_spec(["blocks", "0", "mixer", "k"], kleaf, mesh, 128)
    assert spec[1] == "data"          # batch over dp
    assert "model" in tuple(spec)     # something TP-sharded
