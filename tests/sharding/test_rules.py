"""Sharding rules: every param/cache leaf of every arch gets a valid spec
(divisible or replicated) on the production mesh axes sizes."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.api import Rules


class FakeMesh:
    """Only .shape and .axis_names are consulted by the rules."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "jamba-1.5-large-398b",
                                  "mistral-large-123b", "gemma3-1b",
                                  "rwkv6-7b"])
def test_param_specs_divisible(arch):
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _leaf_spec

    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    sizes = {"data": 16, "model": 16}
    n_sharded = 0
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        spec = _leaf_spec(names, leaf, mesh)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % n == 0, (names, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 10  # rules actually shard things


def test_expert_leaves_get_model_on_expert_dim():
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _leaf_spec

    cfg = get_config("deepseek-v3-671b")
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    mesh = FakeMesh({"data": 16, "model": 16})
    leaf = params["blocks"][0]["ffn"]["experts"]["up"]
    spec = _leaf_spec(["blocks", "0", "ffn", "experts", "up"], leaf, mesh)
    # (n_blocks, E, D, F): scan axis unsharded, E -> model, D -> data
    assert spec == P(None, "model", "data", None)
    # router replicated (shard_map contract)
    rspec = _leaf_spec(["blocks", "0", "ffn", "router", "w"],
                       params["blocks"][0]["ffn"]["router"]["w"], mesh)
    assert rspec == P()


def test_rules_spec_dedups_axes():
    r = Rules({"batch": ("pod", "data"), "embed": "model",
               "heads": "model"})
    # second use of "model" in one spec must be dropped
    assert r.spec(("batch", "heads", "embed")) == P(("pod", "data"),
                                                    "model", None)


def test_paged_partition_specs_shard_pool_and_slot_dims():
    """Serving (§10): every paged-cache leaf's pool dim (attention blocks)
    or slot dim (recurrent states) goes over "data"; scanned segments keep
    the leading layer axis unsharded."""
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM

    for arch in ("qwen3-1.7b", "jamba-1.5-large-398b"):
        cfg = get_config(arch, reduced=True)
        paged = jax.eval_shape(
            lambda c=cfg: TransformerLM.init_paged_cache(c, 4, 32, 4))
        specs = TransformerLM.paged_partition_specs(cfg, paged)
        flat_p = jax.tree.leaves(paged)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s) > 0
        for leaf, spec in zip(flat_p, flat_s):
            assert spec in (P("data"), P(None, "data"))
            # stacked (scanned) leaves shard dim 1, others dim 0
            dim = 0 if spec == P("data") else 1
            assert leaf.shape[dim] in (32, 4)   # pool blocks or batch slots


def test_serving_param_shardings_strip_data_axes():
    """Serving params must be data-replicated (manual-over-data round);
    only "model" tensor parallelism survives from the training specs."""
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _strip_axes, serving_param_shardings

    assert _strip_axes(P(("model", "data"), None, "data"), ("data",)) == \
        P("model", None, None)
    assert _strip_axes(P("data"), ("data", "pod")) == P(None)

    cfg = get_config("qwen3-1.7b")
    params = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = serving_param_shardings(params, mesh)
    n_model = 0
    for s in jax.tree.leaves(shardings):
        for comp in s.spec:
            axes = () if comp is None else (
                (comp,) if isinstance(comp, str) else tuple(comp))
            assert "data" not in axes and "pod" not in axes, s.spec
            n_model += "model" in axes
    assert n_model > 0          # TP specs survive the strip


def test_decode_activation_rules_route_batch_to_dp():
    from repro.sharding.rules import decode_activation_rules

    r = decode_activation_rules(FakeMesh({"data": 16, "model": 16}))
    assert r.spec(("batch", "seq", "embed")) == P("data", None, None)
    assert r.spec(("batch", "seq", "vocab")) == P("data", None, "model")
    r2 = decode_activation_rules(FakeMesh({"pod": 2, "data": 16,
                                           "model": 16}))
    assert r2.spec(("batch", None, "heads")) == P(("pod", "data"), None,
                                                  "model")


def test_cache_specs_prefer_batch_dp():
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.sharding.rules import _cache_leaf_spec

    cfg = get_config("qwen3-1.7b")
    mesh = FakeMesh({"data": 16, "model": 16})
    cache = jax.eval_shape(
        lambda: TransformerLM.init_cache(cfg, 128, 32776))
    kleaf = cache["blocks"][0]["mixer"]["k"]  # (n_blocks, B, S, KV, hd)
    spec = _cache_leaf_spec(["blocks", "0", "mixer", "k"], kleaf, mesh, 128)
    assert spec[1] == "data"          # batch over dp
    assert "model" in tuple(spec)     # something TP-sharded
