"""Sharded (shard_map expert-parallel) MoE must match the dense path.

Runs in a subprocess with 8 forced host devices so the main test process
keeps its single-device view."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.moe import MoE
    from repro.sharding import use_rules
    from repro.sharding.api import Rules
    from repro.sharding.moe_shard import moe_apply_sharded

    cfg = get_config("dbrx-132b", reduced=True)   # 4 experts top-2
    p = MoE.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

    # dense no-drop reference (exact ARM semantics)
    y_ref, aux_ref = MoE.apply(p, x, cfg, capacity_factor=None)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        y_sh, aux_sh = jax.jit(
            lambda p, x: moe_apply_sharded(p, x, cfg, mesh, None))(p, x)
    err = float(jnp.max(jnp.abs(y_ref - y_sh)))
    aux_err = abs(float(aux_ref) - float(aux_sh))
    print(json.dumps({"err": err, "aux_err": aux_err}))
""")


def test_sharded_moe_matches_dense():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.join(os.path.dirname(__file__),
                                          "..", ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] < 2e-4, rec
    # aux is a per-data-shard load-balance pmean (local-balance semantics;
    # f_e * p_e is nonlinear in the token set) — close, not identical
    assert rec["aux_err"] < 0.1, rec
