"""BlockManager units: free-list accounting, refcounts, prefix-cache chain
lookup, LRU eviction of cached-free blocks, reserved sink block."""
import numpy as np
import pytest

from repro.serving.blocks import BlockManager, chain_hashes


def test_alloc_never_hands_out_block_zero():
    m = BlockManager(num_blocks=8, block_size=4)
    got = m.alloc(7)
    assert 0 not in got
    assert sorted(got) == list(range(1, 8))
    with pytest.raises(MemoryError):
        m.alloc(1)


def test_release_returns_capacity():
    m = BlockManager(num_blocks=6, block_size=4)
    got = m.alloc(5)
    assert m.available() == 0
    m.release_all(got)
    assert m.available() == 5
    assert m.blocks_in_use() == 0
    with pytest.raises(AssertionError):
        m.release(got[0])   # double free


def test_chain_hashes_depend_on_whole_prefix():
    a = chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = chain_hashes([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert len(a) == len(b) == 2
    assert a[0] != b[0]
    # differing first block must change the SECOND block's key too (chained)
    assert a[1] != b[1]
    # identical prompts agree
    assert a == chain_hashes(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), 4)


def test_prefix_lookup_hits_registered_chain():
    m = BlockManager(num_blocks=10, block_size=4)
    prompt = np.arange(12)
    keys = chain_hashes(prompt, 4)
    blks = m.alloc(3)
    for b, k in zip(blks, keys):
        m.register(b, k)
    # same prompt: full chain hit, refcounts bumped
    hits, keys2 = m.lookup_prefix(prompt, 3)
    assert hits == blks and keys2 == keys
    assert all(m.refcount[b] == 2 for b in blks)
    # divergent second block: only the first block hits
    other = np.concatenate([prompt[:4], np.asarray([99, 98, 97, 96]),
                            prompt[8:]])
    hits2, _ = m.lookup_prefix(other, 3)
    assert hits2 == blks[:1]
    stats = m.stats.export()
    assert stats["prefix_hits"] == 4 and stats["prefix_misses"] == 2


def test_cached_free_blocks_survive_until_evicted():
    m = BlockManager(num_blocks=4, block_size=2)   # 3 usable blocks
    blks = m.alloc(2)
    keys = chain_hashes([7, 7, 7, 7], 2)
    for b, k in zip(blks, keys):
        m.register(b, k)
    m.release_all(blks)                 # refcount 0, but still hittable
    hits, _ = m.lookup_prefix([7, 7, 7, 7], 2)
    assert hits == blks                 # resurrected from cached-free
    m.release_all(blks)
    # exhaust: 1 plain free + 2 cached-free -> eviction unregisters them
    got = m.alloc(3)
    assert set(blks) <= set(got)
    assert m.stats.evictions >= 1
    hits3, _ = m.lookup_prefix([7, 7, 7, 7], 2)
    assert hits3 == []                  # evicted chain no longer hittable
