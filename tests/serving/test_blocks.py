"""BlockManager units: free-list accounting, refcounts, prefix-cache chain
lookup, LRU eviction of cached-free blocks, reserved sink block, and the
spill/migration accounting the preemption + rebalancing layer sits on."""
import numpy as np
import pytest

from repro.serving.blocks import (BlockManager, ShardedBlockPool,
                                  chain_hashes)


def test_alloc_never_hands_out_block_zero():
    m = BlockManager(num_blocks=8, block_size=4)
    got = m.alloc(7)
    assert 0 not in got
    assert sorted(got) == list(range(1, 8))
    with pytest.raises(MemoryError):
        m.alloc(1)


def test_release_returns_capacity():
    m = BlockManager(num_blocks=6, block_size=4)
    got = m.alloc(5)
    assert m.available() == 0
    m.release_all(got)
    assert m.available() == 5
    assert m.blocks_in_use() == 0
    with pytest.raises(AssertionError):
        m.release(got[0])   # double free


def test_chain_hashes_depend_on_whole_prefix():
    a = chain_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = chain_hashes([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert len(a) == len(b) == 2
    assert a[0] != b[0]
    # differing first block must change the SECOND block's key too (chained)
    assert a[1] != b[1]
    # identical prompts agree
    assert a == chain_hashes(np.asarray([1, 2, 3, 4, 5, 6, 7, 8]), 4)


def test_prefix_lookup_hits_registered_chain():
    m = BlockManager(num_blocks=10, block_size=4)
    prompt = np.arange(12)
    keys = chain_hashes(prompt, 4)
    blks = m.alloc(3)
    for b, k in zip(blks, keys):
        m.register(b, k)
    # same prompt: full chain hit, refcounts bumped
    hits, keys2 = m.lookup_prefix(prompt, 3)
    assert hits == blks and keys2 == keys
    assert all(m.refcount[b] == 2 for b in blks)
    # divergent second block: only the first block hits
    other = np.concatenate([prompt[:4], np.asarray([99, 98, 97, 96]),
                            prompt[8:]])
    hits2, _ = m.lookup_prefix(other, 3)
    assert hits2 == blks[:1]
    stats = m.stats.export()
    assert stats["prefix_hits"] == 4 and stats["prefix_misses"] == 2


def test_cached_free_blocks_survive_until_evicted():
    m = BlockManager(num_blocks=4, block_size=2)   # 3 usable blocks
    blks = m.alloc(2)
    keys = chain_hashes([7, 7, 7, 7], 2)
    for b, k in zip(blks, keys):
        m.register(b, k)
    m.release_all(blks)                 # refcount 0, but still hittable
    hits, _ = m.lookup_prefix([7, 7, 7, 7], 2)
    assert hits == blks                 # resurrected from cached-free
    m.release_all(blks)
    # exhaust: 1 plain free + 2 cached-free -> eviction unregisters them
    got = m.alloc(3)
    assert set(blks) <= set(got)
    assert m.stats.evictions >= 1
    hits3, _ = m.lookup_prefix([7, 7, 7, 7], 2)
    assert hits3 == []                  # evicted chain no longer hittable


def test_spill_leaves_hashed_blocks_hittable():
    """Preemption spill: released blocks are counted, and hashed prompt
    blocks stay in the cached-free pool so an exact resume re-hits them."""
    m = BlockManager(num_blocks=8, block_size=4)
    prompt = np.arange(8)
    keys = chain_hashes(prompt, 4)
    blks = m.alloc(3)                       # 2 prompt blocks + 1 private
    for b, k in zip(blks[:2], keys):
        m.register(b, k)
    assert m.spill(blks) == 3
    assert m.stats.spilled == 3
    assert m.blocks_in_use() == 0
    hits, _ = m.lookup_prefix(prompt, 2)
    assert hits == blks[:2]                 # resumed sequence re-hits them


def test_pool_migration_accounting():
    """begin/finish_migration move a sequence's block accounting between
    sub-pools: fresh landing ids on the destination, source refs released,
    per-shard stats recording the move."""
    pool = ShardedBlockPool(num_shards=2, blocks_per_shard=6, block_size=4)
    src = pool.manager(0).alloc(3)
    assert pool.available(0) == 2 and pool.available(1) == 5

    landing = pool.begin_migration(0, 1, 3)
    assert len(landing) == 3 and 0 not in landing
    assert pool.available(1) == 2
    pool.finish_migration(0, src)
    assert pool.available(0) == 5
    assert pool.manager(1).stats.migrated_in == 3
    assert pool.manager(0).stats.migrated_out == 3
    stats = pool.stats_export()
    assert stats["blocks_migrated_in"] == 3
    assert stats["blocks_migrated_out"] == 3

    with pytest.raises(AssertionError):
        pool.begin_migration(1, 1, 1)       # same-shard move is not a copy
    with pytest.raises(MemoryError):
        pool.begin_migration(0, 1, 3)       # destination sub-pool is full


def test_staging_ledger_claims_and_refusals():
    """StagingLedger (DESIGN.md §15): claims are granted only within the
    caller's headroom and per-shard slot budget, tracked per (shard, uid),
    and release returns exactly what was claimed."""
    from repro.serving.blocks import StagingLedger

    led = StagingLedger(slots_per_shard=2)
    assert led.try_claim(0, uid=10, need=3, headroom=8)
    assert led.staged_blocks(0) == 3 and led.staged_count(0) == 1
    assert led.has(0, 10) and not led.has(0, 11)
    # headroom refusal: the caller already netted out resident
    # reservations AND existing claims; need must fit what is left
    assert not led.try_claim(0, uid=11, need=6, headroom=5)
    assert led.try_claim(0, uid=11, need=5, headroom=5)
    # slot refusal: the shard's staging area is full
    assert not led.try_claim(0, uid=12, need=1, headroom=100)
    # shards are independent
    assert led.try_claim(1, uid=12, need=4, headroom=4)
    assert led.staged_blocks(1) == 4 and led.staged_blocks(0) == 8
    assert led.release(0, 10) == 3
    assert led.staged_blocks(0) == 5 and led.staged_count(0) == 1
    assert led.try_claim(0, uid=13, need=1, headroom=1)
    # double-claiming a staged uid is a bookkeeping bug, not a refusal
    with pytest.raises(AssertionError):
        led.try_claim(0, uid=11, need=1, headroom=10)
    with pytest.raises(KeyError):
        led.release(0, 99)                  # never claimed
