"""Subprocess driver for the kill-point crash harness (DESIGN.md §16).

Not a test file — ``test_recovery.py`` launches this script as a child
process, arms one ``REPRO_KILL_POINT`` site, and asserts the child died by
SIGKILL mid-flight; a second child with the same durable directory then
restores and finishes the work. Every line this driver prints is a flushed
JSON event (``submitted`` / ``finish`` / ``recovered`` / ``metrics``), so
whatever reached stdout before the kill is exactly what the dead process
had delivered to its client.

Usage: ``python recovery_driver.py {serve,resume,reference} [durable_dir]``

The workload is fixed and deterministic: one long low-priority request
that gets preempted (parked) by three high-priority arrivals on a batch=1
engine — so the serve phase exercises the journal (submits, park, admits,
finishes), the checkpoint (a parked snapshot with ``flush_to_disk``-ed
chain keys), and the disk tier (spill puts at every parked checkpoint),
giving all four kill points a site that actually fires.
"""
import json
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine

EPS_KEY = jax.random.PRNGKey(9)
ENGINE_KW = dict(batch=1, window_max=4, max_len=64, block_size=4,
                 adaptive=False, preempt_floor=1.0)
METRIC_KEYS = ("requests_finished", "prefill_calls", "preemptions",
               "recovered_requests", "recovered_parked",
               "checkpoints_written", "disk_spills", "disk_hits",
               "disk_promotes", "journal_appends", "resume_recomputes")


def make_requests(cfg):
    rng = np.random.default_rng(5)
    low = Request(uid=0, prompt=rng.integers(0, cfg.vocab, size=24),
                  new_tokens=10, priority=5)
    high = [Request(uid=1 + i,
                    prompt=rng.integers(0, cfg.vocab, size=6),
                    new_tokens=6, priority=0)
            for i in range(3)]
    return [low] + high


def emit(event: dict):
    print(json.dumps(event), flush=True)


def drain(eng, emitted: set):
    for r in eng.done:
        if r.uid not in emitted and r.result is not None:
            emitted.add(r.uid)
            emit({"event": "finish", "uid": int(r.uid),
                  "tokens": np.asarray(r.result).tolist()})


def run_to_done(eng, emitted: set):
    while (eng.queue or eng._staged_total()
           or any(s is not None for s in eng.slots)):
        if not eng.step():
            break
        drain(eng, emitted)
    drain(eng, emitted)


def emit_metrics(eng):
    m = eng.export_metrics()
    emit({"event": "metrics",
          **{k: int(m.get(k, 0)) for k in METRIC_KEYS}})


def main():
    phase = sys.argv[1]
    durable_dir = sys.argv[2] if len(sys.argv) > 2 else None
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(ENGINE_KW, eps_key=EPS_KEY)
    if phase in ("serve", "resume"):
        assert durable_dir, "serve/resume need a durable dir"
        kw.update(durable_dir=durable_dir, journal_fsync_every=1)
    eng = ServingEngine(cfg, params, **kw)
    emitted: set = set()

    if phase == "resume":
        n = eng.restore()
        emit({"event": "recovered", "n": int(n)})
    else:
        reqs = make_requests(cfg)
        eng.submit(reqs[0])
        emit({"event": "submitted", "uid": 0})
        eng.step()              # low-pri admitted: high-pri arrivals preempt
        drain(eng, emitted)
        for r in reqs[1:]:
            eng.submit(r)
            emit({"event": "submitted", "uid": int(r.uid)})

    run_to_done(eng, emitted)
    eng.close()
    emit_metrics(eng)


if __name__ == "__main__":
    main()
