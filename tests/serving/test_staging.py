"""Device-resident continuous batching system tests (DESIGN.md §15).

The acceptance bar: the staged engine (pre-staged prompts + in-loop slot
adoption + adaptive ``rounds_per_sync``) must emit tokens bitwise equal to
BOTH the host-admission engine (``staging_slots=0``, PR 4 behavior) on the
same traffic AND per-request solo ``PredictiveSampler.generate`` runs —
across attention, sliding-window local, MLA, and recurrent-hybrid mixers,
and under every scheduling disturbance the runtime supports (priority
arrivals, forced migration, cancellation of a staged request, injected
faults on an adopted row). Adoption must also actually pay: strictly fewer
host syncs than the ``k = 1``-under-backlog baseline on the same backlog.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import FaultPlan, Request, ServingEngine

EPS_KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(np.asarray(req.prompt)[None].astype(np.int32),
                      req.new_tokens,
                      seq_ids=np.asarray([req.seq_id], np.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _assert_all_exact(cfg, params, done, window, max_len):
    assert done, "no requests completed"
    for req in done:
        np.testing.assert_array_equal(
            req.result, _solo(cfg, params, req, window, max_len),
            err_msg=f"request {req.uid} diverged from its solo run")


def _traffic(cfg, seed=3, n=8, lo=2, hi=7, new_lo=8, new_hi=13):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(lo, hi))),
                    new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    return {r.uid: r for r in eng.run()}


def _staged_uids(eng):
    return [e.req.uid for entries in eng.staged for e in entries]


KW = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY, block_size=4,
          adaptive=False, rounds_per_sync=8)


def test_staged_adoption_bit_exact_and_fewer_syncs(qwen):
    """Deep backlog through both engines: tokens identical per uid (and to
    solo), the staged engine adopts in-loop and syncs strictly less than
    the baseline's sync-every-round-under-backlog heuristic."""
    cfg, params = qwen
    base = ServingEngine(cfg, params, staging_slots=0, **KW)
    ref = _drain(base, _traffic(cfg))

    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        **KW)
    got = _drain(eng, _traffic(cfg))
    assert set(got) == set(ref)
    for uid in ref:
        np.testing.assert_array_equal(
            got[uid].result, ref[uid].result,
            err_msg=f"request {uid}: staging changed tokens")
    assert eng.metrics.staged_sequences > 0
    assert eng.metrics.in_loop_adoptions > 0
    assert eng.metrics.host_syncs < base.metrics.host_syncs, \
        (eng.metrics.host_syncs, base.metrics.host_syncs)
    # adoption leaves nothing behind: staging area + ledger fully drained
    assert eng._staged_total() == 0
    assert all(eng.ledger.staged_count(s) == 0
               for s in range(eng.topo.data_size))
    _assert_all_exact(cfg, params, list(got.values()), 4, KW["max_len"])


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_staged_adoption_bit_exact_across_mixers(arch):
    """In-loop adoption (forced-acceptance prefill + table-row swap + fresh
    noise stream + recurrent-row zeroing) is integer bookkeeping: bitwise
    exactness must hold for every mixer family, including the recurrent
    hybrid whose adopted rows must restart their un-paged state from
    zero."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        **KW)
    got = _drain(eng, _traffic(cfg, n=6))
    assert eng.metrics.in_loop_adoptions > 0, \
        "workload never exercised in-loop adoption"
    _assert_all_exact(cfg, params, list(got.values()), 4, KW["max_len"])


def test_priority_arrival_unstages_lower_priority(qwen):
    """Staging commits strictly in queue order: a higher-priority arrival
    must not queue behind already-staged lower-priority requests — the
    area is unstaged, the newcomer re-ranks, and staging rebuilds with it
    at the head (DESIGN.md §15 reconciliation)."""
    cfg, params = qwen
    # k = 1 keeps the setup steps deterministic (the running request must
    # not finish and adopt mid-setup); reconciliation order is k-invariant
    kw = dict(batch=1, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False, rounds_per_sync=1)
    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        preempt=False, **kw)
    rng = np.random.default_rng(5)
    running = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4),
                      new_tokens=24, priority=5)
    eng.submit(running)
    eng.step()
    lows = [Request(uid=1 + i, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=6, priority=5) for i in range(2)]
    for r in lows:
        eng.submit(r)
    eng.step()                          # no free slot -> both staged
    assert _staged_uids(eng) == [1, 2]
    hi = Request(uid=9, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=6,
                 priority=0)
    eng.submit(hi)
    eng.step()                          # reconcile: hi outranks the area
    assert _staged_uids(eng)[0] == 9
    done = eng.run()
    order = [r.uid for r in done]
    assert order.index(9) < order.index(1)
    assert order.index(9) < order.index(2)
    _assert_all_exact(cfg, params, done, 4, kw["max_len"])


def test_adoption_with_forced_migration(qwen):
    """A mid-flight slot migration must compose with staging: the moved
    row keeps decoding exactly and later frees into the adoption scan like
    any other row."""
    cfg, params = qwen

    def traffic(eng, disturb):
        rng = np.random.default_rng(70)
        # long enough to survive the first k=8 dispatch (<= 8 rounds x
        # (W+1) tokens = 40 < 44), so there is still a row to migrate
        first = Request(uid=50, prompt=rng.integers(0, cfg.vocab, 3),
                        new_tokens=44)
        reqs = _traffic(cfg, seed=7, n=5)
        eng.submit(first)
        eng.step()
        if disturb:
            occ = [b for b in range(2) if eng.slots[b] is not None]
            free = [b for b in range(2) if eng.slots[b] is None]
            assert occ and free
            eng.migrate_slot(occ[0], free[0])
        for r in reqs:
            eng.submit(r)
        return {r.uid: r for r in eng.run()}

    kw = dict(staging_slots=2, adaptive_rounds=False, **{**KW,
                                                         "max_len": 64})
    ref = traffic(ServingEngine(cfg, params, **kw), False)
    eng = ServingEngine(cfg, params, **kw)
    got = traffic(eng, True)
    assert eng.metrics.migrations == 1
    assert eng.metrics.in_loop_adoptions > 0
    for uid in ref:
        np.testing.assert_array_equal(
            got[uid].result, ref[uid].result,
            err_msg=f"request {uid}: migration + staging diverged")
    _assert_all_exact(cfg, params, list(got.values()), 4, 64)


def test_cancel_staged_request_releases_claim(qwen):
    """``cancel(uid)`` must find a request in the staging area: its blocks
    and ledger claim are released immediately, it finishes with the
    structured 'cancelled' error, and the remaining traffic is unaffected
    bit-for-bit."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False, rounds_per_sync=1)
    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        **kw)
    rng = np.random.default_rng(11)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4),
                       new_tokens=24))
    eng.step()
    eng.submit(Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3),
                       new_tokens=6))
    eng.submit(Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3),
                       new_tokens=6))
    eng.step()
    assert _staged_uids(eng) == [1, 2]
    free_before = eng._mgr(0).available()
    assert eng.cancel(1)
    assert _staged_uids(eng) == [2]
    assert eng.ledger.staged_count(0) == 1
    assert eng._mgr(0).available() > free_before     # blocks back in pool
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {0, 1, 2}
    assert done[1].error.code == "cancelled" and done[1].result is None
    assert eng.ledger.staged_count(0) == 0
    _assert_all_exact(cfg, params, [done[0], done[2]], 4, kw["max_len"])


def test_poisoned_adopted_row_quarantined_then_retried(qwen):
    """A staged request whose noise stream is NaN-poisoned (§14) is adopted
    in-loop, trips the health bit, and is failed through the displaced-
    episode harvest path; with a retry budget it re-runs on a fresh stream
    and every request — including the retried one — matches solo."""
    cfg, params = qwen
    reqs = _traffic(cfg, n=6)
    poisoned_stream = reqs[4].seq_id          # deep enough to be staged
    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        request_retries=1,
                        faults=FaultPlan(poison_streams=(poisoned_stream,)),
                        **KW)
    done = list(_drain(eng, reqs).values())
    assert all(r.ok for r in done), \
        [str(r.error) for r in done if r.error]
    assert reqs[4].retries == 1
    assert reqs[4].seq_id != poisoned_stream   # fresh stream on retry
    assert eng.metrics.in_loop_adoptions > 0
    _assert_all_exact(cfg, params, done, 4, KW["max_len"])


def test_staged_engine_leaves_rows_clean(qwen):
    """After draining, adopted rows are as clean as admitted ones: seq_ids
    zeroed, positions reset — the §12 slot-hygiene contract extended to the
    adoption path."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, staging_slots=2, adaptive_rounds=False,
                        **KW)
    _drain(eng, _traffic(cfg, n=6))
    assert eng.metrics.in_loop_adoptions > 0
    assert np.asarray(eng.seq_ids).tolist() == [0] * eng.B
    assert np.asarray(eng.n).tolist() == [1] * eng.B
    assert all(s is None for s in eng.slots)


def test_staged_interleavings_hypothesis(qwen):
    """Property net: random interleavings of submit / step / migrate /
    cancel through the staged engine stay bitwise-equal to solo runs and
    drain the staging ledger."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = qwen

    op = st.one_of(
        st.tuples(st.just("submit"),
                  st.tuples(st.integers(1, 8), st.integers(2, 10))),
        st.tuples(st.just("step"), st.none()),
        st.tuples(st.just("migrate"), st.integers(0, 3)),
        st.tuples(st.just("cancel"), st.integers(0, 5)),
    )

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(op, min_size=2, max_size=10),
               st.integers(1, 2), st.booleans())
    def run_plan(plan, slots, adaptive_k):
        if sum(1 for p in plan if p[0] == "submit") < 3:
            plan = [("submit", (2, 4)), ("submit", (3, 6)),
                    ("submit", (2, 5))] + plan
        eng = ServingEngine(cfg, params, staging_slots=slots,
                            adaptive_rounds=adaptive_k, **KW)
        uid, cancelled = 0, set()
        for op_name, arg in plan:
            if op_name == "submit":
                L_p, new = arg
                rng = np.random.default_rng(100 + uid)
                eng.submit(Request(uid=uid,
                                   prompt=rng.integers(0, cfg.vocab, L_p),
                                   new_tokens=new))
                uid += 1
            elif op_name == "step":
                if (eng.queue or eng._staged_total()
                        or any(s is not None for s in eng.slots)):
                    eng.step()
            elif op_name == "migrate":
                occ = [b for b in range(eng.B) if eng.slots[b] is not None]
                free = [b for b in range(eng.B) if eng.slots[b] is None]
                if occ and free:
                    eng.migrate_slot(occ[arg % len(occ)],
                                     free[arg % len(free)])
            elif op_name == "cancel" and uid:
                target = arg % uid
                if eng.cancel(target):
                    cancelled.add(target)
        done = eng.run()
        assert len(done) == uid
        assert eng._staged_total() == 0
        assert all(eng.ledger.staged_count(s) == 0
                   for s in range(eng.topo.data_size))
        for r in done:
            if r.uid in cancelled:
                assert r.error.code == "cancelled"
            else:
                np.testing.assert_array_equal(
                    r.result, _solo(cfg, params, r, 4, KW["max_len"]),
                    err_msg=f"request {r.uid} diverged from its solo run")

    run_plan()
