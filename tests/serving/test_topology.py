"""Topology/router units — all host-side (no devices, no mesh): slot and
block-pool partition math, pool-pressure admission routing, per-shard stats
merging, and the priority/EDF/FIFO queue order."""
import numpy as np
import pytest

from repro.serving import Request, ServingTopology, ShardedBlockPool
from repro.serving.admission import AdmissionQueue


class FakeMesh:
    """Only .shape and .axis_names are consulted by the partition math."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_single_device_topology_is_one_shard():
    t = ServingTopology()
    assert t.data_size == 1
    assert t.auto_axes == frozenset()
    assert t.slots_per_shard(4) == 4
    assert t.shard_of_slot(3, 4) == 0
    assert list(t.slot_range(0, 4)) == [0, 1, 2, 3]
    assert t.block_offset(0, 17) == 0

    def fn(*a):
        return a

    # without a mesh the round wrapper is the identity (plain jit path)
    assert t.wrap_round(fn, None, 6, 4) is fn


def test_partition_math_over_data_shards():
    t = ServingTopology(FakeMesh({"data": 2, "model": 4}))
    assert t.data_size == 2
    assert t.auto_axes == frozenset({"model"})
    assert t.slots_per_shard(8) == 4
    assert [t.shard_of_slot(b, 8) for b in range(8)] == [0] * 4 + [1] * 4
    assert list(t.slot_range(1, 8)) == [4, 5, 6, 7]
    # global pool id of shard 1's sink = its sub-pool base
    assert t.block_offset(1, 33) == 33
    with pytest.raises(AssertionError):
        t.slots_per_shard(5)            # batch must divide over shards


def test_route_picks_max_headroom_with_ties_to_lowest():
    assert ShardedBlockPool.route(3, {0: 5, 1: 9}) == 1
    assert ShardedBlockPool.route(3, {1: 9, 0: 5}) == 1
    assert ShardedBlockPool.route(3, {0: 9, 1: 9}) == 0   # tie -> lowest id
    assert ShardedBlockPool.route(6, {0: 5, 1: 4}) is None  # nobody fits
    assert ShardedBlockPool.route(5, {0: 5, 1: 4}) == 0   # exact fit admits
    assert ShardedBlockPool.route(1, {}) is None          # no free slots


def test_sub_pools_are_independent():
    pool = ShardedBlockPool(2, 8, 4)
    got = pool.manager(0).alloc(3)
    assert pool.available(0) == 4 and pool.available(1) == 7
    assert pool.available() == 11
    assert pool.blocks_in_use() == 3
    # shard-local ids: both shards can hand out the same local id
    other = pool.manager(1).alloc(3)
    assert got == other
    pool.manager(0).release_all(got)
    pool.manager(1).release_all(other)
    assert pool.available() == 14 and pool.blocks_in_use() == 0


def test_prefix_caches_do_not_cross_shards_and_stats_merge():
    pool = ShardedBlockPool(2, 8, 2)
    prompt = np.asarray([5, 6, 7, 8, 9])
    m0 = pool.manager(0)
    blocks = m0.alloc(2)
    from repro.serving import chain_hashes
    keys = chain_hashes(prompt, 2)
    for b, k in zip(blocks, keys):
        m0.register(b, k)
    # same prompt hits on shard 0, misses on shard 1 (per-shard cache)
    hits0, _ = m0.lookup_prefix(prompt, 2)
    assert hits0 == blocks
    hits1, _ = pool.manager(1).lookup_prefix(prompt, 2)
    assert hits1 == []
    merged = pool.stats_export()
    assert merged["prefix_hits"] == 2
    assert merged["prefix_misses"] == 2
    assert merged["prefix_hit_rate"] == 0.5


def test_queue_orders_priority_then_deadline_then_fifo():
    q = AdmissionQueue()
    reqs = [Request(uid=0, prompt=np.ones(1), new_tokens=1),
            Request(uid=1, prompt=np.ones(1), new_tokens=1, deadline=500.0),
            Request(uid=2, prompt=np.ones(1), new_tokens=1, deadline=5000.0),
            Request(uid=3, prompt=np.ones(1), new_tokens=1, priority=-1),
            Request(uid=4, prompt=np.ones(1), new_tokens=1)]
    for r in reqs:
        q.push(r)
    # priority class first; then earliest deadline; deadline-free requests
    # sort last and stay FIFO among themselves
    assert [q.pop().uid for _ in range(len(reqs))] == [3, 1, 2, 0, 4]


def test_deadline_time_and_miss_flag():
    r = Request(uid=0, prompt=np.ones(1), new_tokens=1)
    assert r.deadline_time == float("inf")
    r.finish_time = 1e12
    assert not r.missed_deadline
    d = Request(uid=1, prompt=np.ones(1), new_tokens=1, deadline=2.0)
    d.submit_time = 100.0
    assert d.deadline_time == 102.0
    d.finish_time = 101.5
    assert not d.missed_deadline
    d.finish_time = 102.5
    assert d.missed_deadline
