"""Kill-point crash/recovery matrix (DESIGN.md §16).

For each named kill point a child engine (``recovery_driver.py serve``) is
SIGKILLed mid-flight — no cleanup, no atexit, exactly a power-cut process —
and a second child restores from the same durable directory and drains the
remaining work. The acceptance bar is the engine's own exactness
invariant: every token any phase delivered must be bit-identical to the
request's solo ``PredictiveSampler.generate`` run, and every request whose
``submit()`` returned before the kill (= durably journaled) must be
delivered by the union of the two phases. SIGKILL (not an exception) is
the point: flushed-but-unfsynced journal frames survive it, which is what
the ``pre_fsync`` site exists to prove.
"""
import json
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM

sys.path.insert(0, os.path.dirname(__file__))
from recovery_driver import ENGINE_KW, EPS_KEY, make_requests  # noqa: E402

DRIVER = os.path.join(os.path.dirname(__file__), "recovery_driver.py")

# (kill point, firing index): indices chosen to land mid-run for the
# driver's fixed workload — after the first admission but before the queue
# drains — so every phase boundary (journaled-not-checkpointed,
# mid-checkpoint, flushed-not-fsynced, fully synced) is actually hit.
MATRIX = [("post_admit", 2), ("mid_spill", 1),
          ("pre_fsync", 5), ("post_sync", 3)]


def _run_driver(phase: str, ddir: str, kill: str = "") -> list[dict]:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("REPRO_KILL_POINT", None)
    if kill:
        env["REPRO_KILL_POINT"] = kill
    proc = subprocess.run([sys.executable, DRIVER, phase, ddir],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    if kill:
        assert proc.returncode == -signal.SIGKILL, (
            f"kill point {kill!r} never fired "
            f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")
    else:
        assert proc.returncode == 0, proc.stderr[-2000:]
    events = []
    for line in proc.stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            events.append(json.loads(line))
    return events


@pytest.fixture(scope="module")
def reference():
    """Solo-run tokens per request — the engine-independent ground truth
    every recovered/merged result must match bitwise."""
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    s = PredictiveSampler(cfg, params, window=ENGINE_KW["window_max"],
                          max_len=ENGINE_KW["max_len"], eps_key=EPS_KEY)
    out = {}
    for req in make_requests(cfg):
        t, _ = s.generate(
            jnp.asarray(np.asarray(req.prompt)[None], jnp.int32),
            req.new_tokens,
            seq_ids=jnp.asarray([req.seq_id], jnp.int32))
        out[req.uid] = np.asarray(t[0, :len(req.prompt) + req.new_tokens])
    return out


@pytest.mark.parametrize("point,index", MATRIX,
                         ids=[p for p, _ in MATRIX])
def test_kill_point_recovery_bitwise(tmp_path, reference, point, index):
    ddir = str(tmp_path / "durable")
    serve = _run_driver("serve", ddir, kill=f"{point}:{index}")
    resume = _run_driver("resume", ddir)

    submitted = {e["uid"] for e in serve if e.get("event") == "submitted"}
    merged = {}
    for e in serve + resume:
        if e.get("event") != "finish":
            continue
        tokens = np.asarray(e["tokens"])
        if e["uid"] in merged:
            # a finish delivered pre-crash and re-delivered post-restore
            # must be the SAME tokens (determinism, not dedup, is the
            # exactly-once story)
            np.testing.assert_array_equal(merged[e["uid"]], tokens)
        merged[e["uid"]] = tokens

    # no durably-accepted request is lost
    assert submitted, "serve phase died before accepting anything"
    missing = submitted - set(merged)
    assert not missing, f"accepted requests lost across the crash: {missing}"
    # every delivered token sequence is bit-identical to its solo run
    for uid, tokens in merged.items():
        np.testing.assert_array_equal(
            tokens, reference[uid],
            err_msg=f"uid {uid} diverged after {point} crash")

    # the long parked low-priority request (uid 0) finishes last, so any
    # mid-run crash leaves at least it to re-enqueue (journaled finishes
    # re-deliver through done without counting here)
    recovered = [e for e in resume if e.get("event") == "recovered"]
    assert recovered and recovered[0]["n"] >= 1


def test_uninterrupted_durable_run_is_reference_exact(tmp_path, reference):
    """No crash at all: the durability machinery (journal appends, per-step
    checkpoints, disk spills) must be bitwise invisible."""
    events = _run_driver("serve", str(tmp_path / "durable"))
    finishes = {e["uid"]: np.asarray(e["tokens"])
                for e in events if e.get("event") == "finish"}
    assert set(finishes) == set(reference)
    for uid, tokens in finishes.items():
        np.testing.assert_array_equal(tokens, reference[uid])
    (metrics,) = [e for e in events if e.get("event") == "metrics"]
    assert metrics["journal_appends"] > 0
    assert metrics["checkpoints_written"] > 0
    assert metrics["preemptions"] >= 1       # the workload parked someone
