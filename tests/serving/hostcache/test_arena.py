"""Host-arena / staging-ring / tier-facade units (DESIGN.md §13): byte
budget enforcement, strict LRU order, refcount pinning, slab recycling,
dedup puts, contiguous-run lookup, one-shot park consumption."""
import numpy as np
import pytest

from repro.serving import HostArena, HostTier, StagingRing


def _blk(fill, shape=(4, 8), dtype=np.float32):
    return np.full(shape, fill, dtype)


BLK_BYTES = _blk(0).nbytes


def test_budget_is_a_hard_bound():
    a = HostArena(3 * BLK_BYTES)
    for i in range(5):
        assert a.put(i, [_blk(i)])
        assert a.bytes_resident + a.bytes_slab <= a.capacity_bytes
    assert len(a) == 3                       # LRU evicted the overflow
    assert a.stats.evictions == 2
    # an entry that can never fit is rejected, not partially admitted
    assert not a.put("huge", [_blk(0, shape=(64, 64))])
    assert a.stats.rejections == 1
    assert len(a) == 3


def test_strict_lru_eviction_order_with_touch():
    a = HostArena(3 * BLK_BYTES)
    for k in "xyz":
        a.put(k, [_blk(1)])
    assert a.get("x") is not None            # refresh x: y is now oldest
    a.put("w", [_blk(2)])
    assert not a.contains("y")               # y evicted, x survived
    assert a.contains("x") and a.contains("z") and a.contains("w")


def test_pinned_entries_are_eviction_exempt():
    a = HostArena(2 * BLK_BYTES)
    assert a.put("pinned", [_blk(7)], pin=True)
    a.put("a", [_blk(1)])
    a.put("b", [_blk(2)])                    # evicts "a", never "pinned"
    assert a.contains("pinned") and not a.contains("a")
    # fully pinned arena: a new put is rejected outright
    assert a.put("c", [_blk(3)], pin=True)
    assert not a.put("d", [_blk(4)])
    a.unpin("pinned")
    assert a.put("d", [_blk(4)])             # unpinned entry now evictable
    assert not a.contains("pinned")


def test_slab_buffers_are_recycled_per_shape():
    a = HostArena(4 * BLK_BYTES)
    a.put("a", [_blk(1)])
    a.drop("a")                              # buffer parked in the slab pool
    assert a.bytes_slab == BLK_BYTES and a.bytes_resident == 0
    a.put("b", [_blk(2)])                    # same shape: recycled, no alloc
    assert a.stats.slab_reuses == 1
    assert a.bytes_slab == 0
    np.testing.assert_array_equal(a.get("b")[0], _blk(2))


def test_dedup_put_refreshes_and_optionally_pins():
    a = HostArena(4 * BLK_BYTES)
    assert a.put("k", [_blk(5)])
    assert a.put("k", [_blk(5)], pin=True)   # dedup: no second copy
    assert a.stats.dedup_hits == 1
    assert a.bytes_resident == BLK_BYTES
    a.put("x", [_blk(1)])
    a.put("y", [_blk(2)])
    a.put("z", [_blk(3)])                    # pressure: "k" is pinned, safe
    assert a.contains("k")


def test_unpin_is_tolerant_of_missing_or_unpinned_entries():
    """§14 contract: integrity failures drop corrupt entries even while
    pinned, and the pin owner STILL unpins on its normal path afterwards —
    so unpinning a missing or unpinned key is a silent no-op, never an
    error, and never corrupts a live refcount."""
    a = HostArena(BLK_BYTES)
    a.put("k", [_blk(0)])
    a.unpin("k")                             # unpinned entry: no-op
    a.unpin("gone")                          # missing entry: no-op
    a.put("p", [_blk(1)], pin=True)
    a.unpin("p")
    a.unpin("p")                             # double unpin: refs stay >= 0
    assert a.pin("p")                        # entry still usable
    a.unpin("p")


def test_tier_kv_run_stops_at_first_gap():
    t = HostTier(capacity_bytes=1 << 20)
    keys = [101, 102, 103, 104]
    for k in (101, 102, 104):                # 103 missing: run must stop
        assert t.put_kv(0, k, [_blk(k)])
    assert t.kv_run(0, keys) == 2
    assert t.kv_run(0, keys[2:]) == 0        # resident-behind-a-gap unused
    # shard namespaces are disjoint partitions of one shared budget
    assert t.kv_run(1, keys) == 0
    assert t.put_kv(1, 101, [_blk(1)])
    assert t.kv_run(1, keys) == 1


def test_tier_park_is_pinned_and_one_shot():
    t = HostTier(capacity_bytes=4 * BLK_BYTES)
    assert t.put_park(7, [_blk(9), _blk(10)])
    t.put_kv(0, 1, [_blk(1)])
    t.put_kv(0, 2, [_blk(2)])                # pressure: park entry pinned
    got = t.take_park(7)
    np.testing.assert_array_equal(got[0], _blk(9))
    assert t.take_park(7) is None            # consumed
    assert t.arena.bytes_resident <= 2 * BLK_BYTES


def test_staging_ring_depth_and_accounting():
    ring = StagingRing(depth=2)
    for i in range(5):
        ring.stage(i, [_blk(i)])
    assert len(ring) == 5                    # nothing lost to the depth cap
    tags = []
    while True:
        item = ring.take()
        if item is None:
            break
        tag, devs = item
        tags.append(tag)
        np.testing.assert_array_equal(np.asarray(devs[0]), _blk(tag))
    assert tags == [0, 1, 2, 3, 4]           # FIFO order preserved
    st = ring.stats_export()
    assert st["h2d_staged"] == 5
    assert st["h2d_staged_bytes"] == 5 * BLK_BYTES
    assert 0.0 <= st["h2d_overlap_frac"] <= 1.0


def test_corrupt_pinned_get_leaks_no_pin_and_strands_no_bytes():
    """§14/§16 edge: an integrity failure on a PINNED entry drops it like
    any other corrupt entry — and must fully release its accounting: no
    phantom pin survives (the arena can evict its way back to empty) and
    every byte lands in the slab pool or the free budget, never stranded."""
    fired = []
    a = HostArena(4 * BLK_BYTES, on_corruption=fired.append)
    assert a.put("k", [_blk(3)], pin=True)
    # corrupt the stored copy in place, then read through the pin
    a._entries["k"].arrays[0].view(np.uint8).flat[0] ^= 0xFF
    assert a.get("k") is None
    assert a.stats.checksum_failures == 1 and fired == ["k"]
    assert not a.contains("k")
    # accounting: resident bytes released to the slab, budget intact
    assert a.bytes_resident == 0
    assert a.bytes_slab == BLK_BYTES
    assert a.bytes_resident + a.bytes_slab <= a.capacity_bytes
    # the dead pin protects nothing: the arena fills back to capacity
    for i in range(4):
        assert a.put(i, [_blk(i)])
    assert len(a) == 4 and a.stats.rejections == 0
    # the pin owner's normal-path unpin is a harmless no-op (§14)
    a.unpin("k")
    assert a.put("again", [_blk(9)])          # still evictable, no refs leak
    assert a.stats.slab_reuses >= 1           # corrupt buffer was recycled


def test_tier_drop_park_ungated_during_half_open_probe():
    """Refcount/payload hygiene must run in EVERY breaker state: a parked
    payload discarded while the tier is open or mid-probe (half_open) still
    frees its pinned bytes — otherwise a tripped tier slowly pins the arena
    full."""
    from repro.serving.faults import CircuitBreaker

    br = CircuitBreaker(threshold=1, cooldown=4)
    t = HostTier(capacity_bytes=4 * BLK_BYTES, breaker=br)
    assert t.put_park(7, [_blk(7)])
    assert t.put_park(8, [_blk(8)])
    br.record_failure()                       # threshold=1: trips open
    assert br.state == "open"
    assert t.drop_park(7)                     # open: drop still runs
    assert t.arena.bytes_resident == BLK_BYTES
    br.state = "half_open"                    # mid-probe, verdict pending
    assert t.drop_park(8)                     # half_open: drop still runs
    assert br.state == "half_open"            # hygiene is not the probe
    assert t.arena.bytes_resident == 0
    # the actual probe (a verified get path) re-closes the breaker
    assert t.put_kv(0, 11, [_blk(1)])
    assert t.get_kv(0, 11) is not None
    assert br.state == "closed"
