"""Host cache tier behind the serving engine (DESIGN.md §13).

Acceptance net: every tiered path — spill-and-restage of evicted prefix
blocks, arena-parked preemption payloads with dedup'd prompt blocks, and
recurrent-state snapshot reuse — must emit tokens bitwise-equal to solo
``PredictiveSampler.generate`` runs, while the tier's counters prove the
host round-trips actually happened."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine
from repro.serving.blocks import BlockManager
from repro.serving.faults import FaultPlan

EPS_KEY = jax.random.PRNGKey(9)

# The CI chaos job (DESIGN.md §14) re-runs this net under REPRO_FAULT_PLAN:
# injected arena put-rejections / read-corruption / staging drops
# legitimately eat the tier's CAPACITY advantage (spills lost, snapshots
# recomputed, staged runs truncated), so counter asserts that prove the
# tier paid off only run fault-free. Every bitwise exactness assert runs
# regardless — faults must never cost correctness.
FAULT_FREE = FaultPlan.from_env() is None


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(jnp.asarray(np.asarray(req.prompt)[None], jnp.int32),
                      req.new_tokens,
                      seq_ids=jnp.asarray([req.seq_id], jnp.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _assert_all_exact(cfg, params, done, window, max_len):
    assert done, "no requests completed"
    for req in done:
        np.testing.assert_array_equal(
            req.result, _solo(cfg, params, req, window, max_len),
            err_msg=f"request {req.uid} diverged from its solo run")


def test_blocks_dropped_vs_spilled_accounting():
    """Evictions split into saved-to-host (spilled) vs lost (dropped) —
    the tier's effectiveness is unreadable if the two share a counter."""
    mgr = BlockManager(num_blocks=4, block_size=4)      # 3 usable + sink
    saved = []
    mgr.spill_hook = lambda b, key: saved.append(key) or key % 2 == 0
    for i, b in enumerate(mgr.alloc(3)):
        mgr.register(b, 100 + i)
    mgr.release_all(range(1, 4))                        # all cached-free
    mgr.alloc(3)                         # evicts 100 (saved), 101, 102
    st = mgr.stats.export()
    assert saved == [100, 101, 102]
    assert st["blocks_spilled"] == 2     # keys 100, 102 (hook said True)
    assert st["blocks_dropped"] == 1     # key 101 declined by the hook
    assert st["evictions"] == 3


def test_spilled_prefix_blocks_restage_from_host(qwen):
    """Device pool too small to keep a prefix cached across interleaved
    traffic: eviction spills the blocks D2H; a later same-prefix request
    misses on device, hits the host tier, and H2D-stages the run back —
    skipping that prefill — with bitwise-identical tokens."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False, num_blocks=8)
    rng = np.random.default_rng(11)
    pre_a = rng.integers(0, cfg.vocab, 8)
    pre_b = rng.integers(0, cfg.vocab, 9)
    reqs = [
        Request(uid=0, prompt=np.concatenate([pre_a, [3]]), new_tokens=8),
        Request(uid=1, prompt=pre_b, new_tokens=15),   # worst case fills
        #                                                the 7-block pool,
        #                                                evicting A's blocks
        Request(uid=2, prompt=np.concatenate([pre_a, [5]]), new_tokens=8),
    ]

    eng = ServingEngine(cfg, params, **kw)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    m = eng.export_metrics()
    if FAULT_FREE:
        assert m["blocks_spilled"] >= 2      # A's 2 full blocks went D2H
        assert m["host_hits"] >= 1
        assert m["host_staged_blocks"] >= 1  # ...and came back
        assert reqs[2].prefix_hit_blocks >= 1
    _assert_all_exact(cfg, params, done, window=4, max_len=48)

    # A/B vs a tier-less engine on identical traffic: the tier must
    # strictly reduce prefill work (the re-admitted blocks are not recomputed)
    eng_nt = ServingEngine(cfg, params, **kw, host_cache_mb=0)
    assert eng_nt.tier is None
    for r in reqs:
        eng_nt.submit(Request(uid=r.uid, prompt=r.prompt,
                              new_tokens=r.new_tokens))
    eng_nt.run()
    m_nt = eng_nt.export_metrics()
    assert m_nt["blocks_dropped"] >= 2       # same evictions, nothing saved
    if FAULT_FREE:
        assert m["prefill_calls"] < m_nt["prefill_calls"]


def test_parked_payload_dedup_counts_arena_bytes(qwen):
    """Two victims sharing a prompt park into the arena: the shared
    prompt-hash blocks are stored ONCE (second park pins, not copies), so
    the second park adds exactly one arena entry (its private payload)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab, 13)          # 3 full shared blocks
    r0 = Request(uid=0, prompt=prompt, new_tokens=24)
    r1 = Request(uid=1, prompt=prompt, new_tokens=24)
    eng.submit(r0)
    eng.submit(r1)
    eng.step()
    assert all(s is not None for s in eng.slots)
    arena = eng.tier.arena
    n0, b0 = len(arena), arena.bytes_resident
    eng.preempt_slot(0)
    n1, b1 = len(arena), arena.bytes_resident
    eng.preempt_slot(1)
    n2, b2 = len(arena), arena.bytes_resident
    if FAULT_FREE:
        assert n1 - n0 >= 4        # 3 shared KV blocks + 1 park payload
        assert n2 - n1 == 1        # dedup: ONLY the park payload is new
        assert b2 - b1 < b1 - b0   # second park is strictly cheaper
    done = eng.run()
    assert eng.metrics.preemptions == 2 and eng.metrics.resumes == 2
    assert len(done) == 2
    _assert_all_exact(cfg, params, done, window=4, max_len=64)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "jamba-1.5-large-398b"])
def test_recurrent_prefix_reuse_via_snapshots(arch):
    """Recurrent archs get prefix hits for the first time: a shared system
    prompt's boundary snapshots are captured on the cold run and restored
    on the warm one (host_hits > 0), with tokens bitwise-equal to a cold
    engine and to solo. rwkv6 = pure recurrent (no KV at all); jamba =
    attention+mamba hybrid (KV blocks and the ssm state row must agree on
    the restore boundary)."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=1, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    rng = np.random.default_rng(13)
    system = rng.integers(0, cfg.vocab, 13)          # 3 snapshot boundaries
    r0 = Request(uid=0, prompt=system, new_tokens=8)
    r1 = Request(uid=1, prompt=np.concatenate([system, [7, 2]]),
                 new_tokens=8)

    eng = ServingEngine(cfg, params, **kw)
    assert eng.rec_prefix and not eng.kv_prefix
    eng.submit(r0)
    eng.run()
    if FAULT_FREE:
        assert eng.metrics.rec_snapshot_captures >= 3  # boundaries 4, 8, 12
    eng.submit(r1)
    done = eng.run()
    m = eng.export_metrics()
    if FAULT_FREE:
        assert eng.metrics.rec_snapshot_restores >= 1
        assert m["host_hits"] > 0
        assert r1.prefix_hit_blocks >= 3             # full shared prefix
    _assert_all_exact(cfg, params, [r0] + done, window=4, max_len=48)

    # warm-path tokens must match a cold engine serving the same request
    cold = ServingEngine(cfg, params, **kw)
    rc = Request(uid=1, prompt=r1.prompt, new_tokens=8)
    cold.submit(rc)
    cold.run()
    assert cold.metrics.rec_snapshot_restores == 0
    np.testing.assert_array_equal(r1.result, rc.result)


def _interleaved_tiered(cfg, params, plan, batch=2, max_len=64, **extra):
    """Admit/step/preempt/migrate interleavings over a deliberately tiny
    device pool (evictions -> spills on nearly every admission) and an
    engine-default tier budget (shrinkable via REPRO_HOST_CACHE_MB)."""
    eng = ServingEngine(cfg, params, batch=batch, window_max=4,
                        max_len=max_len, eps_key=EPS_KEY, block_size=4,
                        adaptive=False, num_blocks=12, **extra)
    uid = 0
    for op, arg in plan:
        if op == "submit":
            L_p, new = arg
            rng = np.random.default_rng(100 + uid)
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L_p),
                               new_tokens=new))
            uid += 1
        elif op == "step":
            if eng.queue or any(s is not None for s in eng.slots):
                eng.step()
        elif op == "preempt":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            if occ:
                eng.preempt_slot(occ[arg % len(occ)])
        elif op == "migrate":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            free = [b for b in range(batch) if eng.slots[b] is None]
            if occ and free:
                eng.migrate_slot(occ[arg % len(occ)],
                                 free[arg % len(free)])
    done = eng.run()
    assert len(done) == uid
    for req in done:
        np.testing.assert_array_equal(
            req.result, _solo(cfg, params, req, 4, max_len),
            err_msg=f"request {req.uid} diverged from its solo run")
    return eng


PLAN = [("submit", (3, 8)), ("submit", (9, 6)), ("step", None),
        ("preempt", 0), ("submit", (9, 10)), ("step", None),
        ("migrate", 1), ("step", None), ("submit", (7, 5)),
        ("preempt", 1), ("step", None), ("submit", (3, 6)),
        ("preempt", 0), ("migrate", 0)]


def test_interleaved_tiered_schedule_exact(qwen):
    """Deterministic always-run form: slot churn + arena parks + spills +
    resumes over the tiny pool stay bitwise-exact."""
    cfg, params = qwen
    eng = _interleaved_tiered(cfg, params, PLAN)
    assert eng.metrics.preemptions >= 1
    m = eng.export_metrics()
    if FAULT_FREE:
        assert m["host_puts"] >= 1       # the tier actually saw traffic


def test_interleaved_tiered_tiny_budget_exact(qwen):
    """Same schedule under a ~30 KiB arena: rejections and forced arena
    evictions (parks fall back to raw payloads, spills drop) must degrade
    capacity only — never correctness."""
    cfg, params = qwen
    eng = _interleaved_tiered(cfg, params, PLAN, host_cache_mb=0.03)
    assert eng.tier is not None
    assert eng.tier.arena.capacity_bytes < 64 * 1024


def test_interleaved_tiered_schedules_hypothesis(qwen):
    """Property form: random interleavings of admit/step/preempt/migrate
    over the tiny tiered pool stay bitwise-equal to solo generate."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = qwen

    op = st.one_of(
        st.tuples(st.just("submit"),
                  st.tuples(st.integers(1, 9), st.integers(2, 8))),
        st.tuples(st.just("step"), st.none()),
        st.tuples(st.just("preempt"), st.integers(0, 3)),
        st.tuples(st.just("migrate"), st.integers(0, 3)),
    )

    @hyp.settings(max_examples=6, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(op, min_size=2, max_size=8))
    def run_plan(plan):
        if not any(p[0] == "submit" for p in plan):
            plan = [("submit", (2, 4))] + plan
        _interleaved_tiered(cfg, params, plan)

    run_plan()


def test_serve_help_lists_host_cache_flags(capsys):
    from repro.launch import serve
    with pytest.raises(SystemExit) as exc:
        serve.main(["--help"])
    assert exc.value.code == 0
    out = capsys.readouterr().out
    assert "--host-cache-mb" in out
    assert "--no-host-cache" in out


def test_queued_request_prefetches_spilled_prefix(qwen):
    """§15 prefetch satellite: while a request WAITS in the queue (slot
    occupied, no headroom to stage it), its host-resident prefix blocks are
    pushed through the async staging ring ahead of time; admission then
    merges the already-device-resident copies (``prefetch_hits``) instead
    of paying the host pull + H2D wait inline. Tokens stay bitwise equal
    to solo runs."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False, num_blocks=8,
              staging_slots=1)                  # prefetch defaults on
    rng = np.random.default_rng(11)
    pre_a = rng.integers(0, cfg.vocab, 8)
    pre_b = rng.integers(0, cfg.vocab, 9)

    eng = ServingEngine(cfg, params, **kw)
    eng.submit(Request(uid=0, prompt=np.concatenate([pre_a, [3]]),
                       new_tokens=8))
    eng.run()                       # publishes A's 2 full prefix blocks
    # worst-case filler: reserves the whole 7-block pool up front, so A's
    # cached-free blocks are evicted (spilled D2H) on its FIRST dispatch
    # and the follow-up request below can be neither admitted nor staged
    eng.submit(Request(uid=1, prompt=pre_b, new_tokens=15))
    eng.step()
    late = Request(uid=2, prompt=np.concatenate([pre_a, [5]]), new_tokens=8)
    eng.submit(late)
    for _ in range(4):              # queued steps: prefetch window
        eng.step()
    if FAULT_FREE:
        assert late.uid in eng._prefetched or eng.metrics.prefetch_hits >= 1
    done = eng.run()
    m = eng.export_metrics()
    if FAULT_FREE:
        assert m["blocks_spilled"] >= 2
        assert m["prefetch_hits"] >= 1
        assert late.prefix_hit_blocks >= 1
    assert not eng._prefetched      # claimed at admission, never leaked
    _assert_all_exact(cfg, params, done, window=4, max_len=48)
