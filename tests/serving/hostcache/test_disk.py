"""Disk-tier units (DESIGN.md §16): frame codec, byte budget + LRU,
atomic-visibility discipline, crc-verified reads, restart index rebuild,
orphan sweep, breaker isolation, and the three disk fault seams."""
import os

import numpy as np

from repro.serving import DiskTier, FaultPlan
from repro.serving.faults import CircuitBreaker
from repro.serving.hostcache import durable_name
from repro.serving.hostcache.disk import decode_entry, encode_entry


def _blk(fill, shape=(4, 8), dtype=np.float32):
    return np.full(shape, fill, dtype)


def test_encode_decode_roundtrip():
    arrays = [_blk(3), np.arange(7, dtype=np.int64),
              np.zeros((0,), np.float16), np.ones((2, 1, 3), np.int32)]
    out = decode_entry(encode_entry(arrays))
    assert out is not None and len(out) == len(arrays)
    for a, b in zip(arrays, out):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_decode_rejects_any_inconsistency():
    frame = encode_entry([_blk(1)])
    assert decode_entry(frame[:-1]) is None          # truncated payload
    assert decode_entry(frame[: len(frame) // 2]) is None
    assert decode_entry(b"") is None
    assert decode_entry(frame + b"x") is None        # trailing garbage
    bad = bytearray(frame)
    bad[-1] ^= 0xFF                                  # bit rot
    assert decode_entry(bytes(bad)) is None
    bad = bytearray(frame)
    bad[0] ^= 0xFF                                   # wrong magic
    assert decode_entry(bytes(bad)) is None


def test_durable_name_is_process_stable_and_fixed_width():
    assert durable_name("kv", 0, 0x1234) == "kv_0_0000000000001234.blk"
    # negative hashes (Python tuple hashes are signed) mask cleanly
    n = durable_name("rec", 3, -1)
    assert n == "rec_3_ffffffffffffffff.blk" and n.endswith(".blk")


def test_put_get_and_budget_lru(tmp_path):
    frame_len = len(encode_entry([_blk(0)]))
    d = DiskTier(str(tmp_path), capacity_bytes=3 * frame_len)
    for i in range(5):
        assert d.put(durable_name("kv", 0, i), [_blk(i)])
    assert len(d) == 3 and d.stats.evictions == 2
    assert d.bytes_resident <= d.capacity_bytes
    # oldest two evicted, newest three readable and verified
    assert d.get(durable_name("kv", 0, 0)) is None
    got = d.get(durable_name("kv", 0, 4))
    np.testing.assert_array_equal(got[0], _blk(4))
    # an entry that can never fit is refused, not partially admitted
    assert not d.put(durable_name("kv", 0, 99), [_blk(0, shape=(64, 64))])
    assert d.stats.rejections == 1
    # dedup put: no second file, recency refreshed
    assert d.put(durable_name("kv", 0, 2), [_blk(2)])
    assert d.stats.dedup_hits == 1 and len(d) == 3


def test_index_rebuild_after_restart(tmp_path):
    d = DiskTier(str(tmp_path))
    for i in range(3):
        d.put(durable_name("kv", 0, i), [_blk(i)])
    resident = d.bytes_resident
    # a new process over the same directory sees every entry, verified
    d2 = DiskTier(str(tmp_path))
    assert len(d2) == 3 and d2.bytes_resident == resident
    for i in range(3):
        np.testing.assert_array_equal(
            d2.get(durable_name("kv", 0, i))[0], _blk(i))


def test_orphan_tmp_swept_at_startup(tmp_path):
    d = DiskTier(str(tmp_path))
    d.put(durable_name("kv", 0, 1), [_blk(1)])
    orphan = os.path.join(str(tmp_path), "kv_0_dead.blk.tmp")
    with open(orphan, "wb") as f:
        f.write(b"half a frame")               # crash between write and rename
    d2 = DiskTier(str(tmp_path))
    assert not os.path.exists(orphan)
    assert d2.stats.orphans_swept == 1
    assert len(d2) == 1                        # the real entry survived


def test_torn_write_seam_demotes_to_miss(tmp_path):
    plan = FaultPlan.parse("disk_torn_write=@0")
    d = DiskTier(str(tmp_path), faults=plan)
    assert d.put(durable_name("kv", 0, 7), [_blk(7)])   # write "succeeds"
    assert plan.fired["disk_torn_write"] == 1
    # the crc verify catches the tear, deletes the file, reports a miss
    assert d.get(durable_name("kv", 0, 7)) is None
    assert d.stats.checksum_failures == 1
    assert not d.contains(durable_name("kv", 0, 7))
    assert len(d) == 0


def test_disk_full_seam_counts_breaker_failures(tmp_path):
    plan = FaultPlan.parse("disk_full=@0;1;2")
    br = CircuitBreaker(threshold=3, cooldown=4)
    d = DiskTier(str(tmp_path), faults=plan, breaker=br)
    for i in range(3):
        assert not d.put(durable_name("kv", 0, i), [_blk(i)])
    assert d.stats.rejections == 3
    assert br.state == "open"                  # 3 consecutive ENOSPC: tripped
    # open tier: probes miss, puts refuse, never an exception
    assert not d.contains(durable_name("kv", 0, 0))
    assert not d.put(durable_name("kv", 0, 9), [_blk(9)])
    assert d.get(durable_name("kv", 0, 9)) is None
    # past the cooldown the half-open probe succeeds and re-closes
    for i in range(10, 16):
        if d.put(durable_name("kv", 0, i), [_blk(i)]):
            break
    assert br.state == "closed"
    st = d.stats_export()
    assert st["disk_state"] == "closed" and st["disk_tripped"] == 1
    assert st["disk_denied_ops"] > 0


def test_disk_slow_seam_is_latency_only(tmp_path):
    plan = FaultPlan.parse("disk_slow=1.0")
    d = DiskTier(str(tmp_path), faults=plan)
    d.put(durable_name("kv", 0, 1), [_blk(1)])
    got = d.get(durable_name("kv", 0, 1))      # stalls, then verifies fine
    np.testing.assert_array_equal(got[0], _blk(1))
    assert plan.fired["disk_slow"] == 1
    assert d.stats.checksum_failures == 0


def test_drop_is_never_breaker_gated(tmp_path):
    d = DiskTier(str(tmp_path))
    d.put(durable_name("kv", 0, 1), [_blk(1)])
    d.breaker.state = "open"
    d.breaker._cooldown_left = 100
    assert d.drop(durable_name("kv", 0, 1))    # hygiene runs while tripped
    assert len(d) == 0
    assert not d.drop(durable_name("kv", 0, 1))


def test_oserror_put_degrades_not_raises(tmp_path):
    d = DiskTier(str(tmp_path))
    os.chmod(str(tmp_path), 0o500)             # directory not writable
    try:
        if os.geteuid() == 0:                  # root ignores mode bits
            return
        assert not d.put(durable_name("kv", 0, 1), [_blk(1)])
        assert d.stats.rejections == 1
        assert d.breaker.failures == 1
    finally:
        os.chmod(str(tmp_path), 0o700)
