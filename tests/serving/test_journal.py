"""Write-ahead request journal units (DESIGN.md §16): crc framing, batched
fsync discipline, torn-tail repair on replay, the ``journal_truncate``
fault seam, and the ``pending()`` lifecycle fold."""
import os
import struct
import zlib

from repro.serving import FaultPlan, RequestJournal


def _path(tmp_path):
    return str(tmp_path / "journal.wal")


def test_append_replay_roundtrip(tmp_path):
    p = _path(tmp_path)
    j = RequestJournal(p)
    j.append("submit", uid=1, prompt=[1, 2, 3], new_tokens=4,
             priority=0, deadline=None, noise_seed=None, rank=0)
    j.append("admit", uid=1)
    j.append("finish", uid=1)
    j.close()
    recs = RequestJournal.replay(p)
    assert [r["type"] for r in recs] == ["submit", "admit", "finish"]
    assert recs[0]["prompt"] == [1, 2, 3]
    assert recs[0]["rank"] == 0


def test_replay_missing_file_is_empty():
    assert RequestJournal.replay("/nonexistent/journal.wal") == []


def test_fsync_batching_counts(tmp_path):
    j = RequestJournal(_path(tmp_path), fsync_every=3)
    for i in range(7):
        j.append("submit", uid=i, prompt=[i], new_tokens=1,
                 priority=0, deadline=None, noise_seed=None, rank=i)
    # 7 appends at fsync_every=3: syncs after records 3 and 6, one pending
    assert j.syncs == 2
    st = j.stats_export()
    assert st["journal_appends"] == 7 and st["journal_unsynced"] == 1
    j.sync()
    assert j.stats_export()["journal_unsynced"] == 0
    j.close()


def test_torn_tail_truncated_and_repaired(tmp_path):
    p = _path(tmp_path)
    j = RequestJournal(p)
    for i in range(3):
        j.append("submit", uid=i, prompt=[i], new_tokens=1,
                 priority=0, deadline=None, noise_seed=None, rank=i)
    j.close()
    good_size = os.path.getsize(p)
    # crash mid-append: half a frame header plus garbage past the tail
    with open(p, "ab") as f:
        f.write(struct.pack("<II", 1 << 20, 0xDEAD)[:6])
    recs = RequestJournal.replay(p)
    assert len(recs) == 3                    # torn frame never surfaces
    assert os.path.getsize(p) == good_size   # file truncated to last good
    # truncation is idempotent and the journal reopens cleanly for append
    assert len(RequestJournal.replay(p)) == 3
    j2 = RequestJournal(p)
    j2.append("finish", uid=0)
    j2.close()
    assert [r["type"] for r in RequestJournal.replay(p)][-1] == "finish"


def test_crc_corruption_stops_replay_at_boundary(tmp_path):
    p = _path(tmp_path)
    j = RequestJournal(p)
    for i in range(4):
        j.append("submit", uid=i, prompt=[i], new_tokens=1,
                 priority=0, deadline=None, noise_seed=None, rank=i)
    j.close()
    recs = RequestJournal.replay(p)
    assert len(recs) == 4
    # flip one payload byte of the THIRD record: replay keeps only 2
    with open(p, "rb") as f:
        buf = f.read()
    off = 0
    for _ in range(2):
        (plen,) = struct.unpack_from("<I", buf, off)
        off += 8 + plen
    bad = bytearray(buf)
    bad[off + 8] ^= 0xFF
    with open(p, "wb") as f:
        f.write(bad)
    recs = RequestJournal.replay(p)
    assert [r["uid"] for r in recs] == [0, 1]


def test_journal_truncate_seam_drops_last_record(tmp_path):
    p = _path(tmp_path)
    j = RequestJournal(p)
    for i in range(3):
        j.append("submit", uid=i, prompt=[i], new_tokens=1,
                 priority=0, deadline=None, noise_seed=None, rank=i)
    j.close()
    plan = FaultPlan.parse("journal_truncate=@0")
    recs = RequestJournal.replay(p, faults=plan)
    assert [r["uid"] for r in recs] == [0, 1]
    assert plan.fired["journal_truncate"] == 1
    # the tear persisted: a faultless replay sees the truncated file
    assert [r["uid"] for r in RequestJournal.replay(p)] == [0, 1]


def test_frame_encoding_is_crc_checked(tmp_path):
    p = _path(tmp_path)
    j = RequestJournal(p)
    j.append("submit", uid=9, prompt=[7], new_tokens=1,
             priority=0, deadline=None, noise_seed=None, rank=0)
    j.close()
    with open(p, "rb") as f:
        buf = f.read()
    plen, crc = struct.unpack_from("<II", buf)
    payload = buf[8:8 + plen]
    assert zlib.crc32(payload) == crc
    assert b'"type":"submit"' in payload


def test_pending_folds_lifecycle():
    recs = [
        {"type": "submit", "uid": 1, "noise_seed": None, "retries": 0},
        {"type": "submit", "uid": 2, "noise_seed": None},
        {"type": "submit", "uid": 3, "noise_seed": None},
        {"type": "admit", "uid": 1},
        {"type": "finish", "uid": 1, "tokens": [4, 2]},   # terminal
        {"type": "admit", "uid": 2},
        {"type": "park", "uid": 2},              # pending + parked
        {"type": "retry", "uid": 3, "noise_seed": 77, "retries": 1},
        {"type": "admit", "uid": 99},            # alien uid: skipped
    ]
    pending, parked, delivered = RequestJournal.pending(recs)
    assert set(pending) == {2, 3}
    assert pending[2]["parked"] and pending[2]["admitted"]
    assert set(parked) == {2}
    # retry folded identity: re-admission must use the retry noise stream
    assert pending[3]["noise_seed"] == 77 and pending[3]["retries"] == 1
    assert not pending[3]["admitted"]
    # terminal outcome folded for re-delivery: tokens travel in the record
    assert set(delivered) == {1}
    assert delivered[1]["terminal"] == "finish"
    assert delivered[1]["tokens"] == [4, 2]


def test_pending_admit_clears_parked():
    recs = [
        {"type": "submit", "uid": 5},
        {"type": "park", "uid": 5},
        {"type": "admit", "uid": 5},             # resumed before the crash
    ]
    pending, parked, _ = RequestJournal.pending(recs)
    assert pending[5]["admitted"] and not pending[5]["parked"]
    assert parked == {}


def test_pending_terminal_clears_parked():
    recs = [
        {"type": "submit", "uid": 6},
        {"type": "park", "uid": 6},
        {"type": "cancel", "uid": 6},
    ]
    pending, parked, delivered = RequestJournal.pending(recs)
    assert pending == {} and parked == {}
    assert delivered[6]["terminal"] == "cancel"
