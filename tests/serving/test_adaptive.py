"""AdaptiveWindowController: widens on saturated acceptance, narrows toward
ancestral on accept~1 streams, stays in [1, w_max] on the pow2 grid."""
import numpy as np

from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.admission import prefill_chunks


def test_widens_on_saturated_acceptance():
    c = AdaptiveWindowController(w_max=16, w_init=2)
    for _ in range(10):
        c.observe(np.full(4, c.window))     # window always fully accepted
    assert c.window == 16


def test_narrows_to_near_ancestral_on_hard_stream():
    c = AdaptiveWindowController(w_max=16)
    assert c.window == 16                   # optimistic start
    for _ in range(20):
        c.observe(np.ones(4))               # accept length 1 every round
    assert c.window <= 2                    # degraded to ~ancestral cost


def test_bounds_and_grid():
    c = AdaptiveWindowController(w_max=12, w_init=5)
    seen = set()
    rng = np.random.default_rng(0)
    for _ in range(50):
        seen.add(c.observe(rng.uniform(1, 12, size=3)))
    assert all(1 <= w <= 12 for w in seen)
    for w in seen:
        assert w == 12 or (w & (w - 1)) == 0   # pow2 grid + w_max rung


def test_saturating_acceptance_reaches_non_pow2_w_max():
    """The top rung is w_max itself even when it is not a power of two."""
    c = AdaptiveWindowController(w_max=12, w_init=4, headroom=1.7)
    for _ in range(10):
        c.observe(np.full(4, c.window))     # window always fully accepted
    assert c.window == 12


def test_disabled_controller_pins_window():
    c = AdaptiveWindowController(w_max=8, w_init=8, enabled=False)
    for _ in range(5):
        c.observe(np.ones(2))
    assert c.window == 8


def test_hysteresis_resists_single_round_noise():
    c = AdaptiveWindowController(w_max=16, w_init=16, patience=2)
    w0 = c.window
    c.observe(np.ones(4))                   # one bad round
    assert c.window == w0                   # needs `patience` agreement


def test_prefill_chunks_cover_exactly():
    for n in range(0, 200):
        chunks = prefill_chunks(n, 64)
        assert sum(chunks) == n
        assert all(c & (c - 1) == 0 for c in chunks)
    assert prefill_chunks(0) == []
    assert len(set(prefill_chunks(199, 64))) <= 7   # bounded compile shapes
