"""AdaptiveWindowController: widens on saturated acceptance, narrows toward
ancestral on accept~1 streams, stays in [1, w_max] on the pow2 grid."""
import numpy as np

from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.admission import prefill_chunks


def test_widens_on_saturated_acceptance():
    c = AdaptiveWindowController(w_max=16, w_init=2)
    for _ in range(10):
        c.observe(np.full(4, c.window))     # window always fully accepted
    assert c.window == 16


def test_narrows_to_near_ancestral_on_hard_stream():
    c = AdaptiveWindowController(w_max=16)
    assert c.window == 16                   # optimistic start
    for _ in range(20):
        c.observe(np.ones(4))               # accept length 1 every round
    assert c.window <= 2                    # degraded to ~ancestral cost


def test_bounds_and_grid():
    c = AdaptiveWindowController(w_max=12, w_init=5)
    seen = set()
    rng = np.random.default_rng(0)
    for _ in range(50):
        seen.add(c.observe(rng.uniform(1, 12, size=3)))
    assert all(1 <= w <= 12 for w in seen)
    for w in seen:
        assert w == 12 or (w & (w - 1)) == 0   # pow2 grid + w_max rung


def test_saturating_acceptance_reaches_non_pow2_w_max():
    """The top rung is w_max itself even when it is not a power of two."""
    c = AdaptiveWindowController(w_max=12, w_init=4, headroom=1.7)
    for _ in range(10):
        c.observe(np.full(4, c.window))     # window always fully accepted
    assert c.window == 12


def test_disabled_controller_pins_window():
    c = AdaptiveWindowController(w_max=8, w_init=8, enabled=False)
    for _ in range(5):
        c.observe(np.ones(2))
    assert c.window == 8


def test_hysteresis_resists_single_round_noise():
    c = AdaptiveWindowController(w_max=16, w_init=16, patience=2)
    w0 = c.window
    c.observe(np.ones(4))                   # one bad round
    assert c.window == w0                   # needs `patience` agreement


def test_prefill_chunks_cover_exactly():
    for n in range(0, 200):
        chunks = prefill_chunks(n, 64)
        assert sum(chunks) == n
        assert all(c & (c - 1) == 0 for c in chunks)
    assert prefill_chunks(0) == []
    assert len(set(prefill_chunks(199, 64))) <= 7   # bounded compile shapes


def test_window_history_is_bounded():
    """A long-lived server syncs millions of times; the telemetry ring
    must not leak (§15 satellite: history capped)."""
    c = AdaptiveWindowController(w_max=8, history_cap=16)
    for _ in range(100):
        c.observe(np.ones(2))
    assert len(c.history) == 16


def test_rounds_ctrl_grows_on_full_quiet_loops_with_backlog():
    from repro.serving.adaptive import RoundsPerSyncController

    c = RoundsPerSyncController(k_max=8)
    assert c.k == 1                          # sync-heavy start: observe first
    for _ in range(12):
        c.observe(loop_rounds=c.k, idle_row_rounds=0, rows=4, backlog=6)
    assert c.k == 8


def test_rounds_ctrl_backlog_gate_blocks_growth():
    """Without backlog there is nothing for a freed row to adopt, so a
    longer loop buys no refill — k must hold."""
    from repro.serving.adaptive import RoundsPerSyncController

    c = RoundsPerSyncController(k_max=8)
    for _ in range(12):
        c.observe(loop_rounds=c.k, idle_row_rounds=0, rows=4, backlog=0)
    assert c.k == 1


def test_rounds_ctrl_shrinks_on_idle_and_holds_floor():
    from repro.serving.adaptive import RoundsPerSyncController

    c = RoundsPerSyncController(k_max=8, k_init=8)
    assert c.k == 8
    for _ in range(20):                      # half of every loop idle
        c.observe(loop_rounds=c.k, idle_row_rounds=2 * c.k, rows=4,
                  backlog=6)
    assert c.k == 1                          # floor, never 0


def test_rounds_ctrl_hysteresis_resists_single_loop_noise():
    from repro.serving.adaptive import RoundsPerSyncController

    c = RoundsPerSyncController(k_max=8, k_init=4, patience=2)
    c.observe(loop_rounds=4, idle_row_rounds=16, rows=4, backlog=6)
    assert c.k == 4                          # one bad loop: no move yet


def test_rounds_ctrl_pow2_grid_and_bounds():
    from repro.serving.adaptive import RoundsPerSyncController

    rng = np.random.default_rng(0)
    c = RoundsPerSyncController(k_max=8)
    seen = set()
    for _ in range(60):
        seen.add(c.observe(loop_rounds=c.k,
                           idle_row_rounds=int(rng.integers(0, 3 * c.k)),
                           rows=4, backlog=int(rng.integers(0, 4))))
    assert all(1 <= k <= 8 and (k & (k - 1)) == 0 for k in seen)
    assert len(c.history) <= c.history_cap


def test_rounds_ctrl_disabled_pins_k():
    from repro.serving.adaptive import RoundsPerSyncController

    c = RoundsPerSyncController(k_max=8, k_init=4, enabled=False)
    for _ in range(10):
        c.observe(loop_rounds=4, idle_row_rounds=0, rows=4, backlog=9)
    assert c.k == 4


def test_metrics_per_token_guard_and_occupancy_splits():
    """Exports divide by tokens_generated in exactly one place; a server
    exporting right after boot must see 0.0, not ZeroDivisionError. The
    duration-weighted and under-backlog occupancies aggregate row-rounds,
    unlike the per-loop mean (which weights a 1-round loop equally with an
    8-round one)."""
    import pytest

    from repro.serving.metrics import EngineMetrics

    m = EngineMetrics()
    out = m.export()
    assert out["syncs_per_token"] == 0.0
    assert out["dispatches_per_token"] == 0.0
    assert out["rounds_per_token"] == 0.0
    assert out["occupancy_weighted"] == 0.0
    assert out["occupancy_under_backlog"] == 0.0

    # loop A: 1 round, 4/4 rows active, dispatched with backlog
    m.observe_loop(window=4, rounds=1, active_row_rounds=4, batch=4,
                   accepted=4, backlog=3)
    # loop B: 8 rounds, half the row-rounds active, no backlog (drain tail)
    m.observe_loop(window=4, rounds=8, active_row_rounds=16, batch=4,
                   accepted=20, backlog=0)
    out = m.export()
    assert out["mean_batch_occupancy"] == pytest.approx((1.0 + 0.5) / 2)
    assert out["occupancy_weighted"] == pytest.approx(20 / 36)
    assert out["occupancy_under_backlog"] == pytest.approx(1.0)
    assert out["syncs_per_token"] == pytest.approx(2 / 24)
