"""Mesh-sharded ServingEngine system tests (DESIGN.md §10).

Each test runs in a subprocess with 8 forced host devices (the main test
process keeps its single-device view — same pattern as
tests/sharding/test_moe_shard.py). The acceptance bar is the topology
exactness contract: a ``data>=2`` engine must emit tokens bit-identical to
the single-device engine AND to per-request solo ``PredictiveSampler``
runs, with ZERO cross-shard collectives on the verify-round hot path
(asserted on the compiled HLO) — block-table indirection is shard-local by
construction. The device-resident round loop must hold the same contract:
each shard's ``lax.while_loop`` stops on its OWN rows (no collective in the
stop condition), the fused round's jaxpr carries no pool-ranked scatter
(every pool write is a pallas aliased epilogue), and a ``rounds_per_sync=4``
mesh engine emits the same tokens as the host-driven one.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def _run(script: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=_ROOT)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


MAIN_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.configs import get_config
    from repro.engine import PredictiveSampler
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    EPS = jax.random.PRNGKey(9)
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS,
              block_size=4, adaptive=False)

    def traffic(eng):
        rng = np.random.default_rng(3)
        for i in range(6):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 9))),
                new_tokens=int(rng.integers(4, 9))))
        return {r.uid: r.result for r in eng.run()}

    ref = traffic(ServingEngine(cfg, params, **kw))
    rec = {"equal": {}, "solo_equal": True}

    # solo per-request references (exactness vs PredictiveSampler.generate)
    solo = PredictiveSampler(cfg, params, window=4, max_len=48, eps_key=EPS)
    rng = np.random.default_rng(3)
    for i in range(6):
        p = rng.integers(0, cfg.vocab, size=int(rng.integers(2, 9)))
        nt = int(rng.integers(4, 9))
        t, _ = solo.generate(np.asarray(p)[None].astype(np.int32), nt,
                             seq_ids=np.asarray([i], np.int32))
        if not (np.asarray(t[0, :len(p) + nt]) == ref[i]).all():
            rec["solo_equal"] = False

    # device-resident loop under the mesh: rounds_per_sync=4 (default) and
    # the host-driven rounds_per_sync=1 engine must all match the
    # single-device reference bit-for-bit at data=2 and data=4
    rec["loop_amortized"] = {}
    for data in (2, 4):
        for rps in (4, 1):
            topo = ServingTopology(make_host_mesh(data, 1))
            eng_m = ServingEngine(cfg, params, topology=topo,
                                  rounds_per_sync=rps, **kw)
            got = traffic(eng_m)
            rec["equal"][f"{data}x{rps}"] = all(
                (got[uid] == ref[uid]).all() for uid in ref)
            if rps == 4:
                rec["loop_amortized"][str(data)] = (
                    eng_m.metrics.rounds > eng_m.metrics.host_syncs)

    # pool-pressure routing: with empty equal sub-pools the first admission
    # ties to shard 0, the second must go to the emptier shard 1 (requests
    # long enough that one k-round device loop cannot finish them — the
    # routed slots must still be occupied at the sync)
    topo = ServingTopology(make_host_mesh(2, 1))
    eng = ServingEngine(cfg, params, topology=topo, **kw)
    rng = np.random.default_rng(5)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 4),
                           new_tokens=40))
    eng.step()
    occupied = [b for b in range(4) if eng.slots[b] is not None]
    rec["routed_slots"] = occupied
    bl = eng.B // topo.data_size
    rec["routing_spread"] = (occupied and occupied[0] < bl
                             and any(b >= bl for b in occupied))

    # §17 contract gate on the mesh verify round loop: zero collectives on
    # the hot path (each shard's while_loop stops on its own rows), zero
    # pool-ranked scatter eqns (no standalone window-writeback before the
    # pallas_call — the fused-epilogue gate), donation aliasing established
    from repro.analysis import check_engine_round
    rep = check_engine_round(eng)
    rec["contract_ok"] = rep.ok
    rec["violations"] = [str(v) for v in rep.violations]
    rec["collectives"] = rep.metrics["collectives"]
    rec["pool_scatters"] = rep.metrics["pool_scatters"]
    rec["pallas_calls"] = rep.metrics["pallas_calls"]
    print(json.dumps(rec))
""")


ARCH_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    arch = "__ARCH__"
    EPS = jax.random.PRNGKey(9)
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=32, eps_key=EPS,
              block_size=4, adaptive=False)

    def traffic(eng, disturb=False):
        rng = np.random.default_rng(3)
        for i in range(3):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 7))),
                new_tokens=int(rng.integers(17, 22))))
        eng.step()
        if disturb:
            # force a CROSS-SHARD migration (blocks device-copied between
            # sub-pools, per-slot + recurrent state moved) and a preemption
            # (park + spill + exact resume) mid-flight
            B = eng.B
            occ = [b for b in range(B) if eng.slots[b] is not None]
            free = [b for b in range(B) if eng.slots[b] is None]
            did = False
            for s in occ:
                for d in free:
                    if (eng.topo.shard_of_slot(s, B)
                            != eng.topo.shard_of_slot(d, B)):
                        eng.migrate_slot(s, d)
                        did = True
                        break
                if did:
                    break
            assert did, (occ, free)
            occ = [b for b in range(B) if eng.slots[b] is not None]
            eng.preempt_slot(occ[-1])
        return {r.uid: r.result for r in eng.run()}, eng

    # single-device host-driven reference vs the mesh DEVICE-RESIDENT loop
    # with a forced cross-shard migration AND a forced preemption:
    # equality crosses the sharding, the drive mode, and the scheduler
    ref, _ = traffic(ServingEngine(cfg, params, rounds_per_sync=1, **kw))
    topo = ServingTopology(make_host_mesh(2, 1))
    got, eng_m = traffic(ServingEngine(cfg, params, topology=topo,
                                       rounds_per_sync=4, **kw),
                         disturb=True)
    equal = all((got[uid] == ref[uid]).all() for uid in ref)
    print(json.dumps({"equal": equal,
                      "migrations": eng_m.metrics.migrations,
                      "preemptions": eng_m.metrics.preemptions,
                      "resumes": eng_m.metrics.resumes}))
""")


SCHED_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    EPS = jax.random.PRNGKey(9)
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS,
              block_size=4, adaptive=False)
    rec = {"equal": {}, "forced": {}}

    def traffic(eng, disturb):
        rng = np.random.default_rng(7)
        for i in range(3):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 9))),
                new_tokens=int(rng.integers(17, 22))))
        eng.step()
        if disturb:
            B = eng.B
            occ = [b for b in range(B) if eng.slots[b] is not None]
            free = [b for b in range(B) if eng.slots[b] is None]
            moved = False
            for s in occ:
                for d in free:
                    if (eng.topo.shard_of_slot(s, B)
                            != eng.topo.shard_of_slot(d, B)):
                        eng.migrate_slot(s, d)
                        moved = True
                        break
                if moved:
                    break
            occ = [b for b in range(B) if eng.slots[b] is not None]
            eng.preempt_slot(occ[0])
            eng.preempt_slot(occ[-1])
        return {r.uid: r.result for r in eng.run()}, eng

    ref, _ = traffic(ServingEngine(cfg, params, **kw), False)
    for data in (2, 4):
        topo = ServingTopology(make_host_mesh(data, 1))
        got, eng_m = traffic(ServingEngine(cfg, params, topology=topo, **kw),
                             True)
        rec["equal"][str(data)] = all(
            (got[uid] == ref[uid]).all() for uid in ref)
        rec["forced"][str(data)] = {
            "migrations": eng_m.metrics.migrations,
            "blocks_migrated": eng_m.metrics.blocks_migrated,
            "preemptions": eng_m.metrics.preemptions,
            "resumes": eng_m.metrics.resumes}

    # admission-driven rebalancing: reuse the ONE scenario definition the
    # benchmark publishes (benchmarks/serving_bench.saturation_mesh: a big
    # request pins shard 0, two smalls fill shard 1's slots, a mid arrival
    # fits neither shard directly and must admit via migration in the same
    # admission pass — its internal asserts are part of this test)
    from benchmarks.serving_bench import saturation_mesh
    row = saturation_mesh(cfg, params)[0]
    rec["rebalance"] = {
        "admitted_on": row["admitted_same_step_on"],
        "admitted_off": row["admitted_same_step_off"],
        "migrations": row["migrations_on"],
        "tokens_equal": row["bit_exact"]}

    # scheduler layer must add NOTHING to the round program: the §17 round
    # contract (zero collectives / pool-ranked scatters, no host callbacks,
    # donation aliased) must hold on a data=2 engine that just performed
    # forced migration+preemptions
    from repro.analysis import check_engine_round
    topo = ServingTopology(make_host_mesh(2, 1))
    eng_h = ServingEngine(cfg, params, topology=topo, **kw)
    traffic(eng_h, True)
    rep = check_engine_round(eng_h)
    rec["contract_ok"] = rep.ok
    rec["violations"] = [str(v) for v in rep.violations]
    rec["collectives"] = rep.metrics["collectives"]
    rec["pool_scatters"] = rep.metrics["pool_scatters"]
    print(json.dumps(rec))
""")


def test_mesh_scheduling_migration_preemption_rebalance():
    """Saturation-safe scheduling under the mesh (DESIGN.md §12): forced
    cross-shard migration + double preemption at data=2 and data=4 emit
    the single-device token streams bit-for-bit; admission rebalancing
    migrates a resident to admit an otherwise-unroutable arrival in the
    same admission pass; and the scheduler adds zero collectives / pool
    scatters to the round HLO (the existing CI gates)."""
    rec = _run(SCHED_SCRIPT)
    assert rec["equal"] == {"2": True, "4": True}, rec
    for data in ("2", "4"):
        f = rec["forced"][data]
        assert f["migrations"] >= 1 and f["blocks_migrated"] >= 1, rec
        assert f["preemptions"] == 2 and f["resumes"] == 2, rec
    assert rec["rebalance"]["admitted_on"], rec
    assert not rec["rebalance"]["admitted_off"], rec
    assert rec["rebalance"]["migrations"] >= 1, rec
    assert rec["rebalance"]["tokens_equal"], rec
    assert rec["contract_ok"], rec["violations"]
    assert all(c == 0 for c in rec["collectives"].values()), rec
    assert rec["pool_scatters"] == 0, rec


FAULT_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.analysis import check_engine_round
    from repro.configs import get_config
    from repro.engine import PredictiveSampler
    from repro.launch.mesh import make_host_mesh
    from repro.serving import (FaultPlan, Request, ServingEngine,
                               ServingTopology)

    EPS = jax.random.PRNGKey(9)
    cfg = get_config("qwen3-1.7b", reduced=True)
    from repro.models.transformer import TransformerLM
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS,
              block_size=4, adaptive=False, host_cache_mb=8)

    def traffic(eng):
        rng = np.random.default_rng(3)
        for i in range(4):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(3, 8))),
                new_tokens=int(rng.integers(8, 12))))
        eng.step()
        # park a running slot so resume crosses the (corruptible) arena
        occ = [b for b in range(eng.B) if eng.slots[b] is not None]
        eng.preempt_slot(occ[0])
        return {r.uid: r for r in eng.run()}, eng

    ref, _ = traffic(ServingEngine(cfg, params, faults=FaultPlan(),
                                   request_retries=1, **kw))
    # scripted chaos on a data=2 mesh: first block alloc dies (retried on
    # the same stream), arena reads corrupt at a seeded rate (cold-resume
    # recompute), uid 2's noise stream is NaN-poisoned on device
    # (quarantined, retried on a fresh stream)
    plan = FaultPlan(schedule={"alloc": (0,)},
                     rates={"arena_corrupt": 0.75},
                     poison_streams=(2,), seed=13)
    topo = ServingTopology(make_host_mesh(2, 1))
    eng = ServingEngine(cfg, params, topology=topo, faults=plan,
                        request_retries=1, **kw)
    got, eng = traffic(eng)
    m = eng.export_metrics()
    rec = {
        "all_ok": all(r.ok for r in got.values()),
        "healthy_equal": all((got[u].result == ref[u].result).all()
                             for u in (0, 1, 3)),
        "poisoned_ok": got[2].ok,
        "fresh_stream": got[2].seq_id not in plan.poison_streams,
        "requests_failed": m["requests_failed"],
        "retries": m["retries"],
        "faults_injected": m["faults_injected"],
        "checksum_failures": m["checksum_failures"]}
    # the poisoned request's fresh stream is solo-exact under its NEW id
    solo = PredictiveSampler(cfg, params, window=4, max_len=48, eps_key=EPS)
    p = np.asarray(got[2].prompt)
    t, _ = solo.generate(p[None].astype(np.int32), got[2].new_tokens,
                         seq_ids=np.asarray([got[2].seq_id], np.int32))
    rec["poisoned_solo_equal"] = bool(
        (np.asarray(t[0, :len(p) + got[2].new_tokens])
         == got[2].result).all())
    # quarantine keeps the §17 round contract: zero collectives, zero
    # pool-ranked scatters on the (now 9-arg, poison-carrying) round fn
    rep = check_engine_round(eng)
    rec["contract_ok"] = rep.ok
    rec["violations"] = [str(v) for v in rep.violations]
    rec["collectives"] = rep.metrics["collectives"]
    rec["pool_scatters"] = rep.metrics["pool_scatters"]
    print(json.dumps(rec))
""")


def test_mesh_engine_scripted_faults_keep_healthy_rows_exact():
    """§14 acceptance on the mesh: a scripted FaultPlan (alloc fault +
    seeded arena corruption + one poisoned stream) on a data=2 engine —
    every healthy request bitwise equal to the fault-free run, the
    poisoned one recovered on a fresh stream (solo-exact under its new
    id), nothing failed permanently, and the faulted round loop still
    compiles to zero collectives / zero pool-ranked scatters."""
    rec = _run(FAULT_SCRIPT)
    assert rec["all_ok"], rec
    assert rec["healthy_equal"], rec
    assert rec["poisoned_ok"] and rec["fresh_stream"], rec
    assert rec["poisoned_solo_equal"], rec
    assert rec["requests_failed"] == 0, rec
    assert rec["retries"] >= 2, rec
    assert rec["faults_injected"] >= 2, rec
    assert rec["checksum_failures"] >= 1, rec
    assert rec["contract_ok"], rec["violations"]
    assert all(c == 0 for c in rec["collectives"].values()), rec
    assert rec["pool_scatters"] == 0, rec


STAGED_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.analysis import check_engine_round
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    EPS = jax.random.PRNGKey(9)
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS,
              block_size=4, adaptive=False, rounds_per_sync=8)

    def traffic(eng):
        rng = np.random.default_rng(3)
        for i in range(10):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 7))),
                new_tokens=int(rng.integers(8, 13))))
        return {r.uid: r.result for r in eng.run()}

    # single-device HOST-ADMISSION reference vs the data=2 STAGED engine:
    # equality crosses the sharding AND the continuous-batching mode
    ref = traffic(ServingEngine(cfg, params, staging_slots=0, **kw))
    topo = ServingTopology(make_host_mesh(2, 1))
    eng = ServingEngine(cfg, params, topology=topo, staging_slots=2,
                        adaptive_rounds=False, **kw)
    got = traffic(eng)
    rec = {"equal": all((got[u] == ref[u]).all() for u in ref),
           "adoptions": eng.metrics.in_loop_adoptions,
           "staged": eng.metrics.staged_sequences}

    # §17 STAGED_ROUND_CONTRACT on the staged round program (the 19-arg
    # §15 ABI: plen + eight descriptor arrays + the q_more starvation
    # flag): the in-loop adoption scan is rank<=2 row bookkeeping per
    # shard, so the hot path must STILL hold zero cross-shard collectives
    # and zero pool-ranked scatter eqns — staged entries present in args
    eng2 = ServingEngine(cfg, params, topology=topo, staging_slots=2,
                         adaptive_rounds=False, **kw)
    rng = np.random.default_rng(5)
    for i in range(8):
        eng2.submit(Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab, 4),
                            new_tokens=20))
    eng2.step()
    rec["staged_now"] = eng2._staged_total()
    rep = check_engine_round(eng2)
    rec["contract_ok"] = rep.ok
    rec["violations"] = [str(v) for v in rep.violations]
    rec["n_args"] = rep.metrics["n_args"]
    rec["collectives"] = rep.metrics["collectives"]
    rec["pool_scatters"] = rep.metrics["pool_scatters"]
    rec["pallas_calls"] = rep.metrics["pallas_calls"]
    print(json.dumps(rec))
""")


def test_mesh_staged_engine_bit_exact_and_hot_path_gates():
    """Device-resident continuous batching under the mesh (DESIGN.md §15):
    a data=2 staged engine (pre-staged prompts + in-loop adoption) emits
    the single-device host-admission token streams bit-for-bit while
    actually adopting in-loop, and the staged round program — with live
    staged descriptors in its arguments — holds the existing CI gates:
    zero cross-shard collectives, zero pool-ranked scatters."""
    rec = _run(STAGED_SCRIPT)
    assert rec["equal"], rec
    assert rec["adoptions"] >= 1 and rec["staged"] >= 1, rec
    assert rec["staged_now"] >= 1, rec
    assert rec["n_args"] == 19, rec
    assert rec["contract_ok"], rec["violations"]
    assert all(c == 0 for c in rec["collectives"].values()), rec
    assert rec["pool_scatters"] == 0, rec
    assert rec["pallas_calls"] >= 1, rec


TP_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import place_params
    from repro.models.transformer import TransformerLM
    from repro.serving import Request, ServingEngine, ServingTopology

    EPS = jax.random.PRNGKey(9)
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=4, window_max=4, max_len=32, eps_key=EPS,
              block_size=4, adaptive=False)

    def traffic(eng):
        rng = np.random.default_rng(3)
        for i in range(4):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 7))),
                new_tokens=int(rng.integers(3, 6))))
        return {r.uid: r.result for r in eng.run()}

    ref = traffic(ServingEngine(cfg, params, **kw))
    topo = ServingTopology(make_host_mesh(2, 2))    # model axis stays auto
    p_tp = place_params(params, topo)   # serving_param_shardings: model TP
    got = traffic(ServingEngine(cfg, p_tp, topology=topo, **kw))
    equal = all((got[uid] == ref[uid]).all() for uid in ref)
    print(json.dumps({"equal": equal}))
""")


def test_mesh_engine_tensor_parallel_params_stay_exact():
    """data=2 x model=2: the model axis is left to GSPMD (auto) with params
    tensor-sharded by ``serving_param_shardings`` — tokens still match the
    single-device engine bit-for-bit."""
    rec = _run(TP_SCRIPT)
    assert rec["equal"], rec


def test_mesh_engine_bit_exact_no_collectives_routed():
    """data=2 and data=4 engines — device-resident (rounds_per_sync=4) AND
    host-driven (=1) — emit the single-device (and solo-sampler) token
    streams bit-for-bit; the device loop actually amortizes host syncs;
    admissions spread over shards by pool pressure; the compiled round-loop
    HLO contains no collective ops (per-shard local stop conditions) and
    its jaxpr no pool-ranked scatter (fused aliased writeback only)."""
    rec = _run(MAIN_SCRIPT)
    assert rec["solo_equal"], rec
    assert rec["equal"] == {"2x4": True, "2x1": True,
                            "4x4": True, "4x1": True}, rec
    assert rec["loop_amortized"] == {"2": True, "4": True}, rec
    assert rec["routing_spread"], rec
    assert rec["contract_ok"], rec["violations"]
    assert all(c == 0 for c in rec["collectives"].values()), rec
    assert rec["pool_scatters"] == 0, rec
    assert rec["pallas_calls"] >= 1, rec


@pytest.mark.parametrize("arch", ["gemma3-1b", "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_mesh_engine_bit_exact_across_mixers(arch):
    """Sliding-window local attention, MLA latents, and a recurrent hybrid
    (un-paged per-slot states riding next to sharded pools) all hold the
    mesh exactness contract at data=2 — with the mesh engine running the
    device-resident loop AND surviving a forced cross-shard migration plus
    a forced preemption/exact-resume, against a host-driven single-device
    reference."""
    rec = _run(ARCH_SCRIPT.replace("__ARCH__", arch))
    assert rec["equal"], rec
    assert rec["migrations"] >= 1, rec
    assert rec["preemptions"] == 1 and rec["resumes"] == 1, rec
