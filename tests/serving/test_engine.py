"""ServingEngine system tests — the acceptance bar is bit-exactness: every
serving path (paged cache, ragged mid-flight admission, prefix-cache hits,
adaptive W) must emit tokens identical to a per-request
``PredictiveSampler.generate`` run with the same eps key and noise stream."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import ContinuousBatcher, PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine

EPS_KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo_reference(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(jnp.asarray(np.asarray(req.prompt)[None], jnp.int32),
                      req.new_tokens,
                      seq_ids=jnp.asarray([req.seq_id], jnp.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _assert_all_exact(cfg, params, done, window, max_len):
    assert done, "no requests completed"
    for req in done:
        ref = _solo_reference(cfg, params, req, window, max_len)
        np.testing.assert_array_equal(
            req.result, ref,
            err_msg=f"request {req.uid} diverged from its solo run")


def test_ragged_midflight_admission_bit_exact(qwen):
    """Satellite: requests of different prompt lengths arriving while others
    are mid-flight must each match their per-request solo run bit-for-bit
    (slot reuse, ragged prefill, paged scatter all exercised)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(0)

    first = [Request(uid=i,
                     prompt=rng.integers(0, cfg.vocab,
                                         size=int(rng.integers(2, 10))),
                     new_tokens=int(rng.integers(6, 12)))
             for i in range(3)]
    for r in first:
        eng.submit(r)
    # run a few rounds so slots are mid-flight, then admit ragged latecomers
    for _ in range(2):
        eng.step()
    late = [Request(uid=10 + i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(1, 14))),
                    new_tokens=int(rng.integers(3, 9)))
            for i in range(3)]
    for r in late:
        eng.submit(r)
    done = eng.run()

    assert len(done) == 6
    assert {r.uid for r in done} == {0, 1, 2, 10, 11, 12}
    _assert_all_exact(cfg, params, done, window=8, max_len=64)
    # slot reuse happened: 6 requests through 2 slots
    assert eng.metrics.requests_finished == 6
    for req in done:
        np.testing.assert_array_equal(req.result[:len(req.prompt)],
                                      np.asarray(req.prompt))


def test_prefix_cache_hits_stay_exact_and_save_prefill(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=96,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(1)
    system_prompt = rng.integers(0, cfg.vocab, size=21)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [system_prompt,
                         rng.integers(0, cfg.vocab,
                                      size=int(rng.integers(2, 6)))]),
                    new_tokens=6)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    _assert_all_exact(cfg, params, done, window=8, max_len=96)

    by_uid = {r.uid: r for r in done}
    assert by_uid[0].prefix_hit_blocks == 0          # first pays full prefill
    for i in (1, 2, 3):                              # the rest share 5 blocks
        assert by_uid[i].prefix_hit_blocks == 5
        assert by_uid[i].prefill_calls < by_uid[0].prefill_calls
    assert eng.export_metrics()["prefix_hit_rate"] > 0.5


def test_adaptive_window_stays_exact(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=True)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(2, 8))),
                    new_tokens=int(rng.integers(6, 14)))
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    # exactness is W-independent: compare against fixed W=8 solo runs even
    # though the engine varied W round-to-round
    _assert_all_exact(cfg, params, done, window=8, max_len=64)
    assert len(set(eng.metrics.window_hist)) >= 1
    assert all(1 <= w <= 8 for w in eng.metrics.window_hist)


def test_adaptive_widens_into_an_accepting_stream(qwen):
    """Engine-level controller integration: starting narrow on a stream
    whose acceptance saturates the window, the EWMA must widen W (the
    narrowing direction is unit-tested in test_adaptive.py — an *untrained*
    LM is actually easy for FPI, since position-pinned noise makes its
    outputs nearly position-deterministic, so a genuinely hard stream needs
    a trained strongly-coupled model as in benchmarks/serving_bench.py)."""
    cfg, params = qwen
    # peaked model (scaled tied embeddings) -> near-deterministic stream
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    easy = ServingEngine(cfg, peaked, batch=2, window_max=16, max_len=96,
                         eps_key=EPS_KEY, block_size=8, adaptive=True,
                         window_init=2)
    for i in range(2):
        easy.submit(Request(uid=i, prompt=np.zeros(2, np.int64),
                            new_tokens=40))
    easy.run()
    assert max(easy.metrics.window_hist) > 2     # widened into the stream
    assert all(w == 16 or (w & (w - 1)) == 0     # stayed on the pow2 grid
               for w in easy.metrics.window_hist)
    # telemetry and controller agree on the retune-boundary count: the EWMA
    # advances once per host sync (the device loop runs at fixed W)
    assert len(easy.controller.history) == easy.metrics.host_syncs
    # the device-resident loop actually amortized rounds over syncs
    assert easy.metrics.rounds > easy.metrics.host_syncs


def test_peaked_model_beats_ancestral_call_count(qwen):
    cfg, params = qwen
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    eng = ServingEngine(cfg, peaked, batch=2, window_max=8, max_len=96,
                        eps_key=EPS_KEY, block_size=8, adaptive=True)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.zeros(2, np.int64),
                           new_tokens=48))
    done = eng.run()
    for req in done:
        assert req.calls_used < req.new_tokens, \
            (req.uid, req.calls_used, req.new_tokens)
    m = eng.export_metrics()
    assert m["arm_calls_vs_ancestral"] < 1.0
    assert m["latency_p95_s"] >= m["latency_p50_s"] > 0.0


def test_priority_admission_order(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(4)
    lo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=0)
    eng.submit(lo)
    eng.submit(hi)
    done = eng.run()
    assert [r.uid for r in done] == [1, 0]       # high priority served first
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_tight_pool_serializes_instead_of_crashing(qwen):
    """Admission reserves each request's worst-case block need: two requests
    that would jointly oversubscribe a tight pool must be served one after
    the other (run-to-completion), not crash mid-generation."""
    cfg, params = qwen
    # each request needs ceil((4 + 40 + 4)/4) = 12 blocks worst-case;
    # pool of 15 usable blocks fits one at a time, never both
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, num_blocks=16,
                        adaptive=False, prefix_cache=False)
    rng = np.random.default_rng(6)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 4),
                           new_tokens=40))
    done = eng.run()
    assert len(done) == 2
    assert eng.metrics.export()["mean_batch_occupancy"] <= 0.5  # serialized
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_admission_deadlock_raises(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, num_blocks=4,
                        adaptive=False)
    eng.submit(Request(uid=0, prompt=np.zeros(30, np.int64), new_tokens=20))
    with pytest.raises(MemoryError):
        eng.run()


def test_paged_attention_path_matches_dense_engine_and_solo(qwen):
    """Tentpole acceptance: the default engine decodes *through* block
    tables (``paged_attention=True`` — no ``gather_paged``/``scatter_paged``
    on attention leaves in the round hot path) and must agree bit-for-bit
    both with the legacy dense gather/scatter engine on identical traffic
    and with each request's per-request solo run."""
    cfg, params = qwen

    def traffic(eng):
        rng = np.random.default_rng(8)
        for i in range(4):
            eng.submit(Request(uid=i,
                               prompt=rng.integers(
                                   0, cfg.vocab,
                                   size=int(rng.integers(2, 9))),
                               new_tokens=int(rng.integers(5, 11))))
        return eng.run()

    kw = dict(batch=2, window_max=8, max_len=64, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    paged = ServingEngine(cfg, params, **kw)
    dense = ServingEngine(cfg, params, paged_attention=False, **kw)
    assert paged.paged_attention and not dense.paged_attention
    done_p, done_d = traffic(paged), traffic(dense)
    by_uid = {r.uid: r for r in done_d}
    for req in done_p:
        np.testing.assert_array_equal(
            req.result, by_uid[req.uid].result,
            err_msg=f"request {req.uid}: paged path diverged from dense")
    _assert_all_exact(cfg, params, done_p, window=8, max_len=64)


def test_paged_kernel_engine_emits_same_tokens(qwen):
    """Force the Pallas paged flash-decode kernel (interpret mode) through a
    short engine run: with the peaked (near-deterministic) model the token
    stream must match the exact-fallback engine despite the kernel's
    re-ordered softmax reduction."""
    cfg, params = qwen
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    kw = dict(batch=2, window_max=4, max_len=32, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    ker = ServingEngine(cfg, peaked, use_attention_kernel=True, **kw)
    ref = ServingEngine(cfg, peaked, use_attention_kernel=False, **kw)
    for eng in (ker, ref):
        for i in range(2):
            eng.submit(Request(uid=i, prompt=np.full(3, i, np.int64),
                               new_tokens=8))
    done_k, done_r = ker.run(), ref.run()
    by_uid = {r.uid: r for r in done_r}
    for req in done_k:
        np.testing.assert_array_equal(req.result, by_uid[req.uid].result)


@pytest.mark.parametrize("paged_attention", [True, False])
def test_round_buffers_are_donated(qwen, paged_attention):
    """Satellite regression: the jitted round loop donates the physical pool
    and per-slot state — after a step the previous pool buffer must be GONE
    (no second full-pool copy retained) on BOTH pool write paths: the fused
    paged round and the legacy dense round, whose window scatter now routes
    through the same aliased ``paged_window_write``. ``donate=False``
    restores the copying behaviour."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False,
              paged_attention=paged_attention)
    for donate in (True, False):
        eng = ServingEngine(cfg, params, donate=donate, **kw)
        eng.submit(Request(uid=0, prompt=np.arange(1, 5), new_tokens=16))
        eng.step()                       # admission + first round loop
        pool_leaf = jax.tree.leaves(eng.paged)[0]
        tok_leaf = eng.tokens
        eng.step()                       # next loop consumes (donates) them
        assert pool_leaf.is_deleted() == donate
        assert tok_leaf.is_deleted() == donate
        assert not jax.tree.leaves(eng.paged)[0].is_deleted()


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_device_loop_matches_host_loop_and_solo(arch):
    """Tentpole acceptance: the device-resident round loop
    (``rounds_per_sync=4``, >= 4 verify rounds per host sync) emits tokens
    bit-identical to the host-driven loop (``rounds_per_sync=1``) and to
    per-request solo ``PredictiveSampler.generate`` runs, across attn /
    sliding-window local / MLA / recurrent-hybrid mixers — and actually
    amortizes host syncs."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)

    def traffic(eng):
        rng = np.random.default_rng(13)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=rng.integers(
                                   0, cfg.vocab,
                                   size=int(rng.integers(2, 7))),
                               new_tokens=int(rng.integers(8, 12))))
        return eng.run()

    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    dev = ServingEngine(cfg, params, rounds_per_sync=4, **kw)
    host = ServingEngine(cfg, params, rounds_per_sync=1, **kw)
    done_dev, done_host = traffic(dev), traffic(host)
    by_uid = {r.uid: r for r in done_host}
    for req in done_dev:
        np.testing.assert_array_equal(
            req.result, by_uid[req.uid].result,
            err_msg=f"request {req.uid}: device loop diverged from "
                    f"host-driven loop")
    _assert_all_exact(cfg, params, done_dev, window=4, max_len=48)
    # per-request round counts are exact regardless of loop batching
    for req in done_dev:
        assert req.calls_used == by_uid[req.uid].calls_used
    # residency: all requests fit the batch, so every sync ran k=4 rounds
    # until the last partial loop; the host loop syncs once per round
    assert dev.metrics.host_syncs < dev.metrics.rounds
    assert dev.metrics.rounds >= 4 * (dev.metrics.host_syncs - 1) + 1
    assert host.metrics.host_syncs == host.metrics.rounds
    m = dev.export_metrics()
    assert m["rounds_per_sync"] > 1.0
    assert m["host_syncs_per_token"] < m["rounds"] / m["tokens_generated"]


def test_table_upload_cached_until_invalidated(qwen):
    """Satellite: the device copy of the block tables is cached between
    rounds — re-uploaded only when admission/slot-clear/table growth
    actually mutates the host tables."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    eng.submit(Request(uid=0, prompt=np.arange(1, 5), new_tokens=24))
    eng.step()                  # admit + grow table to target+W
    dev = eng._tables_dev
    assert dev is not None
    eng.step()                  # steady state: no growth, no new upload
    assert eng._tables_dev is dev
    eng.run()                   # finishing the request clears its row...
    assert eng._tables_dev is None or eng._tables_dev is not dev


def test_deadline_edf_order_and_miss_metrics(qwen):
    """Satellite (latency SLO): within a priority class the queue serves
    earliest-deadline-first (deadline-free requests last); finished
    requests past their SLO are counted in deadline_miss_count and
    queue-wait percentiles are exported."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(11)
    no_slo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3),
                     new_tokens=4)
    tight = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=4, deadline=1e-4)      # unmeetable on CPU
    loose = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=4, deadline=1e6)
    for r in (no_slo, tight, loose):
        eng.submit(r)
    done = eng.run()
    assert [r.uid for r in done] == [1, 2, 0]         # EDF, SLO-free last
    m = eng.export_metrics()
    assert m["deadline_requests"] == 2
    assert m["deadline_miss_count"] == 1              # only the 100us SLO
    assert m["queue_wait_p95_s"] >= m["queue_wait_p50_s"] >= 0.0
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_continuous_batcher_alias_is_serving_engine(qwen):
    """The seed API survives: ContinuousBatcher(sampler, batch) drains a
    queue through the paged engine, and its results are bit-exact too."""
    cfg, params = qwen
    sampler = PredictiveSampler(cfg, params, window=4, max_len=64,
                                eps_key=EPS_KEY)
    batcher = ContinuousBatcher(sampler, batch=2)
    assert isinstance(batcher, ServingEngine)
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=int(rng.integers(2, 6))),
                    int(rng.integers(4, 8)))
            for i in range(4)]
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    assert len(done) == 4
    assert int(np.asarray(batcher.state.rounds)) >= 1
    _assert_all_exact(cfg, params, done, window=4, max_len=64)
