"""ServingEngine system tests — the acceptance bar is bit-exactness: every
serving path (paged cache, ragged mid-flight admission, prefix-cache hits,
adaptive W) must emit tokens identical to a per-request
``PredictiveSampler.generate`` run with the same eps key and noise stream."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import ContinuousBatcher, PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine

EPS_KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo_reference(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(jnp.asarray(np.asarray(req.prompt)[None], jnp.int32),
                      req.new_tokens,
                      seq_ids=jnp.asarray([req.seq_id], jnp.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _assert_all_exact(cfg, params, done, window, max_len):
    assert done, "no requests completed"
    for req in done:
        ref = _solo_reference(cfg, params, req, window, max_len)
        np.testing.assert_array_equal(
            req.result, ref,
            err_msg=f"request {req.uid} diverged from its solo run")


def test_ragged_midflight_admission_bit_exact(qwen):
    """Satellite: requests of different prompt lengths arriving while others
    are mid-flight must each match their per-request solo run bit-for-bit
    (slot reuse, ragged prefill, paged scatter all exercised)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(0)

    first = [Request(uid=i,
                     prompt=rng.integers(0, cfg.vocab,
                                         size=int(rng.integers(2, 10))),
                     new_tokens=int(rng.integers(6, 12)))
             for i in range(3)]
    for r in first:
        eng.submit(r)
    # run a few rounds so slots are mid-flight, then admit ragged latecomers
    for _ in range(2):
        eng.step()
    late = [Request(uid=10 + i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(1, 14))),
                    new_tokens=int(rng.integers(3, 9)))
            for i in range(3)]
    for r in late:
        eng.submit(r)
    done = eng.run()

    assert len(done) == 6
    assert {r.uid for r in done} == {0, 1, 2, 10, 11, 12}
    _assert_all_exact(cfg, params, done, window=8, max_len=64)
    # slot reuse happened: 6 requests through 2 slots
    assert eng.metrics.requests_finished == 6
    for req in done:
        np.testing.assert_array_equal(req.result[:len(req.prompt)],
                                      np.asarray(req.prompt))


def test_prefix_cache_hits_stay_exact_and_save_prefill(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=96,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(1)
    system_prompt = rng.integers(0, cfg.vocab, size=21)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [system_prompt,
                         rng.integers(0, cfg.vocab,
                                      size=int(rng.integers(2, 6)))]),
                    new_tokens=6)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    _assert_all_exact(cfg, params, done, window=8, max_len=96)

    by_uid = {r.uid: r for r in done}
    assert by_uid[0].prefix_hit_blocks == 0          # first pays full prefill
    for i in (1, 2, 3):                              # the rest share 5 blocks
        assert by_uid[i].prefix_hit_blocks == 5
        assert by_uid[i].prefill_calls < by_uid[0].prefill_calls
    assert eng.export_metrics()["prefix_hit_rate"] > 0.5


def test_adaptive_window_stays_exact(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=8, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=True)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(2, 8))),
                    new_tokens=int(rng.integers(6, 14)))
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    # exactness is W-independent: compare against fixed W=8 solo runs even
    # though the engine varied W round-to-round
    _assert_all_exact(cfg, params, done, window=8, max_len=64)
    assert len(set(eng.metrics.window_hist)) >= 1
    assert all(1 <= w <= 8 for w in eng.metrics.window_hist)


def test_adaptive_widens_into_an_accepting_stream(qwen):
    """Engine-level controller integration: starting narrow on a stream
    whose acceptance saturates the window, the EWMA must widen W (the
    narrowing direction is unit-tested in test_adaptive.py — an *untrained*
    LM is actually easy for FPI, since position-pinned noise makes its
    outputs nearly position-deterministic, so a genuinely hard stream needs
    a trained strongly-coupled model as in benchmarks/serving_bench.py)."""
    cfg, params = qwen
    # peaked model (scaled tied embeddings) -> near-deterministic stream
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    easy = ServingEngine(cfg, peaked, batch=2, window_max=16, max_len=96,
                         eps_key=EPS_KEY, block_size=8, adaptive=True,
                         window_init=2)
    for i in range(2):
        easy.submit(Request(uid=i, prompt=np.zeros(2, np.int64),
                            new_tokens=40))
    easy.run()
    assert max(easy.metrics.window_hist) > 2     # widened into the stream
    assert all(w == 16 or (w & (w - 1)) == 0     # stayed on the pow2 grid
               for w in easy.metrics.window_hist)
    # telemetry and controller agree on the retune-boundary count: the EWMA
    # advances once per host sync (the device loop runs at fixed W)
    assert len(easy.controller.history) == easy.metrics.host_syncs
    # the device-resident loop actually amortized rounds over syncs
    assert easy.metrics.rounds > easy.metrics.host_syncs


def test_peaked_model_beats_ancestral_call_count(qwen):
    cfg, params = qwen
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    eng = ServingEngine(cfg, peaked, batch=2, window_max=8, max_len=96,
                        eps_key=EPS_KEY, block_size=8, adaptive=True)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.zeros(2, np.int64),
                           new_tokens=48))
    done = eng.run()
    for req in done:
        assert req.calls_used < req.new_tokens, \
            (req.uid, req.calls_used, req.new_tokens)
    m = eng.export_metrics()
    assert m["arm_calls_vs_ancestral"] < 1.0
    assert m["latency_p95_s"] >= m["latency_p50_s"] > 0.0


def test_priority_admission_order(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(4)
    lo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=0)
    eng.submit(lo)
    eng.submit(hi)
    done = eng.run()
    assert [r.uid for r in done] == [1, 0]       # high priority served first
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_tight_pool_serializes_instead_of_crashing(qwen):
    """Admission reserves each request's worst-case block need: two requests
    that would jointly oversubscribe a tight pool must be served one after
    the other (run-to-completion), not crash mid-generation."""
    cfg, params = qwen
    # each request needs ceil((4 + 40 + 4)/4) = 12 blocks worst-case;
    # pool of 15 usable blocks fits one at a time, never both
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, num_blocks=16,
                        adaptive=False, prefix_cache=False)
    rng = np.random.default_rng(6)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 4),
                           new_tokens=40))
    done = eng.run()
    assert len(done) == 2
    assert eng.metrics.export()["mean_batch_occupancy"] <= 0.5  # serialized
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_unservable_request_rejected_at_submit(qwen):
    """A request whose worst-case block need exceeds the whole pool is
    rejected AT SUBMIT with a structured error (DESIGN.md §14) — the old
    behaviour was an admission-deadlock MemoryError out of ``run()``."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, num_blocks=4,
                        adaptive=False)
    req = Request(uid=0, prompt=np.zeros(30, np.int64), new_tokens=20)
    assert eng.submit(req) is False
    assert req.error is not None and req.error.code == "over_capacity"
    assert req.result is None and not req.ok
    assert eng.run() == [req]            # delivered through done; no crash
    assert eng.export_metrics()["requests_rejected"] == 1


def test_paged_attention_path_matches_dense_engine_and_solo(qwen):
    """Tentpole acceptance: the default engine decodes *through* block
    tables (``paged_attention=True`` — no ``gather_paged``/``scatter_paged``
    on attention leaves in the round hot path) and must agree bit-for-bit
    both with the legacy dense gather/scatter engine on identical traffic
    and with each request's per-request solo run."""
    cfg, params = qwen

    def traffic(eng):
        rng = np.random.default_rng(8)
        for i in range(4):
            eng.submit(Request(uid=i,
                               prompt=rng.integers(
                                   0, cfg.vocab,
                                   size=int(rng.integers(2, 9))),
                               new_tokens=int(rng.integers(5, 11))))
        return eng.run()

    kw = dict(batch=2, window_max=8, max_len=64, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    paged = ServingEngine(cfg, params, **kw)
    dense = ServingEngine(cfg, params, paged_attention=False, **kw)
    assert paged.paged_attention and not dense.paged_attention
    done_p, done_d = traffic(paged), traffic(dense)
    by_uid = {r.uid: r for r in done_d}
    for req in done_p:
        np.testing.assert_array_equal(
            req.result, by_uid[req.uid].result,
            err_msg=f"request {req.uid}: paged path diverged from dense")
    _assert_all_exact(cfg, params, done_p, window=8, max_len=64)


def test_paged_kernel_engine_emits_same_tokens(qwen):
    """Force the Pallas paged flash-decode kernel (interpret mode) through a
    short engine run: with the peaked (near-deterministic) model the token
    stream must match the exact-fallback engine despite the kernel's
    re-ordered softmax reduction."""
    cfg, params = qwen
    peaked = dict(params)
    peaked["embed"] = {"table": params["embed"]["table"] * 6.0}
    kw = dict(batch=2, window_max=4, max_len=32, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    ker = ServingEngine(cfg, peaked, use_attention_kernel=True, **kw)
    ref = ServingEngine(cfg, peaked, use_attention_kernel=False, **kw)
    for eng in (ker, ref):
        for i in range(2):
            eng.submit(Request(uid=i, prompt=np.full(3, i, np.int64),
                               new_tokens=8))
    done_k, done_r = ker.run(), ref.run()
    by_uid = {r.uid: r for r in done_r}
    for req in done_k:
        np.testing.assert_array_equal(req.result, by_uid[req.uid].result)


@pytest.mark.parametrize("paged_attention", [True, False])
def test_round_buffers_are_donated(qwen, paged_attention):
    """Satellite regression: the jitted round loop donates the physical pool
    and per-slot state — after a step the previous pool buffer must be GONE
    (no second full-pool copy retained) on BOTH pool write paths: the fused
    paged round and the legacy dense round, whose window scatter now routes
    through the same aliased ``paged_window_write``. ``donate=False``
    restores the copying behaviour."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False,
              paged_attention=paged_attention)
    for donate in (True, False):
        eng = ServingEngine(cfg, params, donate=donate, **kw)
        eng.submit(Request(uid=0, prompt=np.arange(1, 5), new_tokens=16))
        eng.step()                       # admission + first round loop
        pool_leaf = jax.tree.leaves(eng.paged)[0]
        tok_leaf = eng.tokens
        eng.step()                       # next loop consumes (donates) them
        assert pool_leaf.is_deleted() == donate
        assert tok_leaf.is_deleted() == donate
        assert not jax.tree.leaves(eng.paged)[0].is_deleted()


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_device_loop_matches_host_loop_and_solo(arch):
    """Tentpole acceptance: the device-resident round loop
    (``rounds_per_sync=4``, >= 4 verify rounds per host sync) emits tokens
    bit-identical to the host-driven loop (``rounds_per_sync=1``) and to
    per-request solo ``PredictiveSampler.generate`` runs, across attn /
    sliding-window local / MLA / recurrent-hybrid mixers — and actually
    amortizes host syncs."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)

    def traffic(eng):
        rng = np.random.default_rng(13)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=rng.integers(
                                   0, cfg.vocab,
                                   size=int(rng.integers(2, 7))),
                               new_tokens=int(rng.integers(8, 12))))
        return eng.run()

    kw = dict(batch=4, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    dev = ServingEngine(cfg, params, rounds_per_sync=4, **kw)
    host = ServingEngine(cfg, params, rounds_per_sync=1, **kw)
    done_dev, done_host = traffic(dev), traffic(host)
    by_uid = {r.uid: r for r in done_host}
    for req in done_dev:
        np.testing.assert_array_equal(
            req.result, by_uid[req.uid].result,
            err_msg=f"request {req.uid}: device loop diverged from "
                    f"host-driven loop")
    _assert_all_exact(cfg, params, done_dev, window=4, max_len=48)
    # per-request round counts are exact regardless of loop batching
    for req in done_dev:
        assert req.calls_used == by_uid[req.uid].calls_used
    # residency: all requests fit the batch, so every sync ran k=4 rounds
    # until the last partial loop; the host loop syncs once per round
    assert dev.metrics.host_syncs < dev.metrics.rounds
    assert dev.metrics.rounds >= 4 * (dev.metrics.host_syncs - 1) + 1
    assert host.metrics.host_syncs == host.metrics.rounds
    m = dev.export_metrics()
    assert m["rounds_per_sync"] > 1.0
    assert m["host_syncs_per_token"] < m["rounds"] / m["tokens_generated"]


def test_table_upload_cached_until_invalidated(qwen):
    """Satellite: the device copy of the block tables is cached between
    rounds — re-uploaded only when admission/slot-clear/table growth
    actually mutates the host tables."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    eng.submit(Request(uid=0, prompt=np.arange(1, 5), new_tokens=24))
    eng.step()                  # admit + grow table to target+W
    dev = eng._tables_dev
    assert dev is not None
    eng.step()                  # steady state: no growth, no new upload
    assert eng._tables_dev is dev
    eng.run()                   # finishing the request clears its row...
    assert eng._tables_dev is None or eng._tables_dev is not dev


def test_deadline_edf_order_and_miss_metrics(qwen):
    """Satellite (latency SLO): within a priority class the queue serves
    earliest-deadline-first (deadline-free requests last); finished
    requests past their SLO are counted in deadline_miss_count and
    queue-wait percentiles are exported."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(11)
    no_slo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3),
                     new_tokens=4)
    tight = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=4, deadline=1e-4)      # unmeetable on CPU
    loose = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=4, deadline=1e6)
    for r in (no_slo, tight, loose):
        eng.submit(r)
    done = eng.run()
    assert [r.uid for r in done] == [1, 2, 0]         # EDF, SLO-free last
    m = eng.export_metrics()
    assert m["deadline_requests"] == 2
    assert m["deadline_miss_count"] == 1              # only the 100us SLO
    assert m["queue_wait_p95_s"] >= m["queue_wait_p50_s"] >= 0.0
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_lookahead_admission_no_head_of_line_blocking(qwen):
    """Satellite regression (engine.py admission loop): a small fitting
    request queued behind an oversized, unroutable head must admit into the
    free slot instead of waiting for the head — and the head must not
    starve (it lands once capacity frees) with every result bit-exact."""
    cfg, params = qwen
    # pool of 15 usable blocks: big requests need 12, smalls 3 — while one
    # big runs, the next big is unroutable but a small still fits
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, num_blocks=16, adaptive=False,
              prefix_cache=False, preempt=False)
    rng = np.random.default_rng(21)
    big1 = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4),
                   new_tokens=40)
    big2 = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 4),
                   new_tokens=40)
    small = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 3),
                    new_tokens=5)
    eng = ServingEngine(cfg, params, **kw)
    for r in (big1, big2, small):
        eng.submit(r)
    eng.step()
    # lookahead admitted the small past the unroutable big2 head
    assert {r.uid for b in range(2)
            if (r := eng.slots[b]) is not None} == {0, 2}
    assert big2.bypassed == 1
    assert eng.metrics.head_bypass_admissions == 1
    done = eng.run()
    assert {r.uid for r in done} == {0, 1, 2}
    assert done[0].uid == 2 or done[1].uid == 2   # small didn't wait for big2
    _assert_all_exact(cfg, params, done, window=4, max_len=48)

    # the old break-on-head behaviour is restorable (lookahead=1): the
    # small now head-of-line blocks behind big2
    eng1 = ServingEngine(cfg, params, lookahead=1, **kw)
    for uid, r in ((0, big1), (1, big2), (2, small)):
        eng1.submit(Request(uid=uid, prompt=np.asarray(r.prompt),
                            new_tokens=r.new_tokens))
    eng1.step()
    assert [b for b in range(2) if eng1.slots[b] is not None] == [0]
    assert eng1.metrics.head_bypass_admissions == 0


def test_aging_bound_narrows_admission_to_the_head(qwen):
    """After ``max_head_bypass`` lookahead admissions jump an unroutable
    head, admission goes head-only: later smalls wait even though they
    would fit, so the head admits next and cannot starve."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, num_blocks=16, adaptive=False,
              prefix_cache=False, preempt=False, max_head_bypass=2)
    rng = np.random.default_rng(23)
    eng = ServingEngine(cfg, params, **kw)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4),
                       new_tokens=40))              # occupies the pool
    eng.submit(Request(uid=1, prompt=rng.integers(0, cfg.vocab, 4),
                       new_tokens=40))              # unroutable head
    for i in range(4):                              # fitting smalls behind
        eng.submit(Request(uid=10 + i, prompt=rng.integers(0, cfg.vocab, 3),
                           new_tokens=4))
    done = eng.run()
    head = next(r for r in done if r.uid == 1)
    assert head.bypassed == 2                       # aged exactly to the bound
    assert eng.metrics.head_bypass_admissions == 2
    # once aged, the head ADMITTED before the remaining smalls (they fit
    # but had to wait for it)
    by_uid = {r.uid: r for r in done}
    assert head.admit_time < by_uid[12].admit_time
    assert head.admit_time < by_uid[13].admit_time
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_run_max_rounds_counts_verify_rounds_not_steps(qwen):
    """Satellite regression: the convergence budget must count *executed
    verify rounds* from the packed stats — with ``rounds_per_sync=4`` the
    old per-step decrement silently allowed 4x the documented bound."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=1, max_len=32, eps_key=EPS_KEY,
              block_size=4, adaptive=False, rounds_per_sync=4)
    # W=1: every round accepts exactly one token -> 12 rounds for 12 tokens
    eng = ServingEngine(cfg, params, **kw)
    eng.submit(Request(uid=0, prompt=np.arange(1, 4), new_tokens=12))
    with pytest.raises(RuntimeError):
        eng.run(max_rounds=8)        # 12 > 8: must trip (3 steps passed it
        #                              under the old per-step accounting)
    eng2 = ServingEngine(cfg, params, **kw)
    eng2.submit(Request(uid=0, prompt=np.arange(1, 4), new_tokens=12))
    done = eng2.run(max_rounds=12)   # exactly the required budget
    assert eng2.metrics.rounds == 12
    _assert_all_exact(cfg, params, done, window=1, max_len=32)


def test_deadline_missed_in_queue_counted_before_finish(qwen):
    """Satellite regression: a request that blows its SLO while still
    queued must show up in ``deadline_missed_in_queue`` at admission poll
    time — not only in ``deadline_miss_count`` when it happens to finish."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=64,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(29)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 3),
                       new_tokens=32))
    eng.step()                                       # uid 0 occupies the slot
    eng.submit(Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3),
                       new_tokens=4, deadline=1e-6))
    time.sleep(0.01)
    eng.step()
    m = eng.export_metrics()
    assert m["deadline_missed_in_queue"] == 1        # visible while queued
    assert m["deadline_miss_count"] == 0             # not finished yet
    eng.step()
    assert eng.metrics.deadline_missed_in_queue == 1  # counted once
    done = eng.run()
    m = eng.export_metrics()
    assert m["deadline_missed_in_queue"] == 1
    assert m["deadline_miss_count"] == 1             # finish-side count too
    _assert_all_exact(cfg, params, done, window=4, max_len=64)


def test_clear_row_zeroes_seq_ids(qwen):
    """Satellite regression: a released slot's noise-stream id must be
    zeroed with the rest of the row (stale ids were harmless only while
    inactive lanes stayed no-ops — preemption/migration judge rows on
    being fully clean)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    eng.submit(Request(uid=77, prompt=np.arange(1, 5), new_tokens=24))
    eng.step()          # 4 rounds can accept at most 16 < 24: still running
    assert int(eng.seq_ids[0]) == 77
    eng.run()
    assert np.asarray(eng.seq_ids).tolist() == [0, 0]
    assert np.asarray(eng.n).tolist() == [1, 1]


def test_engine_normalizes_prefill_chunk_to_pow2(qwen):
    """Satellite: a non-pow2 ``prefill_chunk`` (48) must normalize down to
    32 so compiled prefill widths stay on the pow2 grid."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=96,
                        eps_key=EPS_KEY, block_size=4, adaptive=False,
                        prefill_chunk=48)
    assert eng.prefill_chunk == 32
    rng = np.random.default_rng(31)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 50),
                       new_tokens=4))
    done = eng.run()
    assert set(eng._prefill_fns) <= {1, 2, 4, 8, 16, 32}
    _assert_all_exact(cfg, params, done, window=4, max_len=96)


def test_continuous_batcher_alias_is_serving_engine(qwen):
    """The seed API survives: ContinuousBatcher(sampler, batch) drains a
    queue through the paged engine, and its results are bit-exact too."""
    cfg, params = qwen
    sampler = PredictiveSampler(cfg, params, window=4, max_len=64,
                                eps_key=EPS_KEY)
    batcher = ContinuousBatcher(sampler, batch=2)
    assert isinstance(batcher, ServingEngine)
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=int(rng.integers(2, 6))),
                    int(rng.integers(4, 8)))
            for i in range(4)]
    for r in reqs:
        batcher.submit(r)
    done = batcher.run()
    assert len(done) == 4
    assert int(np.asarray(batcher.state.rounds)) >= 1
    _assert_all_exact(cfg, params, done, window=4, max_len=64)
