"""Admission-layer units: pow2 prefill chunk cover, queue lookahead /
remove / requeue semantics (the host-side half of saturation-safe
scheduling — engine integration lives in test_engine.py /
test_preemption.py)."""
import numpy as np

from repro.serving.admission import (AdmissionQueue, Request, pow2_at_most,
                                     prefill_chunks)


def test_prefill_chunks_pow2_cover_exact():
    for length in range(0, 130):
        for max_chunk in (1, 2, 3, 7, 8, 48, 64, 100):
            chunks = prefill_chunks(length, max_chunk)
            assert sum(chunks) == length
            for c in chunks:
                assert c & (c - 1) == 0, (length, max_chunk, chunks)
                assert c <= max_chunk


def test_prefill_chunks_non_pow2_bound_normalized():
    """Satellite regression: a non-pow2 ``max_chunk`` (48) used to emit
    non-pow2 widths (48, 24, ...), breaking the bounded-compiled-widths
    guarantee. The bound must normalize down to 32."""
    chunks = prefill_chunks(100, 48)
    assert chunks == [32, 32, 32, 4]
    # distinct widths across ANY length stay within log2(32)+1 = 6 shapes
    widths = {c for L in range(200) for c in prefill_chunks(L, 48)}
    assert widths <= {1, 2, 4, 8, 16, 32}


def test_pow2_at_most():
    assert [pow2_at_most(x) for x in (1, 2, 3, 48, 64, 100)] == \
        [1, 2, 2, 32, 64, 64]


def _req(uid, priority=0, deadline=None):
    return Request(uid=uid, prompt=np.asarray([1, 2]), new_tokens=4,
                   priority=priority, deadline=deadline)


def test_lookahead_returns_queue_order_without_removal():
    q = AdmissionQueue()
    reqs = [_req(0, priority=1), _req(1, priority=0), _req(2, priority=1)]
    for r in reqs:
        q.push(r)
    look = q.lookahead(2)
    assert [r.uid for r in look] == [1, 0]      # priority, then FIFO
    assert len(q) == 3                          # nothing removed
    assert [r.uid for r in q.lookahead(10)] == [1, 0, 2]


def test_remove_specific_request_keeps_heap_order():
    q = AdmissionQueue()
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        q.push(r)
    assert q.remove(reqs[2])
    assert not q.remove(reqs[2])                # already gone
    assert [q.pop().uid for _ in range(len(q))] == [0, 1, 3, 4]


def test_requeue_preserves_submit_time_and_arrival_order():
    """Preemption requeues must keep the original SLO clock and FIFO rank:
    a parked request resumes ahead of later arrivals in its class."""
    q = AdmissionQueue()
    first, second = _req(0), _req(1)
    q.push(first)
    q.push(second)
    t0 = first.submit_time
    assert q.pop() is first
    q.push(_req(2))
    q.requeue(first)                 # parked -> requeued
    assert first.submit_time == t0   # SLO clock untouched
    assert [q.pop().uid for _ in range(len(q))] == [0, 1, 2]


def test_queue_requests_unordered_view():
    q = AdmissionQueue()
    for i in range(3):
        q.push(_req(i))
    assert {r.uid for r in q.requests()} == {0, 1, 2}
    assert len(q.requests()) == 3
