"""Fault-isolated serving system tests (DESIGN.md §14).

The acceptance bar: under a scripted :class:`FaultPlan` — injected alloc
failures, arena corruption, staging drops, a NaN-poisoned noise stream —
the engine finishes every *healthy* request with tokens bitwise equal to
the fault-free run, fails only the targeted requests with structured
:class:`RequestError`\\ s, and (with a retry budget) recovers even those:
capacity faults replay the same stream exactly, quarantined rows get a
fresh stream. Corruption and staging faults are never errors at all — the
integrity check demotes them to cache misses and the engine recomputes
(cold resume), still bit-exact. ``cancel(uid)`` removes a request wherever
it lives; wall-time / round budgets bound runaways."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import (CircuitBreaker, FaultPlan, HostArena, HostTier,
                           Request, ServingEngine, StagingRing)
from repro.serving.faults import SEAMS, StagingFault

EPS_KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(np.asarray(req.prompt)[None].astype(np.int32),
                      req.new_tokens,
                      seq_ids=np.asarray([req.seq_id], np.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _traffic(cfg, rng_seed=3, n=4, lo=2, hi=7, new_lo=8, new_hi=12):
    rng = np.random.default_rng(rng_seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(lo, hi))),
                    new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


# -- harness units (no engine) ----------------------------------------------

def test_fault_plan_parse_and_deterministic_replay():
    plan = FaultPlan.parse("seed=7,alloc=@2;5,arena_corrupt=0.25,poison=3;9")
    assert plan.schedule["alloc"] == frozenset({2, 5})
    assert plan.rates["arena_corrupt"] == 0.25
    assert plan.seed == 7 and plan.poison_streams == frozenset({3, 9})
    # explicit indices fire exactly at the scripted invocations
    fires = [plan.fire("alloc") for _ in range(8)]
    assert fires == [False, False, True, False, False, True, False, False]
    assert plan.fired["alloc"] == 2 and plan.calls["alloc"] == 8
    # seeded rates replay bit-identically across plan instances (the CI
    # chaos job re-parses the same spec in every process)
    a = FaultPlan.parse("seed=7,arena_corrupt=0.25")
    b = FaultPlan.parse("seed=7,arena_corrupt=0.25")
    seq = [a.fire("arena_corrupt") for _ in range(400)]
    assert seq == [b.fire("arena_corrupt") for _ in range(400)]
    assert 0 < sum(seq) < 400          # the rate actually does something
    c = FaultPlan.parse("seed=8,arena_corrupt=0.25")
    assert seq != [c.fire("arena_corrupt") for _ in range(400)]
    # no plan / unknown seam
    assert FaultPlan.parse("") is None and FaultPlan.parse("  ") is None
    with pytest.raises(AssertionError):
        FaultPlan.parse("bogus_seam=@1")
    # a seam with no schedule never fires
    assert not any(plan.fire("stage_drop") for _ in range(50))
    assert plan.total_fired == 2


def test_fault_plan_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    assert FaultPlan.from_env() is None
    monkeypatch.setenv("REPRO_FAULT_PLAN", "seed=3,stage_drop=0.5,poison=4")
    plan = FaultPlan.from_env()
    assert plan.rates["stage_drop"] == 0.5
    assert plan.poison_streams == frozenset({4})
    assert set(plan.schedule) <= set(SEAMS)


def test_circuit_breaker_trip_cooldown_halfopen_cycle():
    br = CircuitBreaker(threshold=3, cooldown=4)
    # failures must be CONSECUTIVE to trip
    br.record_failure(); br.record_failure(); br.record_success()
    br.record_failure(); br.record_failure()
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    # open: denied for cooldown-1 ops, then the half-open probe passes
    assert [br.allow() for _ in range(3)] == [False, False, False]
    assert br.denied == 3
    assert br.allow() and br.state == "half_open"
    br.record_failure()                       # probe failed: re-open
    assert br.state == "open" and br.trips == 2
    for _ in range(3):
        br.allow()
    assert br.allow() and br.state == "half_open"
    br.record_success()                       # probe succeeded: re-close
    assert br.state == "closed"
    assert br.stats_export() == {"tier_state": "closed", "tier_tripped": 2,
                                 "tier_denied_ops": 6}


def test_arena_corruption_is_a_miss_never_an_error():
    seen = []
    a = HostArena(1 << 16, faults=FaultPlan(schedule={"arena_corrupt": (1,)}),
                  on_corruption=seen.append)
    blk = np.arange(32, dtype=np.float32).reshape(4, 8)
    assert a.put("k", [blk])
    np.testing.assert_array_equal(a.get("k")[0], blk)   # invocation 0: clean
    assert a.get("k") is None          # invocation 1: corrupted -> dropped
    assert seen == ["k"]
    assert a.stats.checksum_failures == 1
    assert not a.contains("k")         # corrupt bytes never served again
    # a PINNED corrupt entry is dropped too (a corrupt pin protects nothing)
    a2 = HostArena(1 << 16, faults=FaultPlan(schedule={"arena_corrupt": (0,)}),
                   on_corruption=seen.append)
    a2.put("p", [blk], pin=True)
    assert a2.get("p") is None and not a2.contains("p")
    a2.unpin("p")                      # owner's unpin stays a safe no-op
    # integrity off: the seam still fires but nothing verifies (A/B lane)
    a3 = HostArena(1 << 16, integrity=False,
                   faults=FaultPlan(schedule={"arena_corrupt": (0,)}))
    a3.put("k", [blk])
    assert a3.get("k") is not None and a3.stats.checksum_failures == 0


def test_tripped_tier_answers_every_probe_as_a_miss():
    t = HostTier(1 << 16, breaker=CircuitBreaker(threshold=1, cooldown=100))
    blk = np.ones((4, 8), np.float32)
    assert t.put_kv(0, 11, [blk]) and t.put_park(5, [blk])
    t.record_failure()                 # threshold=1: open immediately
    assert not t.put_kv(0, 12, [blk])
    assert t.get_kv(0, 11) is None and not t.has_kv(0, 11)
    assert t.kv_run(0, [11]) == 0 and t.take_park(5) is None
    assert not t.pin_kv(0, 11)
    # refcount hygiene is never breaker-gated
    t.unpin_kv(0, 11)
    assert t.drop_park(5)
    st = t.stats_export()
    assert st["tier_state"] == "open" and st["tier_tripped"] == 1
    assert st["tier_denied_ops"] >= 6


def test_staging_drop_raises_and_clear_leaves_nothing():
    ring = StagingRing(depth=2,
                       faults=FaultPlan(schedule={"stage_drop": (1,)}))
    blk = np.zeros((4, 8), np.float32)
    ring.stage(("t0", 0), [blk])
    with pytest.raises(StagingFault):
        ring.stage(("t1", 1), [blk])
    assert ring.clear() == 1           # the in-flight upload is dropped
    assert ring.take() is None         # nothing staged for a later caller
    st = ring.stats_export()
    assert st["h2d_dropped"] == 1


# -- engine: quarantine + retry (the tentpole acceptance) --------------------

def test_injected_alloc_fault_fails_only_offending_request(qwen):
    """The first block allocation dies (seam ``alloc`` @0) during the first
    admission: with no retry budget that request finishes with a structured
    retryable 'admission' error, every other request's tokens are bitwise
    those of the fault-free engine AND of solo runs."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)

    def run(faults, retries=0):
        eng = ServingEngine(cfg, params, faults=faults,
                            request_retries=retries, **kw)
        for r in _traffic(cfg):
            assert eng.submit(r)
        return {r.uid: r for r in eng.run()}, eng

    ref, _ = run(FaultPlan())          # empty plan == fault-free
    got, eng = run(FaultPlan(schedule={"alloc": (0,)}))
    assert eng.faults.fired == {"alloc": 1}
    assert eng.export_metrics()["faults_injected"] == 1
    failed = [r for r in got.values() if not r.ok]
    assert len(failed) == 1
    err = failed[0].error
    assert err.code == "admission" and err.retryable and err.attempts == 1
    assert "MemoryError" in err.detail and failed[0].result is None
    assert eng.metrics.requests_failed == 1
    for uid, r in got.items():
        if r.ok:
            np.testing.assert_array_equal(
                r.result, ref[uid].result,
                err_msg=f"healthy request {uid} diverged under faults")
            np.testing.assert_array_equal(
                r.result, _solo(cfg, params, r, 4, 48))


def test_retry_after_capacity_fault_is_bit_exact(qwen):
    """A retryable capacity fault under the retry budget replays the SAME
    noise stream from a fresh admission — chunked-prefill invariance makes
    the retried run bitwise identical to the never-faulted one."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    ref = ServingEngine(cfg, params, **kw)
    for r in _traffic(cfg):
        ref.submit(r)
    ref_res = {r.uid: r.result for r in ref.run()}

    eng = ServingEngine(cfg, params, request_retries=1,
                        faults=FaultPlan(schedule={"alloc": (0, 3)}), **kw)
    reqs = _traffic(cfg)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.ok for r in done), [str(r.error) for r in done if r.error]
    assert eng.metrics.retries >= 1
    assert sum(r.retries for r in reqs) >= 1
    for r in done:
        np.testing.assert_array_equal(
            r.result, ref_res[r.uid],
            err_msg=f"retried request {r.uid} lost exactness")


def test_poisoned_stream_is_quarantined_rest_of_batch_exact(qwen):
    """A NaN-poisoned noise stream (seam ``poison``, injected at the LOGITS
    level on device) trips the packed-stats health bit: that row alone is
    failed with code 'nonfinite', its blocks released, and the OTHER rows of
    the same device batch finish bitwise equal to the fault-free run —
    the §14 quarantine contract."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    ref = ServingEngine(cfg, params, **kw)
    for r in _traffic(cfg):
        ref.submit(r)
    ref_res = {r.uid: r.result for r in ref.run()}

    eng = ServingEngine(cfg, params, faults=FaultPlan(poison_streams=(2,)),
                        **kw)
    for r in _traffic(cfg):
        eng.submit(r)
    got = {r.uid: r for r in eng.run()}
    bad = got[2]
    assert not bad.ok and bad.result is None
    assert bad.error.code == "nonfinite" and bad.error.retryable
    assert "health bits" in bad.error.detail
    assert eng.metrics.requests_failed == 1
    for uid in (0, 1, 3):
        assert got[uid].ok
        np.testing.assert_array_equal(
            got[uid].result, ref_res[uid],
            err_msg=f"request {uid} shared a batch with the poisoned row")


def test_quarantine_retry_uses_a_fresh_noise_stream(qwen):
    """With a retry budget, the quarantined request re-admits on a FRESH
    noise stream (replaying the poisoned one would fail identically) and
    completes; its tokens match a solo run keyed by the new stream."""
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    eng = ServingEngine(cfg, params, request_retries=1,
                        faults=FaultPlan(poison_streams=(2,)), **kw)
    reqs = _traffic(cfg)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.ok for r in done), [str(r.error) for r in done if r.error]
    poisoned = next(r for r in reqs if r.uid == 2)
    assert poisoned.retries == 1
    assert poisoned.noise_seed is not None
    assert poisoned.seq_id not in eng.faults.poison_streams
    for r in done:                     # incl. the re-streamed row
        np.testing.assert_array_equal(
            r.result, _solo(cfg, params, r, 4, 48),
            err_msg=f"request {r.uid} diverged from its solo run")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "jamba-1.5-large-398b"])
def test_corrupted_park_falls_back_to_cold_resume_exact(arch):
    """Every arena read corrupted (rate 1.0): parked payloads and pinned
    prefix entries all demote to misses, resume goes down the cold
    recompute path (chunk decomposition is bitwise-invariant), and the
    preempted request still matches its undisturbed run — for attention
    AND the recurrent hybrid (snapshot gone -> rebuild from zero)."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=1, window_max=4, max_len=96, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 9)
    hi_prompt = rng.integers(0, cfg.vocab, 3)

    def run(faults):
        eng = ServingEngine(cfg, params, faults=faults, **kw)
        lo = Request(uid=0, prompt=prompt, new_tokens=40, priority=5)
        hi = Request(uid=1, prompt=hi_prompt, new_tokens=6, priority=0)
        eng.submit(lo)
        eng.step()
        eng.submit(hi)                 # higher priority -> park lo
        done = {r.uid: r for r in eng.run()}
        assert eng.metrics.preemptions == 1
        return done, eng

    ref, _ = run(FaultPlan())
    got, eng = run(FaultPlan(rates={"arena_corrupt": 1.0}))
    assert all(r.ok for r in got.values())
    assert eng.metrics.resume_recomputes >= 1
    m = eng.export_metrics()
    assert m["checksum_failures"] >= 1
    for uid in ref:
        np.testing.assert_array_equal(
            got[uid].result, ref[uid].result,
            err_msg=f"request {uid} diverged across the cold resume")


def test_staging_and_put_faults_stay_invisible_to_tokens(qwen):
    """``arena_put`` rejections (spill/park lost) and ``stage_drop`` ring
    deaths are pure de-optimizations: same preemption traffic, every token
    bitwise equal, failures only visible in the §14 counters."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=96, eps_key=EPS_KEY,
              block_size=4, adaptive=False, host_cache_mb=8)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, 13)
    hi_prompt = rng.integers(0, cfg.vocab, 3)

    def run(faults):
        eng = ServingEngine(cfg, params, faults=faults, **kw)
        lo = Request(uid=0, prompt=prompt, new_tokens=40, priority=5)
        hi = Request(uid=1, prompt=hi_prompt, new_tokens=6, priority=0)
        eng.submit(lo)
        eng.step()
        eng.submit(hi)
        done = {r.uid: r for r in eng.run()}
        assert eng.metrics.preemptions == 1
        return done, eng

    ref, _ = run(FaultPlan())
    got, eng = run(FaultPlan(rates={"arena_put": 1.0, "stage_drop": 1.0}))
    assert all(r.ok for r in got.values())
    assert eng.faults.total_fired >= 1
    for uid in ref:
        np.testing.assert_array_equal(got[uid].result, ref[uid].result)


# -- lifecycle: cancel / runaway bounds / validation -------------------------

def test_cancel_queued_running_and_parked(qwen):
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=96, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    eng = ServingEngine(cfg, params, **kw)
    rng = np.random.default_rng(6)
    lo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 5), new_tokens=40,
                 priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=6,
                 priority=0)
    queued = Request(uid=2, prompt=rng.integers(0, cfg.vocab, 4),
                     new_tokens=8, priority=9)
    eng.submit(lo)
    eng.step()
    eng.submit(hi)                     # parks lo (priority preemption)
    eng.submit(queued)
    eng.step()
    assert eng.metrics.preemptions == 1 and 0 in eng.parked
    assert not eng.cancel(99)          # unknown uid
    assert eng.cancel(0)               # parked: queue entry + park discarded
    assert 0 not in eng.parked
    assert eng.cancel(2)               # still queued, never admitted
    running = next(b for b in range(1) if eng.slots[b] is not None)
    assert eng.slots[running].uid == 1
    assert eng.cancel(1)               # running: slot freed immediately
    assert eng.slots[running] is None
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {0, 1, 2}
    assert all(r.error.code == "cancelled" and r.result is None
               for r in done.values())
    m = eng.export_metrics()
    assert m["requests_cancelled"] == 3 and m["parked_requests"] == 0
    assert m["blocks_in_use"] == 0     # cancelled rows released everything


def test_cancelled_neighbor_leaves_survivors_exact(qwen):
    cfg, params = qwen
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    ref = ServingEngine(cfg, params, **kw)
    for r in _traffic(cfg, n=3):
        ref.submit(r)
    ref_res = {r.uid: r.result for r in ref.run()}

    eng = ServingEngine(cfg, params, **kw)
    for r in _traffic(cfg, n=3):
        eng.submit(r)
    eng.step()
    assert eng.cancel(0)               # mid-flight, batch-mate of uid 1
    got = {r.uid: r for r in eng.run()}
    assert got[0].error.code == "cancelled"
    for uid in (1, 2):
        np.testing.assert_array_equal(got[uid].result, ref_res[uid])


def test_round_budget_and_wall_time_abort_runaways(qwen):
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=64, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab, 4)

    eng = ServingEngine(cfg, params, max_request_rounds=1, **kw)
    eng.submit(Request(uid=0, prompt=prompt, new_tokens=32))
    done = eng.run()
    assert done[0].error is not None and done[0].error.code == "round_budget"
    assert not done[0].error.retryable  # determinism: a retry would loop

    eng = ServingEngine(cfg, params, max_request_seconds=0.0, **kw)
    eng.submit(Request(uid=0, prompt=prompt, new_tokens=32))
    done = eng.run()
    assert done[0].error is not None and done[0].error.code == "timeout"
    assert eng.export_metrics()["requests_failed"] == 1


def test_submit_validation_rejects_malformed_requests(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=32,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    cases = [
        (Request(uid=0, prompt=np.zeros(0, np.int64), new_tokens=4),
         "empty_prompt"),
        (Request(uid=1, prompt=np.asarray([1, 2]), new_tokens=0),
         "bad_new_tokens"),
        (Request(uid=2, prompt=np.asarray([1, 2]), new_tokens=10_000),
         "too_long"),
        (Request(uid=3, prompt=np.asarray([1, cfg.vocab]), new_tokens=4),
         "token_out_of_range"),
        (Request(uid=4, prompt=np.asarray([-1, 2]), new_tokens=4),
         "token_out_of_range"),
    ]
    for req, code in cases:
        assert eng.submit(req) is False
        assert req.error.code == code and not req.ok, (req.uid, req.error)
    assert len(eng.queue) == 0         # nothing malformed was admitted
    done = eng.run()
    assert {r.uid for r in done} == {0, 1, 2, 3, 4}
    assert eng.export_metrics()["requests_rejected"] == 5


# -- interleaved chaos schedules (satellite) ---------------------------------

CHAOS_RATES = {"arena_corrupt": 0.25, "arena_put": 0.25, "stage_drop": 0.25}


def _chaos_schedule(cfg, params, plan, batch=2, max_len=64):
    """Drive an engine through an arbitrary submit/step/preempt/migrate/
    cancel interleaving under exactness-preserving fault rates, then check
    every non-cancelled request against its solo run."""
    eng = ServingEngine(cfg, params, batch=batch, window_max=4,
                        max_len=max_len, eps_key=EPS_KEY, block_size=4,
                        adaptive=False, host_cache_mb=8,
                        faults=FaultPlan(rates=CHAOS_RATES, seed=11))
    uid = 0
    for op, arg in plan:
        if op == "submit":
            L_p, new = arg
            rng = np.random.default_rng(100 + uid)
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L_p),
                               new_tokens=new))
            uid += 1
        elif op == "step":
            if eng.queue or any(s is not None for s in eng.slots):
                eng.step()
        elif op == "preempt":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            if occ:
                eng.preempt_slot(occ[arg % len(occ)])
        elif op == "migrate":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            free = [b for b in range(batch) if eng.slots[b] is None]
            if occ and free:
                eng.migrate_slot(occ[arg % len(occ)],
                                 free[arg % len(free)])
        elif op == "cancel":
            live = [r.uid for r in eng.queue.requests()] + [
                s.uid for s in eng.slots if s is not None]
            if live:
                eng.cancel(live[arg % len(live)])
    done = eng.run()
    assert len(done) == uid            # every submission is accounted for
    cancelled = [r for r in done if r.error is not None]
    assert all(r.error.code == "cancelled" for r in cancelled)
    assert len(cancelled) == eng.metrics.requests_cancelled
    for req in done:
        if req.error is None:
            np.testing.assert_array_equal(
                req.result,
                _solo(cfg, params, req, 4, max_len),
                err_msg=f"request {req.uid} diverged under chaos schedule")
    # every slot left fully clean
    assert np.asarray(eng.seq_ids).tolist() == [0] * batch
    assert np.asarray(eng.n).tolist() == [1] * batch
    return eng


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_interleaved_cancel_fault_preempt_migrate_exact(arch):
    """Deterministic chaos interleavings across the mixer zoo: cancels,
    parks, slot moves, and seeded fault rates on every host-tier seam —
    survivors stay bitwise equal to solo runs."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    # uid 0 wants 40 tokens: one rounds_per_sync dispatch cannot finish it,
    # so the first preempt always finds it running (every arch)
    plan = [("submit", (3, 40)), ("submit", (5, 6)), ("step", None),
            ("preempt", 0), ("submit", (2, 10)), ("step", None),
            ("cancel", 1), ("migrate", 1), ("step", None),
            ("submit", (7, 5)), ("preempt", 1), ("cancel", 0),
            ("step", None), ("migrate", 0), ("submit", (4, 6))]
    eng = _chaos_schedule(cfg, params, plan)
    assert eng.metrics.preemptions >= 1
    assert eng.metrics.requests_cancelled >= 1
    assert eng.faults.total_fired >= 1


def test_interleaved_chaos_schedules_hypothesis(qwen):
    """Property form: random interleavings of submit / step / preempt /
    migrate / cancel under seeded fault rates keep survivors solo-exact."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = qwen

    op = st.one_of(
        st.tuples(st.just("submit"),
                  st.tuples(st.integers(1, 8), st.integers(2, 8))),
        st.tuples(st.just("step"), st.none()),
        st.tuples(st.just("preempt"), st.integers(0, 3)),
        st.tuples(st.just("migrate"), st.integers(0, 3)),
        st.tuples(st.just("cancel"), st.integers(0, 3)),
    )

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(op, min_size=2, max_size=8))
    def run_plan(plan):
        if not any(p[0] == "submit" for p in plan):
            plan = [("submit", (2, 4))] + plan
        _chaos_schedule(cfg, params, plan)

    run_plan()
