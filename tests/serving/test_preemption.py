"""Preemption + migration system tests (DESIGN.md §12).

The acceptance bar is the regression net the scheduling layer is judged
against: any interleaving of admit / finish / clear / preempt / resume /
migrate must emit tokens bitwise-equal to per-request solo
``PredictiveSampler.generate`` runs — across attention, sliding-window
local, MLA, and recurrent-hybrid mixers (the hybrid exercises parking and
moving the un-paged per-slot state next to the block payloads)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM
from repro.serving import Request, ServingEngine

EPS_KEY = jax.random.PRNGKey(9)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(cfg, params, req, window, max_len):
    s = PredictiveSampler(cfg, params, window=window, max_len=max_len,
                          eps_key=EPS_KEY)
    t, _ = s.generate(jnp.asarray(np.asarray(req.prompt)[None], jnp.int32),
                      req.new_tokens,
                      seq_ids=jnp.asarray([req.seq_id], jnp.int32))
    return np.asarray(t[0, :len(req.prompt) + req.new_tokens])


def _assert_all_exact(cfg, params, done, window, max_len):
    assert done, "no requests completed"
    for req in done:
        np.testing.assert_array_equal(
            req.result, _solo(cfg, params, req, window, max_len),
            err_msg=f"request {req.uid} diverged from its solo run")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v3-671b",
                                  "jamba-1.5-large-398b"])
def test_forced_preempt_and_migrate_bit_exact_across_mixers(arch):
    """Mid-flight, force a slot migration AND a preemption (park +
    spill + exact resume) and require bitwise token equality with an
    undisturbed engine and with solo runs."""
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    kw = dict(batch=2, window_max=4, max_len=48, eps_key=EPS_KEY,
              block_size=4, adaptive=False)

    def traffic(eng, disturb):
        rng = np.random.default_rng(3)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab,
                                            size=int(rng.integers(2, 7))),
                        new_tokens=int(rng.integers(8, 12)))
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.step()
        if disturb:
            occ = [b for b in range(2) if eng.slots[b] is not None]
            free = [b for b in range(2) if eng.slots[b] is None]
            if free:
                eng.migrate_slot(occ[0], free[0])
            occ = [b for b in range(2) if eng.slots[b] is not None]
            eng.preempt_slot(occ[-1])
        return eng.run()

    ref = {r.uid: r.result
           for r in traffic(ServingEngine(cfg, params, **kw), False)}
    eng = ServingEngine(cfg, params, **kw)
    done = traffic(eng, True)
    assert eng.metrics.preemptions >= 1 and eng.metrics.resumes >= 1
    for req in done:
        np.testing.assert_array_equal(
            req.result, ref[req.uid],
            err_msg=f"request {req.uid}: disturbed engine diverged")
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_preemption_preserves_round_counts(qwen):
    """Restoring the n/cand snapshot makes even the ARM-call count of a
    preempted request identical to its uninterrupted run (candidates gate
    acceptance; a reset window would change the round schedule)."""
    cfg, params = qwen
    kw = dict(batch=1, window_max=4, max_len=96, eps_key=EPS_KEY,
              block_size=4, adaptive=False)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 5)

    eng = ServingEngine(cfg, params, **kw)
    lo = Request(uid=0, prompt=prompt, new_tokens=64, priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=6,
                 priority=0)
    eng.submit(lo)
    eng.step()
    eng.submit(hi)                  # higher priority -> evicts lo
    done = eng.run()
    assert [r.uid for r in done] == [1, 0]
    assert lo.preemptions == 1 and eng.metrics.blocks_parked >= 1

    ref = ServingEngine(cfg, params, **kw)
    lo2 = Request(uid=0, prompt=prompt, new_tokens=64, priority=5)
    ref.submit(lo2)
    ref.run()
    np.testing.assert_array_equal(lo.result, lo2.result)
    assert lo.calls_used == lo2.calls_used
    _assert_all_exact(cfg, params, done, window=4, max_len=96)


def test_progress_floor_protects_nearly_done_victims(qwen):
    """A victim past ``preempt_floor`` of its generation target must not be
    evicted — the high-priority request waits for the slot instead."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=48,
                        eps_key=EPS_KEY, block_size=4, adaptive=False,
                        preempt_floor=0.0)      # every victim protected
    rng = np.random.default_rng(2)
    lo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4), new_tokens=24,
                 priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=0)
    eng.submit(lo)
    eng.step()
    eng.submit(hi)
    done = eng.run()
    assert eng.metrics.preemptions == 0
    assert [r.uid for r in done] == [0, 1]      # lo ran to completion
    _assert_all_exact(cfg, params, done, window=4, max_len=48)


def test_parked_prefix_blocks_rehit_on_resume(qwen):
    """Spill leaves hashed prompt blocks cached-free: an exact resume must
    re-hit them instead of re-uploading the parked copies."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, batch=1, window_max=4, max_len=96,
                        eps_key=EPS_KEY, block_size=4, adaptive=False)
    rng = np.random.default_rng(4)
    lo = Request(uid=0, prompt=rng.integers(0, cfg.vocab, 13), new_tokens=48,
                 priority=5)
    hi = Request(uid=1, prompt=rng.integers(0, cfg.vocab, 3), new_tokens=4,
                 priority=0)
    eng.submit(lo)
    eng.step()                      # admit + publish lo's 3 full blocks
    assert eng.metrics.preemptions == 0
    eng.submit(hi)
    done = eng.run()
    assert eng.metrics.preemptions == 1
    # resume found the 3 published prompt blocks still cached
    assert lo.prefix_hit_blocks >= 3
    _assert_all_exact(cfg, params, done, window=4, max_len=96)


def _interleaved_schedule(cfg, params, plan, batch=2, max_len=64):
    """Drive an engine through an arbitrary admit/step/preempt/migrate/
    finish interleaving, then check every finished request against solo."""
    eng = ServingEngine(cfg, params, batch=batch, window_max=4,
                        max_len=max_len, eps_key=EPS_KEY, block_size=4,
                        adaptive=False)
    uid = 0
    for op, arg in plan:
        if op == "submit":
            L_p, new = arg
            rng = np.random.default_rng(100 + uid)
            eng.submit(Request(uid=uid,
                               prompt=rng.integers(0, cfg.vocab, L_p),
                               new_tokens=new))
            uid += 1
        elif op == "step":
            if eng.queue or any(s is not None for s in eng.slots):
                eng.step()
        elif op == "preempt":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            if occ:
                eng.preempt_slot(occ[arg % len(occ)])
        elif op == "migrate":
            occ = [b for b in range(batch) if eng.slots[b] is not None]
            free = [b for b in range(batch) if eng.slots[b] is None]
            if occ and free:
                eng.migrate_slot(occ[arg % len(occ)],
                                 free[arg % len(free)])
    done = eng.run()
    assert len(done) == uid
    _assert_all_exact(cfg, params, done, window=4, max_len=max_len)
    # every slot left fully clean (satellite: seq_ids zeroed with the row)
    assert np.asarray(eng.seq_ids).tolist() == [0] * batch
    assert np.asarray(eng.n).tolist() == [1] * batch
    return eng


def test_interleaved_admit_finish_clear_preempt_migrate_exact(qwen):
    """Deterministic interleavings (always run, no hypothesis needed):
    slot churn + parking + slot moves in one schedule."""
    cfg, params = qwen
    plan = [("submit", (3, 8)), ("submit", (5, 6)), ("step", None),
            ("preempt", 0), ("submit", (2, 10)), ("step", None),
            ("migrate", 1), ("step", None), ("submit", (7, 5)),
            ("preempt", 1), ("step", None), ("migrate", 0)]
    eng = _interleaved_schedule(cfg, params, plan)
    assert eng.metrics.preemptions >= 1


def test_interleaved_schedules_hypothesis(qwen):
    """Property form of the same net: random interleavings of admit /
    step / preempt / migrate stay bitwise-equal to solo generate."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = qwen

    op = st.one_of(
        st.tuples(st.just("submit"),
                  st.tuples(st.integers(1, 8), st.integers(2, 8))),
        st.tuples(st.just("step"), st.none()),
        st.tuples(st.just("preempt"), st.integers(0, 3)),
        st.tuples(st.just("migrate"), st.integers(0, 3)),
    )

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(st.lists(op, min_size=2, max_size=8))
    def run_plan(plan):
        if not any(p[0] == "submit" for p in plan):
            plan = [("submit", (2, 4))] + plan
        _interleaved_schedule(cfg, params, plan)

    run_plan()
