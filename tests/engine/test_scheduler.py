"""Continuous batcher: ragged requests complete, results match solo runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import ContinuousBatcher, PredictiveSampler, Request
from repro.models.transformer import TransformerLM


def test_batcher_drains_and_matches_solo():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    ek = jax.random.PRNGKey(9)
    sampler = PredictiveSampler(cfg, params, window=4, max_len=64, eps_key=ek)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab, size=rng.integers(2, 6)),
                    new_tokens=int(rng.integers(4, 10)))
            for i in range(5)]

    batcher = ContinuousBatcher(sampler, batch=3)
    for r in reqs:
        batcher.submit(Request(r.uid, r.prompt.copy(), r.new_tokens))
    done = batcher.run()
    assert len(done) == 5

    # each result must equal a solo (batch-1) run with the same per-slot
    # noise stream... noise is per-(slot, position), so compare against a
    # solo sampler pinned to the same slot via a batch of 1? The scheduler
    # admits uid order -> slot order is deterministic; we instead verify
    # structural invariants: prompt preserved, correct length, finite calls.
    by_uid = {r.uid: r for r in done}
    for r in reqs:
        out = by_uid[r.uid].result
        assert out is not None
        assert len(out) == len(r.prompt) + r.new_tokens
        np.testing.assert_array_equal(out[:len(r.prompt)], r.prompt)
        assert by_uid[r.uid].calls_used >= 1


def test_batcher_beats_static_batching_on_ragged_lengths():
    """With very ragged target lengths, continuous batching should finish in
    fewer total rounds than the longest request would cost a static batch
    that waits for stragglers at each length."""
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(1), cfg)
    params = dict(params)
    params["embed"] = {"table": params["embed"]["table"] * 6.0}  # peaked
    sampler = PredictiveSampler(cfg, params, window=4, max_len=96,
                                eps_key=jax.random.PRNGKey(3))
    batcher = ContinuousBatcher(sampler, batch=2)
    lens = [30, 6, 6, 6]
    for i, L in enumerate(lens):
        batcher.submit(Request(i, np.zeros(2, np.int64), L))
    done = batcher.run()
    assert len(done) == 4
    total_rounds = int(np.asarray(batcher.state.rounds))
    assert total_rounds < sum(lens)  # speculative + continuous < 1 call/token
