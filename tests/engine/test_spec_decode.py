"""Serving-engine tests: exactness of windowed predictive decode vs ancestral
(W=1), call savings on predictable streams, per-arch family coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine import PredictiveSampler
from repro.models.transformer import TransformerLM

ARCH_SAMPLE = ["qwen3-1.7b", "deepseek-v3-671b", "rwkv6-7b",
               "jamba-1.5-large-398b", "gemma3-1b"]


def _make(arch, key=0):
    cfg = get_config(arch, reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(key), cfg)
    return cfg, params


@pytest.mark.parametrize("arch", ARCH_SAMPLE)
def test_window_exactness_vs_ancestral(arch):
    """W=8 predictive decode must emit bit-identical tokens to W=1 ancestral
    decode under the same eps stream — the paper's exactness claim, per
    architecture family (attention / MLA+MoE / RWKV / Mamba-hybrid / SWA)."""
    cfg, params = _make(arch)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    ek = jax.random.PRNGKey(42)
    new = 12

    s1 = PredictiveSampler(cfg, params, window=1, max_len=64, eps_key=ek)
    t1, st1 = s1.generate(prompts, new)
    s8 = PredictiveSampler(cfg, params, window=8, max_len=64, eps_key=ek)
    t8, st8 = s8.generate(prompts, new)

    np.testing.assert_array_equal(np.asarray(t1[:, :16]),
                                  np.asarray(t8[:, :16]))
    assert st1["rounds"] == new                      # ancestral: 1 call/token
    assert st8["rounds"] <= st1["rounds"]


def test_call_savings_on_peaked_model():
    """A near-deterministic LM (tiny logit temperature via scaled embeddings)
    must accept multi-token runs -> far fewer calls than tokens."""
    cfg, params = _make("qwen3-1.7b", key=3)
    # sharpen: scale the tied embedding table (peaks the output softmax)
    params = dict(params)
    params["embed"] = {"table": params["embed"]["table"] * 6.0}
    prompts = jnp.zeros((2, 2), jnp.int32)
    s = PredictiveSampler(cfg, params, window=8, max_len=96,
                          eps_key=jax.random.PRNGKey(0))
    toks, st = s.generate(prompts, 48)
    assert st["rounds"] < 48, st
    assert st["mean_accept"] > 1.0


def test_per_seq_calls_leq_rounds():
    cfg, params = _make("gemma-2b")
    prompts = jax.random.randint(jax.random.PRNGKey(5), (3, 3), 0, cfg.vocab)
    s = PredictiveSampler(cfg, params, window=4, max_len=64,
                          eps_key=jax.random.PRNGKey(1))
    _, st = s.generate(prompts, 10)
    assert (st["per_seq_calls"] <= st["rounds"]).all()


def test_forecast_heads_path_runs_and_is_exact():
    cfg, params = _make("deepseek-v3-671b")   # has forecast/MTP heads
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 3), 0, cfg.vocab)
    ek = jax.random.PRNGKey(7)
    s_ref = PredictiveSampler(cfg, params, window=1, max_len=48, eps_key=ek)
    t_ref, _ = s_ref.generate(prompts, 8)
    s_fc = PredictiveSampler(cfg, params, window=6, max_len=48, eps_key=ek,
                             use_forecast_heads=True)
    t_fc, st = s_fc.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t_ref[:, :11]),
                                  np.asarray(t_fc[:, :11]))


@pytest.mark.parametrize("arch", ["musicgen-large", "internvl2-1b",
                                  "dbrx-132b", "mistral-large-123b",
                                  "gemma-2b"])
def test_window_exactness_remaining_archs(arch):
    """Exactness for the rest of the zoo (audio/VLM/MoE/dense families)."""
    cfg, params = _make(arch, key=11)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 3), 0, cfg.vocab)
    ek = jax.random.PRNGKey(21)
    t1, _ = PredictiveSampler(cfg, params, window=1, max_len=48,
                              eps_key=ek).generate(prompts, 8)
    t6, _ = PredictiveSampler(cfg, params, window=6, max_len=48,
                              eps_key=ek).generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t1[:, :11]),
                                  np.asarray(t6[:, :11]))


def test_verify_kernel_path_is_exact():
    """The Pallas spec_verify fast path must be bit-identical to the jnp
    verify (kernel <-> engine integration)."""
    cfg, params = _make("qwen3-1.7b", key=5)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0, cfg.vocab)
    ek = jax.random.PRNGKey(33)
    t_ref, s_ref = PredictiveSampler(
        cfg, params, window=6, max_len=48, eps_key=ek).generate(prompts, 10)
    t_k, s_k = PredictiveSampler(
        cfg, params, window=6, max_len=48, eps_key=ek,
        use_verify_kernel=True).generate(prompts, 10)
    np.testing.assert_array_equal(np.asarray(t_ref[:, :14]),
                                  np.asarray(t_k[:, :14]))
    assert s_ref["rounds"] == s_k["rounds"]


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "rwkv6-7b"])
def test_low_memory_serve_step_equivalence(arch):
    """§Perf C4: the two-pass freeze-masked serve step must produce the same
    tokens, accepts AND recurrent states as the per-position path."""
    import jax.numpy as jnp
    from repro.launch.serve import make_serve_step

    cfg, params = _make(arch, key=13)
    B, W, S = 2, 5, 32
    cache = TransformerLM.init_cache(cfg, B, S, dtype=jnp.float32)
    # advance the cache a few tokens first so states are non-trivial
    toks0 = jax.random.randint(jax.random.PRNGKey(0), (B, 4), 0, cfg.vocab)
    _, _, nc = TransformerLM.decode_window(params, cfg, toks0, cache,
                                           jnp.zeros((B,), jnp.int32))
    cache = TransformerLM.select_states(cfg, nc, jnp.full((B,), 4,
                                                          jnp.int32))
    cand = jax.random.randint(jax.random.PRNGKey(1), (B, W), 0, cfg.vocab)
    clen = jnp.full((B,), 4, jnp.int32)
    eps = jax.random.gumbel(jax.random.PRNGKey(2), (B, W, cfg.vocab))

    out1, a1, c1 = jax.jit(make_serve_step(cfg, W))(params, cand, cache,
                                                    clen, eps)
    out2, a2, c2 = jax.jit(make_serve_step(cfg, W, low_memory=True))(
        params, cand, cache, clen, eps)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    for x, y in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-4, atol=2e-5)
