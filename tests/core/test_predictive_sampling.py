"""Exactness + call-count tests for predictive sampling (Algorithms 1 & 2).

A tiny random "ARM" with strict triangular dependence serves as oracle: its
logits at position p are a fixed nonlinear function of x[:p]. Exactness of
predictive sampling must hold for ANY such ARM — this is the paper's claim 3).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import predictive_sampling as ps
from repro.core import reparam


def make_toy_arm(key, d, K, hdim=16, temp=1.0):
    """Random triangular ARM: logits[p] = MLP(cumsum of embedded x[<p])."""
    k1, k2, k3 = jax.random.split(key, 3)
    emb = jax.random.normal(k1, (K, hdim)) * 0.5
    w1 = jax.random.normal(k2, (hdim, hdim)) * 0.5
    w2 = jax.random.normal(k3, (hdim, K)) * 0.5

    def arm_fn(x):  # x: (B, d) int
        e = emb[x]  # (B, d, hdim)
        # shift right: position p sees strict prefix
        e = jnp.pad(e, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        csum = jnp.cumsum(e, axis=1) / jnp.sqrt(1.0 + jnp.arange(x.shape[1]))[None, :, None]
        h = jnp.tanh(csum @ w1)
        logits = (h @ w2) / temp
        return logits, h

    return arm_fn


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 2**31 - 1), st.integers(2, 24), st.integers(2, 8),
       st.integers(1, 4))
def test_fpi_exactness(seed, d, K, B):
    """FPI output is bit-identical to ancestral sampling under shared eps."""
    key = jax.random.PRNGKey(seed)
    ka, ke = jax.random.split(key)
    arm_fn = make_toy_arm(ka, d, K)
    eps = reparam.gumbel(ke, (B, d, K))

    x_ref, ref_stats = ps.ancestral_sample(arm_fn, eps)
    x_fpi, fpi_stats = ps.fixed_point_sample(arm_fn, eps)
    x_alg1, alg1_stats = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)

    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_fpi))
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_alg1))
    assert int(ref_stats.arm_calls) == d
    assert int(fpi_stats.arm_calls) <= d + 1
    assert int(alg1_stats.arm_calls) <= d
    # Alg 1 vs Alg 2 call counts agree within one observation pass
    assert abs(int(fpi_stats.arm_calls) - int(alg1_stats.arm_calls)) <= 1


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_baseline_forecasters_exactness(seed):
    """zeros / predict-last forecasts change call counts, never samples."""
    key = jax.random.PRNGKey(seed)
    ka, ke = jax.random.split(key)
    d, K, B = 16, 4, 2
    arm_fn = make_toy_arm(ka, d, K)
    eps = reparam.gumbel(ke, (B, d, K))
    x_ref, _ = ps.ancestral_sample(arm_fn, eps)
    for fc in (ps.zeros_forecast, ps.predict_last_forecast):
        x, stats = ps.predictive_sample(arm_fn, fc, eps)
        np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x))
        assert int(stats.arm_calls) <= d


def test_weakly_coupled_arm_converges_fast():
    """An ARM whose conditionals depend only weakly on preceding values
    (the regime the paper exploits: 'may converge much faster if variables do
    not depend strongly on adjacent previous variables') needs << d calls."""
    key = jax.random.PRNGKey(0)
    ka, kb, ke = jax.random.split(key, 3)
    d, K, B = 32, 4, 2
    bias = 8.0 * jax.random.normal(kb, (d, K))  # strong positional prior
    weak = make_toy_arm(ka, d, K)

    def arm_fn(x):
        logits, h = weak(x)
        return 0.05 * logits + bias[None], h

    eps = reparam.gumbel(ke, (B, d, K))
    x, stats = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    x_ref, _ = ps.ancestral_sample(arm_fn, eps)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x))
    assert int(stats.arm_calls) < d // 4


def test_converge_iter_monotone_and_bounded():
    key = jax.random.PRNGKey(7)
    ka, ke = jax.random.split(key)
    d, K, B = 20, 3, 3
    arm_fn = make_toy_arm(ka, d, K)
    eps = reparam.gumbel(ke, (B, d, K))
    x, stats = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    conv = np.asarray(stats.converge_iter)
    assert (conv >= 1).all() and (conv <= int(stats.arm_calls)).all()
    # valid prefix only grows: converge iterations are monotone nondecreasing
    assert (np.diff(conv, axis=1) >= 0).all()
    # per-sample <= batch-level calls
    assert (np.asarray(stats.per_sample_calls) <= int(stats.arm_calls)).all()


def test_without_reparametrization_no_fixed_point_speedup():
    """Paper Table 3: removing reparametrization (resampling fresh noise per
    iteration) destroys convergence — forecasts stop matching outputs."""
    key = jax.random.PRNGKey(0)
    ka, ke = jax.random.split(key)
    d, K, B = 24, 8, 2
    arm_fn = make_toy_arm(ka, d, K, temp=1.5)  # high-entropy
    eps = reparam.gumbel(ke, (B, d, K))
    _, stats_shared = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)

    # adversarial variant: forecast with DIFFERENT noise than the verifier,
    # emulating "most likely value according to P_F" mismatching the sampler.
    eps2 = reparam.gumbel(jax.random.PRNGKey(99), (B, d, K))

    def bad_forecast(x, h, prev_out, eps_, i):
        # prev_out was computed under eps; re-argmax under eps2 to de-correlate
        return prev_out * 0  # degenerate: like no-reparam, rarely matches
    _, stats_bad = ps.predictive_sample(arm_fn, bad_forecast, eps)
    assert int(stats_bad.arm_calls) >= int(stats_shared.arm_calls)
