"""Tests for the Gumbel-max reparametrization and posterior noise (App. B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import reparam


def test_reparam_argmax_matches_categorical_distribution():
    """Gumbel-max samples must follow softmax(logits)."""
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([2.0, 0.0, -1.0, 1.0])
    n = 20000
    eps = reparam.gumbel(key, (n, 4))
    xs = reparam.reparam_argmax(jnp.broadcast_to(logits, (n, 4)), eps)
    freq = np.bincount(np.asarray(xs), minlength=4) / n
    probs = np.asarray(jax.nn.softmax(logits))
    np.testing.assert_allclose(freq, probs, atol=0.02)


def test_reparam_argmax_shift_invariance():
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (16, 10))
    eps = reparam.gumbel(jax.random.PRNGKey(2), (16, 10))
    a = reparam.reparam_argmax(logits, eps)
    b = reparam.reparam_argmax(logits + 123.4, eps)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 7))
def test_posterior_gumbel_consistency(seed, K, batch):
    """argmax(logits + posterior_eps) must equal the conditioning sample x —
    exactly, for any logits/x (the Appendix-B invariant)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = 3.0 * jax.random.normal(k1, (batch, K))
    x = jax.random.randint(k2, (batch,), 0, K)
    eps = reparam.posterior_gumbel(k3, logits, x)
    rec = reparam.reparam_argmax(logits, eps)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(x))


def test_posterior_gumbel_marginal():
    """Marginalizing (x ~ softmax, eps ~ p(eps|x)) must recover the standard
    Gumbel prior on eps (Appendix B, Eq. 12)."""
    key = jax.random.PRNGKey(3)
    n, K = 40000, 3
    logits = jnp.broadcast_to(jnp.asarray([1.0, 0.0, -0.5]), (n, K))
    kx, ke = jax.random.split(key)
    x = jax.random.categorical(kx, logits)
    eps = reparam.posterior_gumbel(ke, logits, x)
    # each marginal eps_{:, c} should be standard Gumbel: mean ~ 0.5772
    m = np.asarray(jnp.mean(eps, axis=0))
    np.testing.assert_allclose(m, np.full(K, np.euler_gamma), atol=0.03)
    v = np.asarray(jnp.var(eps, axis=0))
    np.testing.assert_allclose(v, np.full(K, np.pi**2 / 6), atol=0.1)


def test_posterior_gumbel_strictness():
    """Non-argmax perturbed values stay strictly below the max (no ties)."""
    key = jax.random.PRNGKey(4)
    logits = jax.random.normal(key, (64, 8))
    x = jax.random.randint(jax.random.PRNGKey(5), (64,), 0, 8)
    eps = reparam.posterior_gumbel(jax.random.PRNGKey(6), logits, x)
    vals = logits + eps
    mx = jnp.take_along_axis(vals, x[:, None], axis=-1)
    others = jnp.where(jax.nn.one_hot(x, 8, dtype=bool), -jnp.inf, vals)
    assert bool(jnp.all(others < mx))
