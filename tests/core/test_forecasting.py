"""Learned forecasting modules: validity (conditioning), exactness, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig

CFG = PixelCNNConfig(height=5, width=5, channels=1, categories=2,
                     filters=8, n_res=1, first_kernel=3)
FCFG = fc.PixelForecastConfig(channels=1, categories=2, horizon=4,
                              filters=8, in_filters=8)


def test_pixel_forecast_shapes_and_causality():
    key = jax.random.PRNGKey(0)
    fparams = fc.PixelForecast.init(key, FCFG)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 5, 8))
    out = fc.PixelForecast.apply(fparams, h, FCFG)
    assert out.shape == (2, 25, 4 * 1, 2)
    # strictly triangular: anchor p must not depend on h at pixels >= p
    h2 = h.at[:, 2, 3].add(10.0)  # pixel raster index 13
    out2 = fc.PixelForecast.apply(fparams, h2, FCFG)
    diff = np.abs(np.asarray(out - out2)).max(axis=(0, 2, 3))
    assert diff[:14].max() == pytest.approx(0.0, abs=1e-6)
    assert diff[14:].max() > 0


def test_learned_forecast_exactness():
    """Even an untrained forecasting module yields exact samples."""
    params = PixelCNN.init(jax.random.PRNGKey(2), CFG)
    fparams = fc.PixelForecast.init(jax.random.PRNGKey(3), FCFG)
    arm_fn = PixelCNN.make_arm_fn(params, CFG)
    module = fc.PixelForecast.module_fn(fparams, FCFG)
    forecast = ps.make_learned_forecast(module, window=FCFG.horizon * 1,
                                        group=1)
    eps = reparam.gumbel(jax.random.PRNGKey(4), (2, CFG.d, CFG.categories))
    x_ref, _ = ps.ancestral_sample(arm_fn, eps)
    x_fc, stats = ps.predictive_sample(arm_fn, forecast, eps)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_fc))
    assert int(stats.arm_calls) <= CFG.d


def test_kl_loss_zero_when_matching():
    """KL is ~0 if the module outputs the ARM's own (shifted) logits."""
    B, P, C, K, T = 1, 9, 1, 3, 2
    arm_logits = jax.random.normal(jax.random.PRNGKey(0), (B, P, C, K))
    idx = jnp.minimum(jnp.arange(P)[:, None] + jnp.arange(T)[None, :], P - 1)
    fc_logits = arm_logits[:, idx].reshape(B, P, T * C, K)
    cfg = fc.PixelForecastConfig(channels=C, categories=K, horizon=T,
                                 filters=4, in_filters=4)
    loss = fc.PixelForecast.kl_loss(fc_logits, arm_logits, cfg)
    assert float(loss) == pytest.approx(0.0, abs=1e-6)
    # and positive when mismatched
    loss2 = fc.PixelForecast.kl_loss(fc_logits + jax.random.normal(
        jax.random.PRNGKey(1), fc_logits.shape), arm_logits, cfg)
    assert float(loss2) > 0.01


def test_token_forecast_shift_validity():
    """Token head at position s may use only h[:s] (shifted conditioning)."""
    cfg = fc.TokenForecastConfig(d_model=8, vocab=11, horizon=3)
    params = fc.TokenForecast.init(jax.random.PRNGKey(0), cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (1, 7, 8))
    out = fc.TokenForecast.apply(params, h, cfg)
    assert out.shape == (1, 7, 3, 11)
    h2 = h.at[:, 4].add(5.0)
    out2 = fc.TokenForecast.apply(params, h2, cfg)
    diff = np.abs(np.asarray(out - out2)).max(axis=(0, 2, 3))
    assert diff[:5].max() == pytest.approx(0.0, abs=1e-6)  # s <= 4 unaffected
    assert diff[5:].max() > 0


def test_forecast_training_improves_match_rate():
    """Training the module on posterior-noise pairs (Appendix B) must raise
    its forecast-match rate vs the ARM on held-out noise."""
    from repro import optim
    cfg = CFG
    params = PixelCNN.init(jax.random.PRNGKey(5), cfg)
    fparams = fc.PixelForecast.init(jax.random.PRNGKey(6), FCFG)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)

    def fc_match_rate(fparams, key):
        module = fc.PixelForecast.module_fn(fparams, FCFG)
        forecast = ps.make_learned_forecast(module, window=FCFG.horizon, group=1)
        eps = reparam.gumbel(key, (4, cfg.d, cfg.categories))
        _, stats = ps.predictive_sample(arm_fn, forecast, eps)
        return int(stats.arm_calls)

    opt = optim.adamw(1e-2)
    state = opt.init(fparams)

    @jax.jit
    def step(fparams, state, x):
        logits, h = PixelCNN.forward_int(params, x, cfg)
        B = x.shape[0]
        arm_logits = logits.reshape(B, cfg.d, cfg.categories)[:, :, None, :]

        def loss(fp):
            out = fc.PixelForecast.apply(fp, h, FCFG)
            return fc.PixelForecast.kl_loss(out, arm_logits, FCFG)

        l, g = jax.value_and_grad(loss)(fparams)
        g = optim.zero_frozen(g)
        u, state2 = opt.update(g, state, fparams)
        return optim.apply_updates(fparams, u), state2, l

    x = jax.random.randint(jax.random.PRNGKey(7), (8, 5, 5, 1), 0, 2)
    l0 = None
    for _ in range(40):
        fparams, state, l = step(fparams, state, x)
        l0 = float(l) if l0 is None else l0
    assert float(l) < l0, "KL did not decrease"
