"""Optimizers as (init, update) pairs on pytrees — optax-style GradientTransformation
without the optax dependency.

``update(grads, state, params) -> (updates, state)``; apply with
``apply_updates(params, updates)``. All states are pytrees -> jit/pjit-safe.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def zero_frozen(tree):
    """Zero out gradients/updates for non-trainable buffers — any leaf whose
    dict key starts with '_' (e.g. PixelCNN conv masks)."""
    def walk(node):
        if isinstance(node, dict):
            return {k: (jax.tree.map(jnp.zeros_like, v)
                        if k.startswith("_") else walk(v))
                    for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(tree)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def sgd(lr) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        u = jax.tree.map(lambda g: -lr_fn(step) * g, grads)
        return u, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    """AdamW with decoupled weight decay. Moments in ``moment_dtype``
    (use bfloat16 for memory-tight giant-model configs)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def mom(m, g):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype)

        def sqmom(v, g):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(moment_dtype)

        mu = jax.tree.map(mom, state["mu"], grads)
        nu = jax.tree.map(sqmom, state["nu"], grads)
        step_size = lr_fn(step)

        def upd(m, v, p):
            m_hat = m.astype(jnp.float32) / bc1
            v_hat = v.astype(jnp.float32) / bc2
            u = -step_size * (m_hat / (jnp.sqrt(v_hat) + eps)
                              + weight_decay * p.astype(jnp.float32))
            return u.astype(jnp.float32)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), factored second moments, no first
    moment. Memory ~= (rows + cols) per matrix instead of 2x params — the
    optimizer of record for the >=100B dry-run configs (see DESIGN.md §4)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(per_leaf, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)
        step_size = lr_fn(step)

        def per_leaf(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g32 * rfac[..., None] * cfac[..., None, :]
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(vv + eps)
                new_v = {"v": vv}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -step_size * (u + weight_decay * p.astype(jnp.float32))
            return u, new_v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [per_leaf(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_v = treedef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "v": new_v}

    return Optimizer(init, update)
