from repro.optim.optimizers import (adamw, adafactor, sgd, clip_by_global_norm,
                                    apply_updates, zero_frozen)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine, constant_schedule

__all__ = [
    "adamw", "adafactor", "sgd", "clip_by_global_norm", "apply_updates",
    "zero_frozen",
    "cosine_schedule", "linear_warmup_cosine", "constant_schedule",
]
