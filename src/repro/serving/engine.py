"""`ServingEngine`: paged predictive-sampling serving runtime (DESIGN.md §6-8).

Subsumes the seed ``ContinuousBatcher`` (kept as a thin alias in
``repro.engine.scheduler``): requests are admitted from a priority/FCFS queue
into free slots of a fixed-width batch, every verify round advances each
sequence by its own accept length, and finished sequences free their slot and
blocks immediately. What's new over the dense batcher:

* **Paged KV cache** — attention K/V lives in fixed-size blocks of a shared
  physical pool (``TransformerLM.init_paged_cache``); verify rounds and
  prefill decode *through the block tables* (``decode_window_paged`` /
  DESIGN.md §9): each layer writes its window K/V into physical blocks and
  attends via the paged flash-decode Pallas kernel (TPU) or the gather-view
  exact fallback (CPU). No dense attention K/V view of the whole cache is
  built on the round hot path — ``paged_attention=False`` restores the
  legacy gather/scatter round-trip (kept as the benchmark baseline).
  Admission allocates blocks instead of zeroing a whole cache row.
* **Prefix cache** — full prompt blocks are content-hashed (chained keys);
  admissions sharing a prompt prefix point their tables at the cached blocks
  and skip recomputing them (attention-only models; recurrent stacks carry
  un-paged per-slot state, so they always prefill — see ``_has_recurrent``).
* **Row-local chunked prefill** — an admitted row prefills through batch-1
  windows over its own blocks; nothing scales with the batch width.
* **Adaptive speculation** — the verify window W is retuned per round from
  the observed accept-length EWMA (``AdaptiveWindowController``), bounded to
  powers of two in ``[1, w_max]`` so at most ``log2(w_max)+1`` round shapes
  compile.
* **Telemetry** — per-request latency/accept/ARM-call counters and engine
  gauges exported as plain dicts (``EngineMetrics``).

Exactness: every path emits tokens bit-identical to a per-request
``PredictiveSampler.generate`` run with the same eps key and noise-stream id
(``Request.seq_id``) — asserted in tests/serving/test_engine.py.
"""
from __future__ import annotations

import time
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.spec_decode import GenState, make_eps_fn, verify_round
from repro.kernels import resolve_interpret
from repro.models.transformer import PagedView, TransformerLM
from repro.serving.admission import AdmissionQueue, Request, prefill_chunks
from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.blocks import BlockManager
from repro.serving.metrics import EngineMetrics


def _has_recurrent(cfg) -> bool:
    return any(m in ("mamba", "rwkv") or f == "rwkv_cmix"
               for m, f in cfg.layer_specs())


class ServingEngine:
    def __init__(self, cfg, params, *, batch: int, window_max: int = 8,
                 max_len: int = 256, eps_key=None, eps_fn=None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 adaptive: bool = True, window_init: int = 0,
                 prefix_cache: bool = True, prefill_chunk: int = 64,
                 use_forecast_heads: bool = False,
                 use_verify_kernel: bool = False,
                 paged_attention: bool = True,
                 use_attention_kernel: Optional[bool] = None):
        assert block_size >= 1, f"block_size must be >= 1, got {block_size}"
        assert window_max >= 1, f"window_max must be >= 1, got {window_max}"
        self.cfg = cfg
        self.params = params
        self.B = batch
        self.W_max = window_max
        self.max_len = max_len
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.use_forecast_heads = (use_forecast_heads
                                   and "forecast" in params
                                   and cfg.forecast_horizon > 0)
        self.use_verify_kernel = use_verify_kernel
        # paged_attention: decode through block tables (no dense K/V view on
        # the round hot path). The Pallas kernel is the compiled TPU fast
        # path; elsewhere the default is the gather-view fallback, which is
        # bit-exact vs the dense engine (resolve_interpret's dispatch).
        self.paged_attention = paged_attention
        if use_attention_kernel is None:
            use_attention_kernel = not resolve_interpret(None)
        self.use_attention_kernel = use_attention_kernel
        self.eps_fn = eps_fn if eps_fn is not None else make_eps_fn(
            eps_key if eps_key is not None else jax.random.PRNGKey(0),
            cfg.vocab)

        # ---- paged cache ------------------------------------------------
        self.nb = -(-(max_len + window_max) // block_size)  # table width
        if num_blocks is None:
            # full occupancy + slack so unreferenced prefix blocks survive
            num_blocks = 1 + batch * self.nb + 2 * self.nb
        self.blocks = BlockManager(num_blocks, block_size)
        self.paged = TransformerLM.init_paged_cache(
            cfg, batch, num_blocks, block_size, dtype=cfg.param_dtype)
        self.tables = np.zeros((batch, self.nb), np.int32)
        self.owned: list[list[int]] = [[] for _ in range(batch)]
        # prefix-cache hits need the post-prefix recurrent state too, which
        # is per-slot (not paged) — so recurrent stacks always prefill
        self.prefix_enabled = prefix_cache and not _has_recurrent(cfg)

        # ---- control / telemetry ---------------------------------------
        self.controller = AdaptiveWindowController(
            w_max=window_max, w_init=window_init, enabled=adaptive)
        self.metrics = EngineMetrics()
        self.queue = AdmissionQueue()
        self.slots: list[Optional[Request]] = [None] * batch
        self.done: list[Request] = []
        self.target = np.zeros(batch, np.int64)
        # worst-case block need reserved per slot at admission (run-to-
        # completion guarantee: lazy growth may never exhaust the pool)
        self.reserved = np.zeros(batch, np.int64)

        # ---- per-slot device state -------------------------------------
        self.tokens = jnp.zeros((batch, max_len), jnp.int32)
        self.n = jnp.ones((batch,), jnp.int32)          # cleared-row sentinel
        self.cand = jnp.zeros((batch, window_max), jnp.int32)
        self.seq_ids = jnp.zeros((batch,), jnp.int32)

        self._round_fns: dict[int, callable] = {}
        self._prefill_fns: dict[int, callable] = {}

    # -- seed-API compatibility -------------------------------------------
    @property
    def state(self):
        """Seed ``ContinuousBatcher`` exposed ``state.rounds``; preserved."""
        return SimpleNamespace(rounds=self.metrics.rounds, n=self.n,
                               tokens=self.tokens)

    def submit(self, req: Request):
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.new_tokens <= self.max_len, \
            (len(req.prompt), req.new_tokens, self.max_len)
        self.queue.push(req)

    # -- jitted steps -------------------------------------------------------
    def _round_fn(self, W: int):
        """One verify round. Paged mode decodes through the block tables —
        window K/V lands straight in its physical blocks and attention
        streams the pool (per-round HBM traffic independent of pool size).
        Legacy mode is the dense round-trip: gather the whole view, decode,
        scatter the window back (O(B*S*d) both ways around the round)."""
        if W not in self._round_fns:
            cfg, B = self.cfg, self.B

            def fn(params, paged, tables, tokens, n, cand, seq_ids, target):
                rows = jnp.arange(B)
                if self.paged_attention:
                    cache = paged
                    pv = PagedView(tables, rows, self.use_attention_kernel)
                else:
                    cache = TransformerLM.gather_paged(cfg, paged, tables,
                                                       rows)
                    pv = None
                st = GenState(tokens, n, cand[:, :W], cache,
                              jnp.zeros((), jnp.int32),
                              jnp.zeros((B,), jnp.int32),
                              jnp.zeros((B,), jnp.int32), seq_ids)
                st2 = verify_round(
                    params, cfg, self.eps_fn, st, target,
                    use_forecast_heads=self.use_forecast_heads,
                    use_verify_kernel=self.use_verify_kernel, paged=pv)
                if self.paged_attention:
                    paged2 = st2.cache
                else:
                    active = n < target
                    paged2 = TransformerLM.scatter_paged(
                        cfg, paged, st2.cache, tables, rows,
                        jnp.maximum(n - 1, 0), W, active)
                cand2 = jnp.zeros_like(cand).at[:, :W].set(st2.cand)
                return paged2, st2.tokens, st2.n, cand2, st2.n - n

            self._round_fns[W] = jax.jit(fn)
        return self._round_fns[W]

    def _prefill_fn(self, C: int):
        if C not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, paged, table_row, row, chunk, start):
                if self.paged_attention:
                    view = PagedView(table_row, row,
                                     self.use_attention_kernel)
                    _, _, nc = TransformerLM.decode_window_paged(
                        params, cfg, chunk, paged, view, start)
                    sel = TransformerLM.select_states(
                        cfg, nc, jnp.full((1,), C, jnp.int32))
                    return TransformerLM.adopt_states_paged(
                        cfg, paged, sel, row)
                view = TransformerLM.gather_paged(cfg, paged, table_row, row)
                _, _, nc = TransformerLM.decode_window(
                    params, cfg, chunk, view, start)
                sel = TransformerLM.select_states(
                    cfg, nc, jnp.full((1,), C, jnp.int32))
                return TransformerLM.scatter_paged(
                    cfg, paged, sel, table_row, row, start, C,
                    jnp.ones((1,), bool))

            self._prefill_fns[C] = jax.jit(fn)
        return self._prefill_fns[C]

    # -- slot / block plumbing ---------------------------------------------
    def _ensure_capacity(self, b: int, upto_pos: int):
        """Grow slot ``b``'s block table to cover positions [0, upto_pos)."""
        need = -(-upto_pos // self.block_size)
        assert need <= self.nb, (need, self.nb)
        while len(self.owned[b]) < need:
            blk = self.blocks.alloc(1)[0]
            self.tables[b, len(self.owned[b])] = blk
            self.owned[b].append(blk)

    def _clear_row(self, b: int):
        """Reset a released slot so its (inactive) lane reads no stale or
        garbage cache positions: n=1, cache_len=0 -> only its own window."""
        self.blocks.release_all(self.owned[b])
        self.owned[b] = []
        self.tables[b] = 0
        self.target[b] = 0
        self.reserved[b] = 0
        self.tokens = self.tokens.at[b].set(0)
        self.n = self.n.at[b].set(1)
        self.cand = self.cand.at[b].set(0)

    def _reset_recurrent_row(self, b: int):
        def rec(stacked, leaf):
            return leaf.at[:, b].set(0) if stacked else leaf.at[b].set(0)

        self.paged = TransformerLM._map_paged(
            self.cfg, (self.paged,), lambda stacked, leaf: leaf, rec)

    # -- admission -----------------------------------------------------------
    def _worst_case_blocks(self, req: Request) -> int:
        # every prompt+generation block a fresh allocation, window at W_max
        return -(-(len(req.prompt) + req.new_tokens + self.W_max)
                 // self.block_size)

    def _outstanding_reservations(self) -> int:
        """Blocks already promised to in-flight slots but not yet allocated
        (their tables grow lazily as n advances)."""
        return int(sum(max(0, int(self.reserved[b]) - len(self.owned[b]))
                       for b in range(self.B) if self.slots[b] is not None))

    def _can_admit(self, req: Request) -> bool:
        return (self.blocks.available() - self._outstanding_reservations()
                >= self._worst_case_blocks(req))

    def _admit(self, req: Request, b: int):
        req.admit_time = time.monotonic()
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)

        # prefix-cache: reuse full blocks strictly below position L_p - 1
        # (the verify window rewrites position n-1 = L_p-1 onward, so those
        # blocks stay read-only and shareable)
        hits, keys = [], []
        nb_full = (L_p - 1) // self.block_size
        if self.prefix_enabled and nb_full:
            hits, keys = self.blocks.lookup_prefix(prompt, nb_full)
        req.prefix_hit_blocks = len(hits)
        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._ensure_capacity(b, L_p)

        # per-slot state
        self.tokens = self.tokens.at[b].set(0).at[b, :L_p].set(
            jnp.asarray(prompt, jnp.int32))
        self.n = self.n.at[b].set(L_p)
        self.cand = self.cand.at[b].set(0).at[b, 0].set(int(prompt[-1]))
        self.seq_ids = self.seq_ids.at[b].set(req.seq_id)
        if _has_recurrent(self.cfg):
            self._reset_recurrent_row(b)

        # chunked row-local prefill of the un-cached prompt tail
        start = len(hits) * self.block_size
        table_row = jnp.asarray(self.tables[b:b + 1])
        row = jnp.asarray([b], jnp.int32)
        for C in prefill_chunks(L_p - 1 - start, self.prefill_chunk):
            chunk = jnp.asarray(prompt[None, start:start + C], jnp.int32)
            self.paged = self._prefill_fn(C)(
                self.params, self.paged, table_row, row, chunk,
                jnp.asarray([start], jnp.int32))
            start += C
            req.prefill_calls += 1
            self.metrics.prefill_calls += 1

        # publish this prompt's freshly computed full blocks
        if self.prefix_enabled:
            for j in range(len(hits), nb_full):
                self.blocks.register(self.owned[b][j], keys[j])

        self.slots[b] = req
        self.target[b] = L_p + req.new_tokens
        self.reserved[b] = self._worst_case_blocks(req)

    # -- main loop -----------------------------------------------------------
    def step(self) -> bool:
        """Admit what fits, run one verify round, harvest finished requests.
        Returns True while there is (or may be) work left."""
        for b in range(self.B):
            if self.slots[b] is None and self.queue:
                nxt = self.queue.peek()
                if not self._can_admit(nxt):
                    break
                self._admit(self.queue.pop(), b)

        if not any(s is not None for s in self.slots):
            if self.queue:
                raise MemoryError(
                    "admission deadlock: queued request cannot fit an empty "
                    "engine (prompt+target exceeds the block pool)")
            return False

        W = self.controller.window
        target_dev = jnp.asarray(self.target, jnp.int32)
        for b in range(self.B):
            if self.slots[b] is not None:
                self._ensure_capacity(b, int(self.target[b]) + W)
        n_before = np.asarray(self.n)
        (self.paged, self.tokens, self.n, self.cand, a_dev) = \
            self._round_fn(W)(self.params, self.paged,
                              jnp.asarray(self.tables), self.tokens,
                              self.n, self.cand, self.seq_ids, target_dev)
        a = np.asarray(a_dev)
        n_host = np.asarray(self.n)

        active_rows = [b for b in range(self.B)
                       if self.slots[b] is not None
                       and n_before[b] < self.target[b]]
        for b in active_rows:
            self.slots[b].calls_used += 1
        self.metrics.observe_round(W, len(active_rows), self.B,
                                   int(a[active_rows].sum())
                                   if active_rows else 0)
        self.controller.observe(a[active_rows])

        for b in range(self.B):
            req = self.slots[b]
            if req is not None and n_host[b] >= self.target[b]:
                req.result = np.asarray(self.tokens[b, :n_host[b]])
                req.finish_time = time.monotonic()
                self.metrics.observe_finish(req)
                self.done.append(req)
                self.slots[b] = None
                self._clear_row(b)
        return True

    def run(self, max_rounds: int = 10_000) -> list[Request]:
        """Drain the queue; returns completed Requests with stats."""
        while self.queue or any(s is not None for s in self.slots):
            if not self.step():
                break
            max_rounds -= 1
            if max_rounds <= 0:
                raise RuntimeError("serving engine did not converge")
        return self.done

    # -- telemetry -----------------------------------------------------------
    def export_metrics(self) -> dict:
        out = self.metrics.export(self.blocks.stats.export())
        out["blocks_in_use"] = self.blocks.blocks_in_use()
        out["blocks_available"] = self.blocks.available()
        return out
