"""`ServingEngine`: paged predictive-sampling serving runtime (DESIGN.md §6-10).

Subsumes the seed ``ContinuousBatcher`` (kept as a thin alias in
``repro.engine.scheduler``): requests are admitted from a priority/deadline
queue into free slots of a fixed-width batch, every verify round advances
each sequence by its own accept length, and finished sequences free their
slot and blocks immediately. What's new over the dense batcher:

* **Paged KV cache** — attention K/V lives in fixed-size blocks of a shared
  physical pool (``TransformerLM.init_paged_cache``); verify rounds and
  prefill decode *through the block tables* (``decode_window_paged`` /
  DESIGN.md §9): each layer writes its window K/V into physical blocks and
  attends via the paged flash-decode Pallas kernel (TPU) or the gather-view
  exact fallback (CPU). No dense attention K/V view of the whole cache is
  built on the round hot path — ``paged_attention=False`` restores the
  legacy gather/scatter round-trip (kept as the benchmark baseline).
  Admission allocates blocks instead of zeroing a whole cache row.
* **Mesh sharding** — a ``ServingTopology`` splits the batch slots and the
  physical pool into per-data-shard halves; the verify round runs under
  shard_map manual over "data", so each shard decodes its rows against its
  own sub-pool through *shard-local* block tables (zero collectives on the
  round hot path; DESIGN.md §10). Admission routes requests to the shard
  with the most block headroom. Tokens are bit-identical to the
  single-device engine (placement-independent noise streams).
* **Prefix cache** — full prompt blocks are content-hashed (chained keys);
  admissions sharing a prompt prefix point their tables at the cached blocks
  and skip recomputing them. Under a mesh the cache is per-shard (blocks
  never cross shards).
* **Host cache tier** (DESIGN.md §13) — a bounded host-memory arena behind
  the device prefix cache: evicted prefix blocks spill D2H and re-admit via
  async double-buffered H2D staging overlapped with prefill; parked
  sequences dedup their shared prompt blocks through the same arena; and
  recurrent-state snapshots checkpointed at block boundaries give
  ssm/rwkv/hybrid stacks prefix hits for the first time (their per-slot
  state is un-paged, so without the tier they always prefill — see
  ``_has_recurrent`` and the ``kv_prefix``/``rec_prefix`` split).
  Everything tier-related is admission-path host work: the verify-round
  jaxpr/HLO is untouched.
* **Row-local chunked prefill** — an admitted row prefills through batch-1
  windows over its own blocks; nothing scales with the batch width.
* **Device-resident verify rounds** — a verify round is a SINGLE device
  dispatch (the fused paged kernel commits window K/V as an aliased
  epilogue — no standalone scatter before the pallas_call), and up to
  ``rounds_per_sync`` rounds run inside one ``lax.while_loop`` dispatch
  between host syncs: the host pulls one packed (B, 4) stats array per
  loop instead of ``n``/``cand`` every round (DESIGN.md §11). Under a mesh
  each shard's loop stops on its own rows — no cross-shard collective.
* **Adaptive speculation** — the verify window W is retuned per host sync
  from the observed accept-length EWMA (``AdaptiveWindowController``),
  bounded to powers of two in ``[1, w_max]`` so at most ``log2(w_max)+1``
  round shapes compile; the loop runs at fixed W, so the sync IS the
  retune boundary.
* **Donated round buffers** — the physical pool and per-slot device state
  are dead the moment a round returns their successors, so the jitted round
  and prefill steps donate them (``donate_argnums``): XLA updates the pool
  in place instead of holding two full copies live per round
  (``donate=False`` restores the copying behaviour for A/B measurement).
* **Saturation-safe scheduling** (DESIGN.md §12) — admission scans a
  bounded ``lookahead`` window past an unroutable head (with an aging bound
  so the head cannot starve); a queued higher-priority request may
  **preempt** the lowest-priority running slot below a progress floor —
  its live block contents are spilled to a host-side parking list and it
  is requeued for *exact* resume (still-valid prefix blocks re-hit, the
  ``n``/``cand`` snapshot restored, tokens bitwise-identical to an
  uninterrupted run); and admission may **rebalance** a mesh by migrating
  a live sequence's blocks between shard sub-pools (device block copy +
  one table-row re-upload + per-slot state move — bit-exact by
  construction, since tokens and noise streams are placement-independent)
  when one shard's pool is exhausted while another has headroom.
* **Fault isolation** (DESIGN.md §14) — the engine fails *per request*,
  never per process: submit-time validation rejects malformed requests with
  a structured ``RequestError``; a per-row health flag folded into the
  packed sync stats (non-finite logits, stuck progress) quarantines only
  the offending slot — its blocks are released, the error attached, and
  every other row of the same batch stays bitwise identical to a fault-free
  run (poison is injected at the LOGITS level, so cache contents stay
  finite and row-local); host-side faults (allocation failures, corrupt or
  tripped host-tier entries, staging drops) unwind to the request that hit
  them, with bounded retries (``request_retries``) and fresh noise streams
  for quarantined rows; ``cancel(uid)`` removes a request wherever it
  currently lives (queued, parked, running); ``max_request_seconds`` /
  ``max_request_rounds`` bound runaway requests. All of it is scriptable
  through a deterministic ``FaultPlan`` (``repro.serving.faults``).
* **Telemetry** — per-request latency/accept/ARM-call counters, deadline
  (SLO) misses — including expiries detected while still queued/parked —
  preemption/migration/aging counters, and engine gauges exported as plain
  dicts (``EngineMetrics``).

Exactness: every path emits tokens bit-identical to a per-request
``PredictiveSampler.generate`` run with the same eps key and noise-stream id
(``Request.seq_id``) — asserted in tests/serving/test_engine.py and, for the
mesh paths, tests/serving/test_mesh_engine.py.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import maybe_check
from repro.engine.spec_decode import GenState, make_eps_fn, verify_round
from repro.kernels import resolve_interpret
from repro.models.transformer import PagedView, TransformerLM
from repro.serving.admission import (AdmissionQueue, Request, StagedEntry,
                                     pack_staged_descriptors, pow2_at_most,
                                     prefill_chunks)
from repro.serving.adaptive import (AdaptiveWindowController,
                                    RoundsPerSyncController)
from repro.serving.blocks import (ShardedBlockPool, StagingLedger,
                                  chain_hashes)
from repro.serving.faults import (CircuitBreaker, FaultPlan, RequestError,
                                  kill_point)
from repro.serving.hostcache import DiskTier
from repro.serving.journal import RequestJournal
from repro.serving.metrics import EngineMetrics
from repro.serving.topology import ServingTopology


def _has_recurrent(cfg) -> bool:
    return any(m in ("mamba", "rwkv") or f == "rwkv_cmix"
               for m, f in cfg.layer_specs())


@dataclass
class ParkedSequence:
    """Host-side parking payload of a preempted slot (DESIGN.md §12, §13).

    Everything an exact resume needs: the accepted-token row and the
    ``n``/``cand`` snapshot (candidates gate only acceptance, never token
    values — restoring them keeps even the *round count* identical to an
    uninterrupted run), plus the contents of the ``nb_live`` blocks that
    hold positions ``[0, n-1)`` (position ``n-1`` onward is rewritten by
    the next verify window, so those blocks need no spill).

    With a host tier the payload is split (§13): the victim's full prompt
    blocks live ONCE in the tier's shared ``kv`` namespace, refcount-pinned
    under ``kv_keys`` — N victims of a shared prefix pin the same entries
    instead of storing N copies — and only the *private* remainder (rows of
    the tail blocks ``[len(kv_keys), nb_live)`` preceded by the recurrent
    state row) is parked per victim: in the arena (``in_arena``) when it
    fits, raw in ``private`` otherwise. Without a tier, ``payload`` is the
    legacy cache-shaped pytree: attention leaves carry the gathered pool
    rows in table order, recurrent leaves the slot's state snapshot."""
    n: int
    tokens: np.ndarray           # (max_len,) accepted-token row
    cand: np.ndarray             # (W_max,) verify-window snapshot
    nb_live: int                 # leading owned blocks whose contents matter
    payload: Optional[dict] = None   # legacy host pytree (no host tier)
    kv_keys: tuple = ()          # arena-pinned chain keys, blocks [0, len)
    n_rec: int = 0               # leading private arrays = recurrent row
    rows_per_block: int = 0      # arrays per tail block in the private part
    in_arena: bool = False       # private part parked under ("park", uid)
    private: Optional[list] = None   # raw fallback when the arena was full
    shard: int = 0               # tier kv partition the pins live under
    #                              (resume may land on a different shard)
    cold: bool = False           # checkpoint-restored park (DESIGN.md §16):
    #                              no live payload or pins exist in THIS
    #                              process — resume rebuilds through the
    #                              disk-tier fall-through + re-prefill and
    #                              never consumes a payload


class ServingEngine:
    def __init__(self, cfg, params, *, batch: int, window_max: int = 8,
                 max_len: int = 256, eps_key=None, eps_fn=None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 adaptive: bool = True, window_init: int = 0,
                 prefix_cache: bool = True, prefill_chunk: int = 64,
                 use_forecast_heads: bool = False,
                 use_verify_kernel: bool = False,
                 paged_attention: bool = True,
                 use_attention_kernel: Optional[bool] = None,
                 topology: Optional[ServingTopology] = None,
                 donate: bool = True, rounds_per_sync: int = 4,
                 lookahead: int = 8, max_head_bypass: int = 16,
                 preempt: bool = True, preempt_floor: float = 0.75,
                 rebalance: bool = True,
                 host_cache_mb: Optional[float] = None, host_tier=None,
                 request_retries: int = 0,
                 max_request_seconds: Optional[float] = None,
                 max_request_rounds: Optional[int] = None,
                 integrity_checks: bool = True,
                 faults: Optional[FaultPlan] = None,
                 staging_slots: int = 0,
                 adaptive_rounds: Optional[bool] = None,
                 host_prefetch: Optional[bool] = None,
                 prefetch_budget: int = 4,
                 durable_dir: Optional[str] = None,
                 journal_fsync_every: int = 1,
                 disk_tier: bool = True,
                 disk_cache_mb: Optional[float] = None):
        assert block_size >= 1, f"block_size must be >= 1, got {block_size}"
        assert window_max >= 1, f"window_max must be >= 1, got {window_max}"
        assert rounds_per_sync >= 1, rounds_per_sync
        assert staging_slots >= 0, staging_slots
        assert prefetch_budget >= 0, prefetch_budget
        assert lookahead >= 1, lookahead
        assert max_head_bypass >= 0, max_head_bypass
        assert 0.0 <= preempt_floor <= 1.0, preempt_floor
        self.cfg = cfg
        self.params = params
        self.B = batch
        self.W_max = window_max
        self.max_len = max_len
        self.block_size = block_size
        # pow2 normalization keeps the "log2(max_chunk)+1 compiled prefill
        # widths" guarantee honest for non-pow2 user values (48 -> 32)
        assert prefill_chunk >= 1, prefill_chunk
        self.prefill_chunk = pow2_at_most(prefill_chunk)
        self.use_forecast_heads = (use_forecast_heads
                                   and "forecast" in params
                                   and cfg.forecast_horizon > 0)
        self.use_verify_kernel = use_verify_kernel
        # paged_attention: decode through block tables (no dense K/V view on
        # the round hot path). The Pallas kernel is the compiled TPU fast
        # path; elsewhere the default is the gather-view fallback, which is
        # bit-exact vs the dense engine (resolve_interpret's dispatch).
        self.paged_attention = paged_attention
        if use_attention_kernel is None:
            use_attention_kernel = not resolve_interpret(None)
        self.use_attention_kernel = use_attention_kernel
        # donate the pool + per-slot state into the jitted round/prefill
        # steps (their previous values are dead once the step returns)
        self.donate = donate
        # device-resident rounds: up to this many verify rounds run inside
        # one dispatch (lax.while_loop) between host syncs; 1 = host-driven
        self.rounds_per_sync = rounds_per_sync
        # device-resident continuous batching (DESIGN.md §15): admission
        # pre-stages up to ``staging_slots`` queued requests PER SHARD into
        # spare pool blocks; inside the round loop a freed (or quarantined)
        # row adopts the next staged descriptor without a host sync.
        # ``adaptive_rounds`` replaces the binary ``k = 1 if queue`` sync
        # heuristic with a controller retuned from observed idle row-rounds;
        # it defaults on exactly when staging is on (without adoption a long
        # loop under backlog just strands freed rows).
        self.staging_slots = staging_slots
        # the controller's idle signal only exists in the staged stats ABI,
        # so adaptivity is meaningful (and allowed) only with staging on
        self.adaptive_rounds = (staging_slots > 0 if adaptive_rounds is None
                                else bool(adaptive_rounds)
                                and staging_slots > 0)
        self.rounds_ctrl = RoundsPerSyncController(
            k_max=rounds_per_sync, enabled=self.adaptive_rounds)
        # host-tier prefix prefetch for QUEUED requests (§15 satellite):
        # restage their host-resident prefix blocks through the staging
        # ring while they wait instead of at admission
        self.host_prefetch = (staging_slots > 0 if host_prefetch is None
                              else bool(host_prefetch))
        self.prefetch_budget = prefetch_budget
        # saturation-safe scheduling (DESIGN.md §12): admission lookahead
        # window, head-aging bound, priority preemption (+ progress floor:
        # slots past this generated fraction are never evicted), and
        # cross-shard rebalancing by sequence migration
        self.lookahead = lookahead
        self.max_head_bypass = max_head_bypass
        self.preempt = preempt
        self.preempt_floor = preempt_floor
        self.rebalance = rebalance
        # fault isolation (DESIGN.md §14): bounded re-admission after
        # retryable failures, runaway-request bounds, and the deterministic
        # fault-injection plan (defaults to REPRO_FAULT_PLAN — the CI chaos
        # job's hook — so production code paths need no test shims)
        assert request_retries >= 0, request_retries
        self.request_retries = request_retries
        self.max_request_seconds = max_request_seconds
        self.max_request_rounds = max_request_rounds
        self.faults = faults if faults is not None else FaultPlan.from_env()
        self.eps_fn = eps_fn if eps_fn is not None else make_eps_fn(
            eps_key if eps_key is not None else jax.random.PRNGKey(0),
            cfg.vocab)

        # ---- topology (slot ranges + block sub-pools per data shard) -----
        self.topo = topology if topology is not None else ServingTopology()
        D = self.topo.data_size
        self.B_local = self.topo.slots_per_shard(batch)

        # ---- paged cache ------------------------------------------------
        self.nb = -(-(max_len + window_max) // block_size)  # table width
        if num_blocks is None:
            # per shard: full occupancy + slack so unreferenced prefix
            # blocks survive
            num_blocks = 1 + self.B_local * self.nb + 2 * self.nb
        # ``num_blocks`` is PER DATA SHARD; the device pool holds D of them
        self.pool = ShardedBlockPool(D, num_blocks, block_size)
        self.paged = self.topo.put_paged(cfg, TransformerLM.init_paged_cache(
            cfg, batch, D * num_blocks, block_size, dtype=cfg.param_dtype))
        self._paged_specs = TransformerLM.paged_partition_specs(
            cfg, self.paged, data_axis=self.topo.data_axis)
        # block tables hold SHARD-LOCAL ids (each shard's sink is local 0);
        # host-side code converts to global pool ids via the shard offset
        self.tables = np.zeros((batch, self.nb), np.int32)
        self.owned: list[list[int]] = [[] for _ in range(batch)]

        # ---- durability layer (DESIGN.md §16) ---------------------------
        # ``durable_dir`` roots the crash-safety state: ``disk/`` (the tier
        # below the arena), ``journal.wal`` (the write-ahead request
        # journal), ``checkpoint.json`` (the scheduler snapshot written at
        # sync boundaries). None = volatile engine, byte-for-byte the old
        # behaviour. ``disk_tier=False`` (--no-disk-tier) keeps journal +
        # checkpoint but drops the prefix spill (restarts re-prefill).
        assert journal_fsync_every >= 1, journal_fsync_every
        self.durable_dir = durable_dir
        self.journal = None
        self._ckpt_path = None
        self.disk = None
        if durable_dir is not None:
            if disk_tier:
                dmb = 1024.0 if disk_cache_mb is None else float(disk_cache_mb)
                self.disk = DiskTier(os.path.join(durable_dir, "disk"),
                                     int(dmb * 2 ** 20), faults=self.faults,
                                     breaker=CircuitBreaker())
            self.journal = RequestJournal(
                os.path.join(durable_dir, "journal.wal"),
                fsync_every=journal_fsync_every, faults=self.faults)
            self._ckpt_path = os.path.join(durable_dir, "checkpoint.json")

        # ---- host cache tier (DESIGN.md §13) ----------------------------
        # One byte-budgeted arena behind the device prefix cache: spilled
        # KV blocks, parked-sequence payloads, recurrent-state snapshots.
        # ``host_cache_mb=0`` (or --no-host-cache) disables it; unset falls
        # back to REPRO_HOST_CACHE_MB, then 256 MiB.
        if host_tier is not None:
            self.tier = host_tier
        else:
            mb = host_cache_mb
            if mb is None:
                mb = float(os.environ.get("REPRO_HOST_CACHE_MB", 256))
            self.tier = (self.topo.host_tier(
                int(mb * 2 ** 20), integrity=integrity_checks,
                faults=self.faults, breaker=CircuitBreaker(),
                disk=self.disk)
                if mb > 0 else None)
        if self.faults is not None:
            # the 'alloc' seam: injected block-allocation failures surface
            # as the MemoryError a genuinely exhausted pool would raise
            self.pool.set_fault_hook(lambda: self.faults.fire("alloc"))

        # prefix-cache enablement is split per state kind: attention KV
        # blocks are paged and shareable as before (``kv_prefix``), while a
        # prefix hit for a recurrent stack additionally needs the
        # post-prefix per-slot state — un-paged, so only reachable through
        # the tier's recurrent-state snapshots (``rec_prefix``; without a
        # tier, recurrent archs always prefill, as before)
        has_rec = _has_recurrent(cfg)
        self.has_attn = any(m not in ("mamba", "rwkv")
                            for m, _ in cfg.layer_specs())
        self.kv_prefix = prefix_cache and not has_rec
        self.rec_prefix = prefix_cache and has_rec and self.tier is not None
        # device KV blocks are registered/looked-up whenever the arch has
        # attention layers to fill them (hybrids included under rec_prefix)
        self._kv_share = self.kv_prefix or (self.rec_prefix and self.has_attn)
        self.pool.set_spill_hook(self._make_spill_hook)

        # ---- control / telemetry ---------------------------------------
        self.controller = AdaptiveWindowController(
            w_max=window_max, w_init=window_init, enabled=adaptive)
        self.metrics = EngineMetrics()
        self.queue = AdmissionQueue()
        self.slots: list[Optional[Request]] = [None] * batch
        self.done: list[Request] = []
        self.target = np.zeros(batch, np.int64)
        # worst-case block need reserved per slot at admission (run-to-
        # completion guarantee: lazy growth may never exhaust the pool)
        self.reserved = np.zeros(batch, np.int64)
        # host mirror of each slot's accepted length, refreshed from the
        # packed stats at every sync (preemption progress floor + parking)
        self.n_host = np.ones(batch, np.int64)
        # parked (preempted) sequences by request uid, awaiting exact resume
        self.parked: dict[int, ParkedSequence] = {}
        self._last_rounds_exec = 0
        # staging area (§15): per-shard FIFO of pre-staged entries, a
        # ledger capping their block claims to spare headroom (staging can
        # never starve resident reservations), and the prefetched host-tier
        # rows of still-queued requests ``uid -> (shard, {key: dev rows})``
        self.staged: list[list[StagedEntry]] = [[] for _ in range(D)]
        self.ledger = StagingLedger(staging_slots)
        self._prefetched: dict[int, tuple[int, dict]] = {}

        # ---- per-slot device state (slot dim sharded over "data") -------
        self.tokens = self.topo.put_batch(jnp.zeros((batch, max_len),
                                                    jnp.int32))
        self.n = self.topo.put_batch(jnp.ones((batch,), jnp.int32))
        # ^ cleared-row sentinel n=1
        self.cand = self.topo.put_batch(jnp.zeros((batch, window_max),
                                                  jnp.int32))
        # noise-stream ids: host mirror + cached upload (the staged round
        # ABI loop-carries the device copy so in-loop adoption can swap a
        # row's stream; the host mirror stays authoritative for admission)
        self.seq_ids = np.zeros(batch, np.int32)
        # per-slot prompt length: rows at n >= plen behave identically to
        # the legacy engine (forced-acceptance prefill is a provable no-op
        # there); only rows adopted mid-loop ever see n < plen
        self.plen = np.zeros(batch, np.int64)
        # per-slot poison mask (§14): rows whose noise stream is scripted in
        # ``faults.poison_streams`` get their verify-round logits
        # NaN-replaced on device — the injection point of the quarantine
        # path. All zeros (the common case) is a bit-exact no-op.
        self.poison = np.zeros(batch, np.int32)
        # cached device copies of host-owned admission state; invalidated
        # only when the host actually mutates them (admission, slot clear,
        # table growth) instead of re-uploading every round
        self._tables_dev = None
        self._target_dev = None
        self._poison_dev = None
        self._seq_dev = None
        self._plen_dev = None

        self._round_fns: dict[tuple[int, int], callable] = {}
        self._prefill_fns: dict[int, callable] = {}
        self._copy_fn = None

    # -- seed-API compatibility -------------------------------------------
    @property
    def state(self):
        """Seed ``ContinuousBatcher`` exposed ``state.rounds``; preserved."""
        return SimpleNamespace(rounds=self.metrics.rounds, n=self.n,
                               tokens=self.tokens)

    @property
    def prefix_enabled(self) -> bool:
        """Any prefix reuse active (device KV and/or tiered recurrent)."""
        return self.kv_prefix or self.rec_prefix

    def _validate(self, req: Request) -> Optional[RequestError]:
        """Submit-time validation (DESIGN.md §14): reject malformed or
        unservable requests *before* they own a slot, with a structured
        reason — never an assert five layers down. Token range is checked
        on VALUES (prompts arrive as any integral-valued array; the engine
        casts to int32 at admission)."""
        prompt = np.asarray(req.prompt)
        if prompt.size < 1:
            return RequestError("empty_prompt", "prompt holds no tokens")
        if req.new_tokens <= 0:
            return RequestError("bad_new_tokens",
                                f"new_tokens={req.new_tokens}")
        if prompt.size + req.new_tokens > self.max_len:
            return RequestError(
                "too_long", f"{prompt.size} prompt + {req.new_tokens} new "
                f"> max_len={self.max_len}")
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= self.cfg.vocab:
            return RequestError(
                "token_out_of_range",
                f"tokens span [{lo}, {hi}], vocab={self.cfg.vocab}")
        cap = self.pool.blocks_per_shard - 1      # minus the reserved sink
        if self._worst_case_blocks(req) > cap:
            return RequestError(
                "over_capacity", f"worst case {self._worst_case_blocks(req)}"
                f" blocks > pool capacity {cap}/shard")
        return None

    def submit(self, req: Request) -> bool:
        """Validate and enqueue. Returns False — with ``req.error`` set and
        the request delivered through ``done`` — on rejection."""
        err = self._validate(req)
        if err is not None:
            req.error = err
            req.submit_time = time.monotonic()
            req.finish_time = req.submit_time
            self.metrics.requests_rejected += 1
            self.done.append(req)
            return False
        self.queue.push(req)
        # journal AFTER push: the queue pinned the arrival rank the record
        # durable-izes; with fsync_every=1 the submit is on media before
        # this returns — an accepted request survives any later crash
        self._journal("submit", uid=int(req.uid),
                      prompt=[int(t) for t in
                              np.asarray(req.prompt).ravel()],
                      new_tokens=int(req.new_tokens),
                      priority=int(req.priority), deadline=req.deadline,
                      noise_seed=req.noise_seed, rank=int(req._seq))
        return True

    def _journal(self, type: str, **fields):
        """Append one lifecycle record when a journal is configured
        (DESIGN.md §16); a no-op for volatile engines."""
        if self.journal is not None:
            self.journal.append(type, **fields)

    # -- jitted steps -------------------------------------------------------
    def _round_loop_fn(self, W: int, k: int):
        """Up to ``k`` verify rounds in ONE device dispatch. The round body
        decodes through the block tables — the fused paged kernel commits
        the window K/V into its physical blocks as an aliased epilogue while
        attention streams the pool (one pallas_call per layer, no standalone
        window scatter; per-round HBM traffic independent of pool size).
        Legacy mode is the dense round-trip: gather the whole view, decode,
        write the window span back through the same aliased writeback.

        A ``lax.while_loop`` re-runs the body until every local row is done
        or ``k`` rounds have run (the window-retune boundary): the host
        syncs one small packed stats array per *loop*, not per round —
        (R, 5) int32 ``[accepted, rounds_active, new_length, loop_rounds,
        bad]`` (DESIGN.md §11, §14). ``bad`` is the sticky per-row health
        flag the quarantine path reads: bit 0 = the row produced non-finite
        logits while active (poisoned stream or genuine numeric blowup),
        bit 1 = the row made no progress over an active round. Rows gone
        bad stop counting toward the loop condition — NaNs are row-local
        (logits-level injection; cache contents stay finite), so freezing a
        bad row leaves every healthy row bitwise identical to a fault-free
        run, and inactive rows remain no-ops as before.

        With ``staging_slots > 0`` the loop additionally performs **in-loop
        slot adoption** (DESIGN.md §15): the body opens with a device-side
        free-row scan — rows done or quarantined — that adopts the next
        staged descriptors (FIFO) into those rows: table-row swap, staged
        prompt buffer, fresh noise stream, and forced-acceptance prefill at
        the same verify widths (``prompt_len``), so occupancy stays
        saturated with ZERO extra host pulls. The ABI grows to loop-carry
        everything adoption mutates (tables/seq_ids/target/poison/plen) and
        returns per-descriptor episode stats plus the displaced token rows;
        the packed stats widen to (R, 7) ``[..., gen_rounds, idle_rounds]``.
        Every adoption-scan write is a rank-2 scatter into the small
        descriptor-keyed outputs — the pool itself is only ever touched by
        the same verify-round writeback, so the zero-pool-ranked-scatter and
        zero-collective HLO gates hold unchanged. With ``staging_slots ==
        0`` the legacy 9-arg program below is built bit-for-bit unchanged
        (cached host uploads stay identity-stable across steps).

        Under a mesh topology the whole loop runs shard_map-manual over
        "data": each shard sees its local rows, its local tables, and its
        local block sub-pool, and — crucially — its while_loop stops on its
        OWN rows, so the stop condition needs no cross-shard collective
        (shards may run different trip counts; the compiled HLO stays
        collective-free). The old pool and per-slot state are donated (dead
        after the loop), so XLA updates the pool in place round over round
        instead of copying it."""
        if (W, k) not in self._round_fns:
            if self.staging_slots > 0:
                self._round_fns[(W, k)] = self._build_staged_round(W, k)
                return self._round_fns[(W, k)]
            cfg = self.cfg

            def fn(params, paged, tables, tokens, n, cand, seq_ids, target,
                   poison):
                R = tokens.shape[0]          # rows on this shard (B/D)
                rows = jnp.arange(R)

                def one_round(paged, tokens, n, cand):
                    if self.paged_attention:
                        cache = paged
                        pv = PagedView(tables, rows,
                                       self.use_attention_kernel)
                    else:
                        cache = TransformerLM.gather_paged(cfg, paged,
                                                           tables, rows)
                        pv = None
                    st = GenState(tokens, n, cand[:, :W], cache,
                                  jnp.zeros((), jnp.int32),
                                  jnp.zeros((R,), jnp.int32),
                                  jnp.zeros((R,), jnp.int32), seq_ids)
                    st2, rstats = verify_round(
                        params, cfg, self.eps_fn, st, target,
                        use_forecast_heads=self.use_forecast_heads,
                        use_verify_kernel=self.use_verify_kernel, paged=pv,
                        poison=poison)
                    if self.paged_attention:
                        paged2 = st2.cache
                    else:
                        active = n < target
                        paged2 = TransformerLM.scatter_paged(
                            cfg, paged, st2.cache, tables, rows,
                            jnp.maximum(n - 1, 0), W, active)
                    cand2 = jnp.zeros_like(cand).at[:, :W].set(st2.cand)
                    return paged2, st2.tokens, st2.n, cand2, rstats

                def cond(carry):
                    _, _, n_c, _, _, _, bad, r = carry
                    return (r < k) & jnp.any((n_c < target) & (bad == 0))

                def body(carry):
                    paged_c, tokens_c, n_c, cand_c, acc, act_rounds, bad, \
                        r = carry
                    active = (n_c < target).astype(jnp.int32)
                    n_prev = n_c
                    paged_c, tokens_c, n_c, cand_c, rstats = one_round(
                        paged_c, tokens_c, n_c, cand_c)
                    # consume the §11 per-round stats ABI: col 0 = accepted,
                    # col 3 = non-finite logits; sticky health bits (§14)
                    stuck = active * (n_c == n_prev).astype(jnp.int32)
                    bad = bad | (active * rstats[:, 3]) | (stuck << 1)
                    return (paged_c, tokens_c, n_c, cand_c,
                            acc + rstats[:, 0], act_rounds + active, bad,
                            r + 1)

                init = (paged, tokens, n, cand, jnp.zeros((R,), jnp.int32),
                        jnp.zeros((R,), jnp.int32),
                        jnp.zeros((R,), jnp.int32), jnp.zeros((), jnp.int32))
                (paged2, tokens2, n2, cand2, acc, act_rounds, bad, r) = \
                    jax.lax.while_loop(cond, body, init)
                stats = jnp.stack(
                    [acc, act_rounds, n2,
                     jnp.broadcast_to(r, (R,)), bad], axis=1)
                return paged2, tokens2, n2, cand2, stats

            wrapped = self.topo.wrap_round(fn, self._paged_specs,
                                           n_batch_in=7, n_batch_out=4)
            # donate pool + tokens/n/cand (dead after the loop); tables,
            # seq_ids and target are cached host-owned uploads — kept alive
            donate = (1, 3, 4, 5) if self.donate else ()
            self._round_fns[(W, k)] = jax.jit(wrapped, donate_argnums=donate)
        return self._round_fns[(W, k)]

    def _build_staged_round(self, W: int, k: int):
        """The ``staging_slots > 0`` round-loop program (DESIGN.md §15).

        ABI: ``fn(params, paged, tables, tokens, n, cand, seq_ids, target,
        poison, plen, d_valid, d_tables, d_tokens, d_n, d_target, d_seq,
        d_poison, d_plen, q_more) -> (paged, tables, tokens, n, cand,
        seq_ids, target, poison, plen, stats, adopt_stats, out_tokens)``.
        The d_* descriptor arrays hold this dispatch's staged entries,
        shard-major ``[shard * S + i]`` (S = staging_slots per shard, FIFO
        within a shard); they are uploaded fresh per dispatch and consumed
        in order by the in-loop adoption scan. ``q_more`` is the per-shard
        starvation-exit flag: 1 while the host holds backlog beyond the
        staged set, letting the cond sync early once a row frees with the
        area drained (see ``cond``). Outputs keyed by descriptor:
        ``adopt_stats`` (S, 6) int32 ``[local_row, n, accepted,
        rounds_active, bad, gen_rounds]`` of the episode the adoption
        DISPLACED (-1 rows = descriptor not adopted), and ``out_tokens``
        (S, max_len) the displaced token row — the finished sequence whose
        slot was recycled mid-loop. The loop keeps running while any row is
        live OR descriptors remain unconsumed (adopted rows always start at
        ``n < target``, so every iteration makes progress toward one of the
        two bounds; ``r < k`` caps the trip count regardless)."""
        cfg = self.cfg

        def fn(params, paged, tables, tokens, n, cand, seq_ids, target,
               poison, plen, d_valid, d_tables, d_tokens, d_n, d_target,
               d_seq, d_poison, d_plen, q_more):
            R = tokens.shape[0]          # rows on this shard (B/D)
            S = d_valid.shape[0]         # staged descriptors on this shard
            max_len = tokens.shape[1]
            Wm = cand.shape[1]
            rows = jnp.arange(R)
            count = jnp.sum(d_valid)     # shard-local, no collective

            def one_round(paged, tokens, n, cand, tables, seq_ids, target,
                          poison, plen):
                if self.paged_attention:
                    cache = paged
                    pv = PagedView(tables, rows, self.use_attention_kernel)
                else:
                    cache = TransformerLM.gather_paged(cfg, paged,
                                                       tables, rows)
                    pv = None
                st = GenState(tokens, n, cand[:, :W], cache,
                              jnp.zeros((), jnp.int32),
                              jnp.zeros((R,), jnp.int32),
                              jnp.zeros((R,), jnp.int32), seq_ids)
                st2, rstats = verify_round(
                    params, cfg, self.eps_fn, st, target,
                    use_forecast_heads=self.use_forecast_heads,
                    use_verify_kernel=self.use_verify_kernel, paged=pv,
                    poison=poison, prompt_len=plen)
                if self.paged_attention:
                    paged2 = st2.cache
                else:
                    active = n < target
                    paged2 = TransformerLM.scatter_paged(
                        cfg, paged, st2.cache, tables, rows,
                        jnp.maximum(n - 1, 0), W, active)
                cand2 = jnp.zeros_like(cand).at[:, :W].set(st2.cand)
                return paged2, st2.tokens, st2.n, cand2, rstats

            def cond(carry):
                n_c, target_c, bad = carry[3], carry[6], carry[11]
                m, r = carry[14], carry[15]
                live = jnp.any((n_c < target_c) & (bad == 0))
                # starvation exit: a freed row with the staging area drained
                # while the host still holds backlog (q_more) means the
                # right move is to sync NOW and let the host restage —
                # idling to the k bound is the one cost adoption can't fix.
                # (After at least one round, so a dispatch always makes
                # progress even when admission is stuck on capacity.)
                free_now = (n_c >= target_c) | (bad > 0)
                starve = ((q_more[0] > 0) & (m >= count)
                          & jnp.any(free_now) & (r > 0))
                return (r < k) & (live | (m < count)) & ~starve

            def body(carry):
                (paged_c, tables_c, tokens_c, n_c, cand_c, seq_c, target_c,
                 poison_c, plen_c, acc, act, bad, gen, idle, m, r, astats,
                 otok) = carry
                # ---- in-loop adoption scan: freed/quarantined rows pull
                # the next staged descriptors, FIFO, without a sync -------
                free = (n_c >= target_c) | (bad > 0)
                rank = jnp.cumsum(free.astype(jnp.int32)) - 1
                desc = m + rank              # FIFO: row order breaks ties
                take = free & (desc < count)
                di = jnp.where(take, desc, S)    # S = scatter-drop sentinel
                # displaced episodes, keyed by descriptor (rank-2 scatters:
                # the pool never appears on the left of an adoption write)
                otok = otok.at[di].set(tokens_c, mode="drop")
                ep = jnp.stack([rows.astype(jnp.int32), n_c, acc, act, bad,
                                gen], axis=1)
                astats = astats.at[di].set(ep, mode="drop")
                src = jnp.clip(desc, 0, S - 1)
                tk = take[:, None]
                tokens_c = jnp.where(tk, d_tokens[src], tokens_c)
                tables_c = jnp.where(tk, d_tables[src], tables_c)
                n_c = jnp.where(take, d_n[src], n_c)
                seq_c = jnp.where(take, d_seq[src], seq_c)
                target_c = jnp.where(take, d_target[src], target_c)
                poison_c = jnp.where(take, d_poison[src], poison_c)
                plen_c = jnp.where(take, d_plen[src], plen_c)
                # adopted verify window: slots inside the prompt carry the
                # true prompt tokens (they source the K/V writes and the
                # forced matches); slot 0 = token at n0-1 is always covered
                p = (d_n[src] - 1)[:, None] + jnp.arange(Wm)[None, :]
                ptok = jnp.take_along_axis(
                    d_tokens[src], jnp.clip(p, 0, max_len - 1), axis=1)
                a_cand = jnp.where((p <= (d_plen[src] - 1)[:, None])
                                   & (jnp.arange(Wm)[None, :] < W), ptok, 0)
                cand_c = jnp.where(tk, a_cand, cand_c)
                # fresh episode accumulators + a zeroed recurrent row (the
                # adopted sequence replays its prompt from scratch there)
                acc = jnp.where(take, 0, acc)
                act = jnp.where(take, 0, act)
                bad = jnp.where(take, 0, bad)
                gen = jnp.where(take, 0, gen)
                idle = idle + (free & ~take).astype(jnp.int32)
                m = m + jnp.sum(take.astype(jnp.int32))
                if _has_recurrent(cfg):
                    def zrec(stacked, leaf):
                        shp = [1] * leaf.ndim
                        shp[1 if stacked else 0] = R
                        return jnp.where(take.reshape(shp),
                                         jnp.zeros((), leaf.dtype), leaf)

                    paged_c = TransformerLM._map_paged(
                        cfg, (paged_c,), lambda stacked, leaf: leaf, zrec)
                # ---- verify round (adopted rows prefill-by-window via
                # forced acceptance; resident rows are bit-identical to the
                # legacy body) -------------------------------------------
                active = (n_c < target_c).astype(jnp.int32)
                n_prev = n_c
                paged_c, tokens_c, n_c, cand_c, rstats = one_round(
                    paged_c, tokens_c, n_c, cand_c, tables_c, seq_c,
                    target_c, poison_c, plen_c)
                stuck = active * (n_c == n_prev).astype(jnp.int32)
                bad = bad | (active * rstats[:, 3]) | (stuck << 1)
                # accepted counts GENERATED tokens only (forced prompt
                # accepts are prefill throughput, not generation)
                acc = acc + jnp.maximum(n_c - jnp.maximum(n_prev, plen_c), 0)
                act = act + active
                gen = gen + active * (n_c > plen_c).astype(jnp.int32)
                return (paged_c, tables_c, tokens_c, n_c, cand_c, seq_c,
                        target_c, poison_c, plen_c, acc, act, bad, gen,
                        idle, m, r + 1, astats, otok)

            z = jnp.zeros((R,), jnp.int32)
            init = (paged, tables, tokens, n, cand, seq_ids, target,
                    poison, plen, z, z, z, z, z, jnp.zeros((), jnp.int32),
                    jnp.zeros((), jnp.int32),
                    jnp.full((S, 6), -1, jnp.int32),
                    jnp.zeros((S, max_len), jnp.int32))
            (paged2, tables2, tokens2, n2, cand2, seq2, target2, poison2,
             plen2, acc, act, bad, gen, idle, m, r, astats, otok) = \
                jax.lax.while_loop(cond, body, init)
            stats = jnp.stack(
                [acc, act, n2, jnp.broadcast_to(r, (R,)), bad, gen, idle],
                axis=1)
            return (paged2, tables2, tokens2, n2, cand2, seq2, target2,
                    poison2, plen2, stats, astats, otok)

        wrapped = self.topo.wrap_round(fn, self._paged_specs,
                                       n_batch_in=17, n_batch_out=11)
        # everything loop-carried is dead after the loop; descriptor
        # uploads (10..17) are rebuilt per dispatch but tiny — not donated
        donate = tuple(range(1, 10)) if self.donate else ()
        return jax.jit(wrapped, donate_argnums=donate)

    def _contract_check(self, kind: str, fn, args) -> None:
        """§17 contract seam: under ``REPRO_CHECK_CONTRACTS=1`` every
        compiled program is checked against its named contract once at
        first dispatch (zero collectives / pool-ranked scatters / host
        callbacks / f64, donation aliasing, recompile hazard). The label
        is per-engine so the recompile registry never mixes instances;
        ``donate=False`` engines skip the aliasing rule."""
        maybe_check(kind, fn, args, label=f"{kind}@{hex(id(self))}",
                    donate=self.donate, **self._contract_exemptions())

    def _contract_exemptions(self) -> dict:
        """Arch/topology refinements of the §17 contracts for THIS engine
        (consumed by ``maybe_check``/``check_engine_round``):

        * ``tensor_parallel`` — a model axis left to GSPMD all-reduces
          partial products every layer by design and does not preserve
          the manual pool-donation aliasing, so the data-axis-only rules
          (NoCollectives, DonationAliasCovers) don't apply.
        * ``pool_scatter_shapes`` — the exact KV-pool leaf shapes
          (global, plus per-data-shard on the block axis), narrowing
          NoPoolRankedScatters from the rank-3 proxy to real pool
          writes: MoE expert-dispatch buffers and recurrent per-slot
          state rows are high-rank scatters the round runs by design,
          while any scatter shaped like the pool itself is the dense
          writeback regression the fused epilogue eliminated.
        """
        shapes = set()
        d = self.topo.data_size

        def pool(stacked, leaf):
            s = tuple(leaf.shape)
            shapes.add(s)
            ax = 1 if stacked else 0     # block axis (data-sharded)
            if d > 1 and s[ax] % d == 0:
                per_shard = list(s)
                per_shard[ax] //= d
                shapes.add(tuple(per_shard))
            return leaf

        TransformerLM._map_paged(self.cfg, (self.paged,), pool,
                                 lambda st, leaf: leaf)
        return {"tensor_parallel": bool(self.topo.auto_axes),
                "pool_scatter_shapes": frozenset(shapes)}

    def _round_args(self) -> tuple:
        """Positional args of the jitted round loop, in ABI order — the one
        place that order is written down (tests and benches that drive the
        round fn directly build their calls through this). With staging
        enabled the tuple grows to the §15 ABI: ``plen`` plus the eight
        descriptor arrays of the current staging area."""
        base = (self.params, self.paged, self._tables_device(), self.tokens,
                self.n, self.cand, self._seq_device(), self._target_device(),
                self._poison_device())
        if self.staging_slots == 0:
            return base
        return base + (self._plen_device(),) + self._staged_args()

    def _staged_args(self) -> tuple:
        """Upload this dispatch's staging area as the eight shard-major
        descriptor arrays of the §15 ABI (data-sharded like the batch dim;
        rebuilt fresh per dispatch — entries come and go between syncs)."""
        packed = pack_staged_descriptors(
            self.staged, self.staging_slots, self.nb, self.max_len)
        # q_more: the starvation-exit signal — 1 while the host holds MORE
        # backlog beyond the staged set (a starved loop should sync so the
        # host can restage); 0 on the drain tail (nothing to restage, run
        # the loop out). One flag per shard (admission routes globally)
        q_more = np.full((self.topo.data_size,),
                         int(len(self.queue) > 0), np.int32)
        return tuple(self.topo.put_batch(a) for a in packed + (q_more,))

    def _prefill_fn(self, C: int):
        """Row-local chunked prefill. Runs as a plain (GSPMD) jit even under
        a mesh — a batch-1 write into one shard's sub-pool is admission-path
        work, so cross-shard traffic here is acceptable; ``table_row``
        carries GLOBAL pool ids (local id + shard offset). The old pool is
        donated, exactly like the round step."""
        if C not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, paged, table_row, row, chunk, start):
                if self.paged_attention:
                    view = PagedView(table_row, row,
                                     self.use_attention_kernel)
                    _, _, nc = TransformerLM.decode_window_paged(
                        params, cfg, chunk, paged, view, start)
                    sel = TransformerLM.select_states(
                        cfg, nc, jnp.full((1,), C, jnp.int32))
                    return TransformerLM.adopt_states_paged(
                        cfg, paged, sel, row)
                view = TransformerLM.gather_paged(cfg, paged, table_row, row)
                _, _, nc = TransformerLM.decode_window(
                    params, cfg, chunk, view, start)
                sel = TransformerLM.select_states(
                    cfg, nc, jnp.full((1,), C, jnp.int32))
                return TransformerLM.scatter_paged(
                    cfg, paged, sel, table_row, row, start, C,
                    jnp.ones((1,), bool))

            kw = {}
            sh = self.topo.paged_shardings(cfg, self.paged)
            if sh is not None:
                kw["out_shardings"] = sh
            donate = (1,) if self.donate else ()
            self._prefill_fns[C] = jax.jit(fn, donate_argnums=donate, **kw)
        return self._prefill_fns[C]

    def _copy_blocks_fn(self):
        """Jitted sequence-move step: copy ``nb`` pool block rows
        ``src_ids -> dst_ids`` (GLOBAL ids; unused lanes padded with the
        sink id 0, whose gathered garbage rewrites itself — deterministic
        and never read unmasked) and move the per-slot recurrent state row
        ``src_row -> dst_row`` (zeroing the source row, like
        ``_clear_row``). One compiled shape per engine: the id vectors are
        padded to the table width ``nb``. Under a mesh this is a plain
        GSPMD jit, exactly like row-local prefill: a migration's cross-
        shard block copy is admission-path work, never on the round hot
        path, and the output is pinned back to the sub-pool placement so
        zero collectives appear in the ROUND HLO (the CI gate)."""
        if self._copy_fn is None:
            cfg = self.cfg

            def fn(paged, src_ids, dst_ids, src_row, dst_row):
                def attn(stacked, leaf):
                    if stacked:
                        return leaf.at[:, dst_ids].set(leaf[:, src_ids])
                    return leaf.at[dst_ids].set(leaf[src_ids])

                def rec(stacked, leaf):
                    if stacked:
                        moved = leaf[:, src_row]
                        return (leaf.at[:, dst_row].set(moved)
                                .at[:, src_row].set(jnp.zeros_like(moved)))
                    moved = leaf[src_row]
                    return (leaf.at[dst_row].set(moved)
                            .at[src_row].set(jnp.zeros_like(moved)))

                return TransformerLM._map_paged(cfg, (paged,), attn, rec)

            kw = {}
            sh = self.topo.paged_shardings(cfg, self.paged)
            if sh is not None:
                kw["out_shardings"] = sh
            donate = (0,) if self.donate else ()
            self._copy_fn = jax.jit(fn, donate_argnums=donate, **kw)
        return self._copy_fn

    # -- slot / block plumbing ---------------------------------------------
    def _mgr(self, b: int):
        """The BlockManager of the data shard owning batch slot ``b``."""
        return self.pool.manager(self.topo.shard_of_slot(b, self.B))

    def _table_offset(self, b: int) -> int:
        """Global pool id of slot ``b``'s shard-local block 0."""
        return self.topo.block_offset(self.topo.shard_of_slot(b, self.B),
                                      self.pool.blocks_per_shard)

    def _ensure_capacity(self, b: int, upto_pos: int):
        """Grow slot ``b``'s block table to cover positions [0, upto_pos)."""
        need = -(-upto_pos // self.block_size)
        assert need <= self.nb, (need, self.nb)
        mgr = self._mgr(b)
        while len(self.owned[b]) < need:
            blk = mgr.alloc(1)[0]
            self.tables[b, len(self.owned[b])] = blk
            self.owned[b].append(blk)
            self._tables_dev = None

    def _clear_row(self, b: int, release: bool = True):
        """Reset a released slot so its (inactive) lane reads no stale or
        garbage cache positions: n=1, cache_len=0 -> only its own window.
        ``release=False`` keeps the block accounting untouched (migration
        moves ownership instead of freeing it). ``seq_ids`` is zeroed with
        the rest of the row: a stale noise-stream id was harmless only
        because inactive lanes are no-ops, and the preemption/migration
        paths are judged against rows being *fully* clean."""
        if release:
            self._mgr(b).release_all(self.owned[b])
        self.owned[b] = []
        self.tables[b] = 0
        self.target[b] = 0
        self.reserved[b] = 0
        self.n_host[b] = 1
        self._tables_dev = None
        self._target_dev = None
        if self.poison[b]:
            self.poison[b] = 0
            self._poison_dev = None
        if self.plen[b]:
            self.plen[b] = 0
            self._plen_dev = None
        if self.seq_ids[b]:
            self.seq_ids[b] = 0
            self._seq_dev = None
        self.tokens = self.tokens.at[b].set(0)
        self.n = self.n.at[b].set(1)
        self.cand = self.cand.at[b].set(0)

    def _reset_recurrent_row(self, b: int):
        def rec(stacked, leaf):
            return leaf.at[:, b].set(0) if stacked else leaf.at[b].set(0)

        self.paged = TransformerLM._map_paged(
            self.cfg, (self.paged,), lambda stacked, leaf: leaf, rec)

    def _tables_device(self):
        if self._tables_dev is None:
            self._tables_dev = self.topo.put_batch(self.tables)
        return self._tables_dev

    def _target_device(self):
        if self._target_dev is None:
            self._target_dev = self.topo.put_batch(
                self.target.astype(np.int32))
        return self._target_dev

    def _poison_device(self):
        if self._poison_dev is None:
            self._poison_dev = self.topo.put_batch(self.poison)
        return self._poison_dev

    def _seq_device(self):
        if self._seq_dev is None:
            self._seq_dev = self.topo.put_batch(self.seq_ids)
        return self._seq_dev

    def _plen_device(self):
        if self._plen_dev is None:
            self._plen_dev = self.topo.put_batch(
                self.plen.astype(np.int32))
        return self._plen_dev

    def _set_poison(self, b: int, req: Request):
        """Refresh slot ``b``'s poison-mask entry for its new occupant."""
        v = int(self.faults is not None
                and req.seq_id in self.faults.poison_streams)
        if int(self.poison[b]) != v:
            self.poison[b] = v
            self._poison_dev = None

    # -- host cache tier plumbing (DESIGN.md §13) ----------------------------
    def _collect_block_payload(self, gids) -> list:
        """Attention pool rows for GLOBAL block ids ``gids``: ONE device
        pull, split host-side into a flat row list per block. Row order is
        the ``_map_paged`` leaf walk — ``_merge_block_rows`` replays the
        same walk, so the flat encoding round-trips without a schema."""
        if len(gids) == 0:
            return []
        g = jnp.asarray(np.asarray(gids, np.int32))
        flags, pulled = [], []

        def attn(stacked, leaf):
            flags.append(stacked)
            pulled.append(leaf[:, g] if stacked else leaf[g])
            return leaf

        TransformerLM._map_paged(self.cfg, (self.paged,), attn,
                                 lambda stacked, leaf: leaf)
        host = jax.device_get(pulled)
        return [[a[:, j] if st else a[j] for st, a in zip(flags, host)]
                for j in range(len(gids))]

    def _merge_block_rows(self, gid: int, rows):
        """Write one block's attention rows (``_map_paged`` walk order)
        into the pool at GLOBAL id ``gid`` — the same admission-path
        ``.at[].set`` merge the exact-resume upload uses; the round
        jaxpr/HLO never sees it."""
        it = iter(rows)

        def attn(stacked, leaf):
            a = next(it)
            if not isinstance(a, jax.Array):
                # explicit host copy: never let the device buffer alias an
                # arena slab that a later put may recycle
                a = jnp.asarray(np.array(a))
            return leaf.at[:, gid].set(a) if stacked else leaf.at[gid].set(a)

        self.paged = TransformerLM._map_paged(self.cfg, (self.paged,), attn,
                                              lambda stacked, leaf: leaf)

    def _collect_rec_row(self, b: int) -> list:
        """Slot ``b``'s recurrent state rows (leaf walk order), on host."""
        pulled = []

        def rec(stacked, leaf):
            pulled.append(leaf[:, b] if stacked else leaf[b])
            return leaf

        TransformerLM._map_paged(self.cfg, (self.paged,),
                                 lambda stacked, leaf: leaf, rec)
        return list(jax.device_get(pulled))

    def _restore_rec_row(self, b: int, rows):
        it = iter(rows)

        def rec(stacked, leaf):
            a = jnp.asarray(np.array(next(it)))
            return leaf.at[:, b].set(a) if stacked else leaf.at[b].set(a)

        self.paged = TransformerLM._map_paged(self.cfg, (self.paged,),
                                              lambda stacked, leaf: leaf, rec)

    def _make_spill_hook(self, shard: int):
        """BlockManager eviction -> host tier: when a registered cached-free
        block is reclaimed, copy its contents D2H into the arena under its
        chain key (skipping the pull when the key is already resident —
        chained keys are content-addressed). Returns None (drop outright)
        without a tier or attention leaves to spill."""
        if self.tier is None or not self.has_attn:
            return None
        off = self.topo.block_offset(shard, self.pool.blocks_per_shard)

        def hook(local_bid: int, key) -> bool:
            if self.tier.has_kv(shard, key):
                return True
            rows = self._collect_block_payload([local_bid + off])[0]
            return self.tier.put_kv(shard, key, rows)

        return hook

    def _stage_host_blocks(self, b: int, mgr, host_keys, pos0: int,
                           prefetched: Optional[dict] = None) -> int:
        """Re-admit host-resident KV blocks into slot ``b``'s table
        positions ``[pos0, pos0 + len(host_keys))`` through the async
        staging ring: upload ``k+1`` dispatches while ``k``'s merge is
        still executing (double-buffered, ``staging.depth`` in flight).
        The run is pinned first so the block allocations below — whose
        evictions spill INTO the same arena — cannot evict it mid-flight;
        a pin that fails truncates the run and prefill covers the rest.

        Partial failure (DESIGN.md §14): a staging run that dies mid-ring —
        an injected/real ``StagingFault``, an allocation failure, a corrupt
        entry read — must leave NOTHING behind: the ring is cleared so the
        next caller cannot ``take()`` uploads staged for this slot's table,
        and only blocks that completed the merge+register pair count as
        staged; everything short of that is rewritten by prefill (staging
        is a pure optimization, truncation is always safe). Returns the
        number of blocks staged."""
        shard = self.topo.shard_of_slot(b, self.B)
        pinned = []
        for key in host_keys:
            if prefetched is not None and key in prefetched:
                pinned.append(key)   # device-resident copy: no pin needed
                continue
            if not self.tier.pin_kv(shard, key):
                break
            pinned.append(key)
        try:
            self._ensure_capacity(
                b, (pos0 + len(pinned)) * self.block_size)
        except Exception:
            for key in pinned:
                if prefetched is None or key not in prefetched:
                    self.tier.unpin_kv(shard, key)
            raise
        try:
            staged = self._restage_host_blocks(
                shard, mgr, pinned,
                self.owned[b][pos0:pos0 + len(pinned)],
                prefetched=prefetched)
        finally:
            for key in pinned:
                if prefetched is None or key not in prefetched:
                    self.tier.unpin_kv(shard, key)
        return staged

    def _restage_host_blocks(self, shard: int, mgr, host_keys, block_ids,
                             prefetched: Optional[dict] = None) -> int:
        """The slot-less core of host-tier restaging (§13/§15): merge the
        tier entries under ``host_keys`` into the already-allocated
        shard-local ``block_ids`` (1:1, key order) through the async
        staging ring, registering each completed block. Callers own
        pinning and capacity. ``prefetched`` maps chain keys to device
        rows uploaded while the request was still queued (§15 prefetch):
        those merge directly — no pull, no H2D wait — and count
        ``prefetch_hits``; the ring is drained first so completed merges
        always form a key-order prefix (the contiguity every caller's
        coverage math depends on). Returns the number of blocks merged."""
        off = self.topo.block_offset(shard, self.pool.blocks_per_shard)
        ring = self.tier.staging
        staged = 0
        try:
            for j, key in enumerate(host_keys):
                if prefetched is not None and key in prefetched:
                    while True:          # keep commitment in key order
                        item = ring.take()
                        if item is None:
                            break
                        (blk, k2), devs = item
                        self._merge_block_rows(blk + off, devs)
                        mgr.register(blk, k2)
                        staged += 1
                    self._merge_block_rows(block_ids[j] + off,
                                           prefetched[key])
                    mgr.register(block_ids[j], key)
                    staged += 1
                    self.metrics.prefetch_hits += 1
                    continue
                rows = self.tier.get_kv(shard, key)   # counts the host hit
                if rows is None:     # corrupt/tripped mid-run: truncate
                    break
                ring.stage((block_ids[j], key), rows)
                if len(ring) >= ring.depth:           # drain behind the ring
                    (blk, k2), devs = ring.take()
                    self._merge_block_rows(blk + off, devs)
                    mgr.register(blk, k2)
                    staged += 1
            while True:
                item = ring.take()
                if item is None:
                    break
                (blk, k2), devs = item
                self._merge_block_rows(blk + off, devs)
                mgr.register(blk, k2)
                staged += 1
        except Exception:
            # drop every in-flight upload (staged-but-unmerged blocks are
            # rewritten by prefill — `staged` only counts completed merges)
            ring.clear()
            self.metrics.staging_errors += 1
            self.tier.record_failure()
        self.metrics.host_staged_blocks += staged
        return staged

    # -- sequence migration / priority preemption (DESIGN.md §12) -----------
    def _live_blocks(self, b: int) -> int:
        """Leading owned blocks whose contents the next round still reads:
        those holding positions [0, n-1). The verify window re-encodes
        position n-1 onward every round (slot 0 carries the last accepted
        token), so later blocks are garbage-by-design and need no spill."""
        return -(-max(int(self.n_host[b]) - 1, 0) // self.block_size)

    def _park_payload(self, b: int, nb_live: int) -> dict:
        """Device->host pull of everything slot ``b``'s exact resume needs
        from the cache: the ``nb_live`` pool block rows (attention leaves,
        in table order) and the per-slot recurrent state row."""
        gids = jnp.asarray(self.tables[b, :nb_live].astype(np.int32)
                           + self._table_offset(b))

        def attn(stacked, leaf):
            return leaf[:, gids] if stacked else leaf[gids]

        def rec(stacked, leaf):
            return leaf[:, b] if stacked else leaf[b]

        return jax.device_get(TransformerLM._map_paged(
            self.cfg, (self.paged,), attn, rec))

    def preempt_slot(self, b: int) -> Request:
        """Evict the running slot ``b``: spill its live block contents (and
        recurrent state) to a host-side parking entry, release its blocks
        and slot, and requeue the request (original submit time + arrival
        order) for exact resume. Tokens of the resumed run are bitwise
        those of an uninterrupted one: the parked n/cand snapshot restores
        the verify window exactly and noise streams are position-keyed."""
        req = self.slots[b]
        assert req is not None, f"slot {b} is not occupied"
        nb_live = self._live_blocks(b)
        if self.tier is None:
            self.parked[req.uid] = ParkedSequence(
                n=int(self.n_host[b]),
                tokens=np.asarray(self.tokens[b]),
                cand=np.asarray(self.cand[b]),
                nb_live=nb_live,
                payload=self._park_payload(b, nb_live))
        else:
            self.parked[req.uid] = self._park_tiered(req, b, nb_live)
        self._mgr(b).spill(self.owned[b])
        self.owned[b] = []
        self.slots[b] = None
        self._clear_row(b, release=False)
        self.queue.requeue(req)
        self._journal("park", uid=int(req.uid))
        req.preemptions += 1
        self.metrics.preemptions += 1
        self.metrics.blocks_parked += nb_live
        return req

    def _park_tiered(self, req: Request, b: int, nb_live: int) -> ParkedSequence:
        """Park into the host tier (DESIGN.md §13): the victim's full
        prompt blocks go to the shared ``kv`` namespace — refcount-pinned,
        stored ONCE however many victims share the prefix — and only the
        private remainder (tail block rows + the recurrent state row) is
        parked per victim: in the arena when it fits, raw host memory as
        the overflow fallback (parking must never fail)."""
        shard = self.topo.shard_of_slot(b, self.B)
        off = self._table_offset(b)
        prompt = np.asarray(req.prompt)
        nb_pub = (min((len(prompt) - 1) // self.block_size, nb_live)
                  if self._kv_share else 0)
        keys = chain_hashes(prompt, self.block_size, nb_pub)
        # pull only the blocks whose keys are not already arena-resident
        # (content-addressed: a resident entry IS this block's contents)
        need = [jb for jb in range(nb_pub)
                if not self.tier.has_kv(shard, keys[jb])]
        payloads = dict(zip(need, self._collect_block_payload(
            [int(self.tables[b, jb]) + off for jb in need])))
        kv_keys = []
        for jb in range(nb_pub):
            ok = (self.tier.put_kv(shard, keys[jb], payloads[jb], pin=True)
                  if jb in payloads else self.tier.pin_kv(shard, keys[jb]))
            if not ok:          # arena full / entry evicted: rest goes private
                break
            kv_keys.append(keys[jb])
        tail = self._collect_block_payload(
            [int(self.tables[b, jb]) + off
             for jb in range(len(kv_keys), nb_live)]) if self.has_attn \
            else [[] for _ in range(len(kv_keys), nb_live)]
        rec = self._collect_rec_row(b) if _has_recurrent(self.cfg) else []
        private = list(rec)
        for rows in tail:
            private.extend(rows)
        in_arena = self.tier.put_park(req.uid, private)
        return ParkedSequence(
            n=int(self.n_host[b]), tokens=np.asarray(self.tokens[b]),
            cand=np.asarray(self.cand[b]), nb_live=nb_live,
            kv_keys=tuple(kv_keys), n_rec=len(rec),
            rows_per_block=len(tail[0]) if tail else 0,
            in_arena=in_arena, private=None if in_arena else private,
            shard=shard)

    def _resume(self, req: Request, b: int, parked: ParkedSequence):
        """Re-admit a parked request into slot ``b`` exactly where it left
        off: re-hit still-valid prefix blocks, upload the parked contents of
        the rest (host tier or legacy payload), restore the per-slot
        n/cand/tokens snapshot."""
        req.admit_time = time.monotonic()
        if parked.cold:
            # checkpoint-restored park (§16): no payload or pins exist in
            # this process — rebuild through the disk-tier fall-through +
            # re-prefill (bitwise-exact either way)
            self.metrics.resume_recomputes += 1
            return self._resume_cold(req, b, parked)
        if parked.payload is None:
            return self._resume_tiered(req, b, parked)
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        mgr = self._mgr(b)
        nb_live = parked.nb_live
        # full prompt blocks may have survived the spill in this shard's
        # prefix cache (spill leaves hashed blocks cached-free) — re-hit
        # them instead of re-uploading
        hits, keys = [], []
        nb_full = min((L_p - 1) // self.block_size, nb_live)
        if self.prefix_enabled and nb_full:
            hits, keys = mgr.lookup_prefix(prompt, nb_full)
        req.prefix_hit_blocks += len(hits)
        # hits are owned the moment lookup returns: record them BEFORE the
        # (fault-injectable) alloc so an unwind releases them (§14)
        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._tables_dev = None
        fresh = mgr.alloc(nb_live - len(hits))
        owned = list(hits) + fresh
        self.owned[b] = list(owned)
        self.tables[b, :nb_live] = owned

        # upload the parked payload: non-hit block rows + the recurrent row
        fresh_pos = np.arange(len(hits), nb_live)
        gids = jnp.asarray(np.asarray(fresh, np.int64).astype(np.int32)
                           + self._table_offset(b))

        def attn(stacked, pleaf, kleaf):
            if len(fresh_pos) == 0:
                return pleaf
            if stacked:
                return pleaf.at[:, gids].set(jnp.asarray(kleaf[:, fresh_pos]))
            return pleaf.at[gids].set(jnp.asarray(kleaf[fresh_pos]))

        def rec(stacked, pleaf, kleaf):
            if stacked:
                return pleaf.at[:, b].set(jnp.asarray(kleaf))
            return pleaf.at[b].set(jnp.asarray(kleaf))

        self.paged = TransformerLM._map_paged(
            self.cfg, (self.paged, parked.payload), attn, rec)

        # per-slot state: the exact park-time snapshot
        self.tokens = self.tokens.at[b].set(
            jnp.asarray(parked.tokens, jnp.int32))
        self.n = self.n.at[b].set(parked.n)
        self.cand = self.cand.at[b].set(jnp.asarray(parked.cand, jnp.int32))
        self.seq_ids[b] = req.seq_id
        self._seq_dev = None
        self.n_host[b] = parked.n

        # re-publish the freshly uploaded full prompt blocks
        if self.prefix_enabled:
            for j in range(len(hits), nb_full):
                mgr.register(owned[j], keys[j])

        self.slots[b] = req
        self._set_poison(b, req)
        self.target[b] = L_p + req.new_tokens
        self._target_dev = None
        if self.plen[b] != L_p:
            self.plen[b] = L_p
            self._plen_dev = None
        self.reserved[b] = self._worst_case_blocks(req)
        self.metrics.resumes += 1

    def _resume_tiered(self, req: Request, b: int, parked: ParkedSequence):
        """Exact resume from a tier-split park: device re-hits first (spill
        left hashed blocks cached-free), then the pinned shared ``kv``
        entries, then the private tail rows; the recurrent row is restored
        bit-exactly from the private part, so device KV hits need no
        snapshot gating here (unlike a fresh admission).

        The whole parked payload is prefetched BEFORE any engine state is
        touched (§14): a piece gone missing — a checksum failure demoted
        the entry to a miss, the breaker tripped, the arena evicted under
        pressure — then routes to :meth:`_resume_cold` (recompute) with
        nothing to unwind. Prefetched shared rows stay valid until the park
        pins drop at the end; the merge copies them out."""
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        mgr = self._mgr(b)
        # the pinned kv entries live under the PARKING shard's tier
        # partition — resume may land elsewhere (mesh routing), and the
        # entries are content-addressed, so read them where they are
        shard = parked.shard
        off = self._table_offset(b)
        nb_live = parked.nb_live
        n_shared = len(parked.kv_keys)

        private = (self.tier.take_park(req.uid) if parked.in_arena
                   else (parked.private or []))
        shared, missing = [], parked.in_arena and private is None
        if not missing:
            for key in parked.kv_keys:
                rows = self.tier.get_kv(shard, key)
                if rows is None:      # pinned entry corrupt / tier tripped
                    missing = True
                    break
                shared.append(rows)
        if missing:
            self._discard_park(req.uid, parked)
            self.metrics.resume_recomputes += 1
            return self._resume_cold(req, b, parked)
        # private payload: recurrent row arrays first, then the rows of
        # tail blocks [n_shared, nb_live) (flat, rows_per_block each)
        rec_rows = private[:parked.n_rec]
        tail = private[parked.n_rec:]
        rpb = parked.rows_per_block

        hits, keys = [], []
        nb_full = min((L_p - 1) // self.block_size, nb_live)
        if self._kv_share and nb_full:
            hits, keys = mgr.lookup_prefix(prompt, nb_full)
        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._tables_dev = None
        fresh = mgr.alloc(nb_live - len(hits))
        owned = list(hits) + fresh
        self.owned[b] = list(owned)
        self.tables[b, :nb_live] = owned

        host_restored = 0
        for jb in range(len(hits), nb_live):
            if jb < n_shared:
                rows = shared[jb]
                host_restored += 1
            else:
                t0 = (jb - n_shared) * rpb
                rows = tail[t0:t0 + rpb]
            self._merge_block_rows(owned[jb] + off, rows)
        req.prefix_hit_blocks += len(hits) + host_restored
        if _has_recurrent(self.cfg):
            self._restore_rec_row(b, rec_rows)

        # per-slot state: the exact park-time snapshot
        self.tokens = self.tokens.at[b].set(
            jnp.asarray(parked.tokens, jnp.int32))
        self.n = self.n.at[b].set(parked.n)
        self.cand = self.cand.at[b].set(jnp.asarray(parked.cand, jnp.int32))
        self.seq_ids[b] = req.seq_id
        self._seq_dev = None
        self.n_host[b] = parked.n

        # re-publish the rebuilt full prompt blocks, drop the park pins
        if self._kv_share:
            for jb in range(len(hits), nb_full):
                mgr.register(owned[jb], keys[jb])
        for key in parked.kv_keys:
            self.tier.unpin_kv(shard, key)

        self.slots[b] = req
        self._set_poison(b, req)
        self.target[b] = L_p + req.new_tokens
        self._target_dev = None
        if self.plen[b] != L_p:
            self.plen[b] = L_p
            self._plen_dev = None
        self.reserved[b] = self._worst_case_blocks(req)
        self.metrics.resumes += 1

    def _resume_cold(self, req: Request, b: int, parked: ParkedSequence):
        """Rebuild a parked slot by recompute when its payload is gone
        (corruption demoted to a miss, tripped tier, arena eviction):
        re-prefill positions ``[0, n-1)`` from the parked accepted-token
        row, then restore the ``n``/``cand``/``tokens`` snapshot. K/V (and
        recurrent state) at a position are pure functions of the preceding
        tokens and chunk decomposition is bitwise-invariant — the standing
        exactness invariant every prefill path rests on — so a cold resume
        emits tokens bitwise identical to a warm one; it just pays prefill
        compute (``resume_recomputes`` counts these)."""
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        mgr = self._mgr(b)
        n = parked.n
        nb_live = parked.nb_live
        toks = np.asarray(parked.tokens, np.int64)
        # recurrent archs would need the state snapshot at any reuse
        # boundary — gone with the payload — so they rebuild from zero;
        # attention archs re-hit device-cached prompt blocks AND fall
        # through to the host/disk tiers (§16: after a restart the device
        # cache is empty but the chain keys still resolve on disk — this
        # is exactly where a warm restart earns its fewer prefill chunks)
        hits, keys, host_keys = [], [], []
        nb_full = min((L_p - 1) // self.block_size, nb_live)
        if self._kv_share and nb_full and not _has_recurrent(self.cfg):
            if self.tier is not None:
                hits, keys, host_keys = mgr.lookup_prefix_tiered(
                    prompt, nb_full, tier=self.tier,
                    shard=self.topo.shard_of_slot(b, self.B))
            else:
                hits, keys = mgr.lookup_prefix(prompt, nb_full)
        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._tables_dev = None
        staged = (self._stage_host_blocks(b, mgr, host_keys, len(hits))
                  if host_keys else 0)
        req.prefix_hit_blocks += len(hits) + staged
        self._ensure_capacity(b, nb_live * self.block_size)
        if _has_recurrent(self.cfg):
            self._reset_recurrent_row(b)

        start = (len(hits) + staged) * self.block_size
        table_row = jnp.asarray(self.tables[b:b + 1] + self._table_offset(b))
        row = jnp.asarray([b], jnp.int32)
        for C in prefill_chunks(n - 1 - start, self.prefill_chunk):
            chunk = jnp.asarray(toks[None, start:start + C], jnp.int32)
            pf = self._prefill_fn(C)
            pf_args = (self.params, self.paged, table_row, row, chunk,
                       jnp.asarray([start], jnp.int32))
            self._contract_check("prefill", pf, pf_args)
            self.paged = pf(*pf_args)
            start += C
            req.prefill_calls += 1
            self.metrics.prefill_calls += 1
        if self._kv_share and not _has_recurrent(self.cfg):
            for j in range(len(hits) + staged, nb_full):
                mgr.register(self.owned[b][j], keys[j])

        # per-slot state: the exact park-time snapshot
        self.tokens = self.tokens.at[b].set(
            jnp.asarray(parked.tokens, jnp.int32))
        self.n = self.n.at[b].set(n)
        self.cand = self.cand.at[b].set(jnp.asarray(parked.cand, jnp.int32))
        self.seq_ids[b] = req.seq_id
        self._seq_dev = None
        self.n_host[b] = n

        self.slots[b] = req
        self._set_poison(b, req)
        self.target[b] = L_p + req.new_tokens
        self._target_dev = None
        if self.plen[b] != L_p:
            self.plen[b] = L_p
            self._plen_dev = None
        self.reserved[b] = self._worst_case_blocks(req)
        self.metrics.resumes += 1

    def _discard_park(self, uid: int, parked: ParkedSequence):
        """Release a parked payload's tier resources without resuming it
        (cancel, failed resume): the park entry and the shared-kv pins.
        Tolerant of partial consumption — ``drop``/``unpin`` are no-ops on
        already-consumed entries."""
        if self.tier is None or parked.cold:
            # a cold (checkpoint-restored) park holds no pins in THIS
            # process — unpinning its keys could steal a pin a live park
            # of the same prefix legitimately owns (§16)
            return
        if parked.in_arena:
            self.tier.drop_park(uid)
        for key in parked.kv_keys:
            self.tier.unpin_kv(parked.shard, key)

    def migrate_slot(self, b_src: int, b_dst: int):
        """Move a live sequence to a free slot: across shard sub-pools
        under a mesh (device block copy into freshly allocated landing
        blocks + one table-row re-upload + per-slot state move) or within
        one (the blocks stay put; only the table row and state move).
        Bit-exact by construction — tokens and noise streams are
        placement-independent, and the block contents are copied bitwise.
        Callers are responsible for capacity: a cross-shard move needs
        ``len(owned)`` free blocks on the destination shard (and should
        leave its outstanding reservations coverable — ``_try_rebalance``
        checks ``reserved`` before moving)."""
        req = self.slots[b_src]
        assert req is not None, f"slot {b_src} is not occupied"
        assert self.slots[b_dst] is None, f"slot {b_dst} is occupied"
        s = self.topo.shard_of_slot(b_src, self.B)
        t = self.topo.shard_of_slot(b_dst, self.B)
        n_owned = len(self.owned[b_src])
        src_ids = np.zeros(self.nb, np.int32)   # sink-padded: id 0 -> id 0
        dst_ids = np.zeros(self.nb, np.int32)
        if s == t:
            new_owned = list(self.owned[b_src])   # blocks stay put
        else:
            new_owned = self.pool.begin_migration(s, t, n_owned)
            src_ids[:n_owned] = (self.tables[b_src, :n_owned]
                                 + self._table_offset(b_src))
            dst_ids[:n_owned] = (np.asarray(new_owned, np.int32)
                                 + self._table_offset(b_dst))
            self.metrics.blocks_migrated += n_owned
        copy_fn = self._copy_blocks_fn()
        copy_args = (self.paged, jnp.asarray(src_ids), jnp.asarray(dst_ids),
                     jnp.asarray(b_src, jnp.int32),
                     jnp.asarray(b_dst, jnp.int32))
        self._contract_check("migration_copy", copy_fn, copy_args)
        self.paged = copy_fn(*copy_args)
        if s != t:
            self.pool.finish_migration(s, self.owned[b_src])
            if self._kv_share:
                # re-publish the copied full prompt blocks under the
                # destination shard's cache (content-identical; first
                # writer wins)
                prompt = np.asarray(req.prompt)
                nb_full = min((len(prompt) - 1) // self.block_size, n_owned)
                keys = chain_hashes(prompt, self.block_size, nb_full)
                for j in range(nb_full):
                    self.pool.manager(t).register(new_owned[j], keys[j])

        # per-slot device rows ride along (the recurrent state row moved
        # inside the copy step)
        for name in ("tokens", "cand"):
            arr = getattr(self, name)
            setattr(self, name, arr.at[b_dst].set(arr[b_src]))
        self.n = self.n.at[b_dst].set(self.n[b_src])
        if self.seq_ids[b_dst] != self.seq_ids[b_src]:
            self.seq_ids[b_dst] = self.seq_ids[b_src]
            self._seq_dev = None
        if self.plen[b_dst] != self.plen[b_src]:
            self.plen[b_dst] = self.plen[b_src]
            self._plen_dev = None

        # host-side bookkeeping moves, then the source row is cleared
        # WITHOUT releasing (ownership moved, it was not freed)
        self.tables[b_dst] = 0
        self.tables[b_dst, :n_owned] = new_owned
        self.owned[b_dst] = list(new_owned)
        self.slots[b_dst] = req
        self.target[b_dst] = self.target[b_src]
        self.reserved[b_dst] = self.reserved[b_src]
        self.n_host[b_dst] = self.n_host[b_src]
        if self.poison[b_dst] != self.poison[b_src]:
            self.poison[b_dst] = self.poison[b_src]
            self._poison_dev = None
        self.slots[b_src] = None
        self.owned[b_src] = []
        self._clear_row(b_src, release=False)
        req.migrations += 1
        self.metrics.migrations += 1

    # -- staging area / in-loop adoption (DESIGN.md §15) ---------------------
    def _staged_total(self) -> int:
        return sum(len(entries) for entries in self.staged)

    def _unstage_all(self):
        """Return every staged entry to the queue (``requeue`` preserves
        the original arrival rank) and its worst-case blocks to the pool
        (registered restaged blocks drop to cached-free — still hittable)."""
        for s in range(self.topo.data_size):
            mgr = self.pool.manager(s)
            for e in self.staged[s]:
                mgr.release_all(e.blocks)
                self.ledger.release(s, e.req.uid)
                self.queue.requeue(e.req)
            self.staged[s] = []

    def _reconcile_staging(self):
        """Re-assert the staging invariants at every sync boundary: staged
        entries exist ONLY while every slot is occupied (a free slot hands
        the backlog back to full lookahead/preempt/rebalance admission,
        which the device adoption scan cannot replicate), and the area
        never outranks the queue head (a higher-priority arrival unstages
        it instead of waiting behind committed descriptors)."""
        if self._staged_total() == 0:
            return
        if any(s is None for s in self.slots):
            self._unstage_all()
            return
        head = self.queue.peek()
        if head is not None:
            hk = (head.priority, head.deadline_time, head._seq)
            if any(hk < e.key for entries in self.staged for e in entries):
                self._unstage_all()

    def _build_staged(self, req: Request, shard: int,
                      need: int) -> StagedEntry:
        """Build one staged entry on ``shard``: worst-case blocks up front
        (an adopted row never allocates mid-loop — the same run-to-
        completion guarantee admission reserves), with device prefix hits
        and host-tier restaged blocks covering the leading prompt
        positions. Recurrent stacks stage from scratch: their un-paged
        state row is zeroed at adoption, so a KV prefix without its
        boundary snapshot would desynchronize. Freshly allocated blocks
        are NOT registered in the prefix cache — their contents only
        become valid as the in-loop forced prefill writes them."""
        mgr = self.pool.manager(shard)
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        hits, keys, host_keys = [], [], []
        nb_full = (L_p - 1) // self.block_size
        if self._kv_share and nb_full and not _has_recurrent(self.cfg):
            hits, keys, host_keys = mgr.lookup_prefix_tiered(
                prompt, nb_full, tier=self.tier, shard=shard)
        try:
            fresh = mgr.alloc(need - len(hits))
        except Exception:
            mgr.release_all(hits)
            raise
        blocks = list(hits) + fresh
        try:
            staged_host = 0
            if host_keys and self.tier is not None:
                pre = self._take_prefetched(req.uid, shard)
                pinned = []
                for key in host_keys:
                    if pre is not None and key in pre:
                        pinned.append(key)    # device copy: no pin needed
                        continue
                    if not self.tier.pin_kv(shard, key):
                        break
                    pinned.append(key)
                try:
                    staged_host = self._restage_host_blocks(
                        shard, mgr, pinned,
                        blocks[len(hits):len(hits) + len(pinned)],
                        prefetched=pre)
                finally:
                    for key in pinned:
                        if pre is None or key not in pre:
                            self.tier.unpin_kv(shard, key)
        except Exception:
            mgr.release_all(blocks)
            raise
        cov = len(hits) + staged_host
        req.prefix_hit_blocks = cov
        table_row = np.zeros(self.nb, np.int32)
        table_row[:len(blocks)] = blocks
        poison = int(self.faults is not None
                     and req.seq_id in self.faults.poison_streams)
        return StagedEntry(
            req=req, shard=shard, prompt=prompt.astype(np.int32),
            n0=cov * self.block_size + 1, plen=L_p,
            target=L_p + req.new_tokens, blocks=blocks,
            table_row=table_row, poison=poison,
            key=(req.priority, req.deadline_time, req._seq))

    def _stage_pending(self):
        """Fill the staging area from the queue, strictly in queue order
        (§15): runs after host admission, only while every slot is
        occupied. Stops at the first request that cannot stage — skipping
        it would let a later request adopt first and invert the committed
        order. Block claims go through the ``StagingLedger``, so staging
        only ever consumes headroom net of resident reservations."""
        if self.staging_slots == 0 or not self.queue:
            return
        if any(s is None for s in self.slots):
            return
        D = self.topo.data_size
        capacity = sum(self.staging_slots - len(self.staged[s])
                       for s in range(D))
        if capacity <= 0:
            return
        for req in self.queue.lookahead(capacity):
            if req.uid in self.parked:
                break       # parked resumes need the host admission path
            need = self._worst_case_blocks(req)
            best = None
            for s in range(D):
                if len(self.staged[s]) >= self.staging_slots:
                    continue
                h = self._headroom(s)
                if h >= need and (best is None or h > best[1]):
                    best = (s, h)
            if best is None:
                break
            s, h = best
            if not self.ledger.try_claim(s, req.uid, need, h):
                break
            try:
                entry = self._build_staged(req, s, need)
            except Exception:
                # staging is a pure optimization: leave the request queued
                # (host admission will retry it) and stop the pass
                self.ledger.release(s, req.uid)
                break
            self.queue.remove(req)
            self._drop_prefetched(req.uid)
            self.staged[s].append(entry)
            self.metrics.staged_sequences += 1

    def _take_prefetched(self, uid: int, shard: int) -> Optional[dict]:
        """Claim ``uid``'s prefetched device rows for an admission or
        staging on ``shard`` — None when nothing was prefetched or the
        copies live under another shard's key partition."""
        ent = self._prefetched.pop(uid, None)
        if ent is None:
            return None
        p_shard, rows = ent
        return rows if p_shard == shard else None

    def _drop_prefetched(self, uid: int):
        self._prefetched.pop(uid, None)

    def _prefetch_queued(self):
        """Proactive host-tier prefetch (§15 satellite): while a request
        waits in the queue, push its host-resident prefix blocks through
        the async staging ring ahead of time; admission/staging later
        merges the device-resident copies (``prefetch_hits``) instead of
        paying the pull + H2D wait inline. Copies are content-addressed
        and immutable, so no pins are held; entries for requests that left
        the queue are dropped here."""
        if (not self.host_prefetch or self.tier is None
                or not self.kv_prefix or self.prefetch_budget == 0):
            return
        queued = {r.uid for r in self.queue.requests()}
        for uid in list(self._prefetched):
            if uid not in queued:
                self._drop_prefetched(uid)
        budget = self.prefetch_budget
        for req in self.queue.lookahead(max(self.lookahead, 1)):
            if budget <= 0:
                break
            if req.uid in self._prefetched or req.uid in self.parked:
                continue
            prompt = np.asarray(req.prompt, np.int64)
            nb_full = (len(prompt) - 1) // self.block_size
            if nb_full <= 0:
                continue
            keys = chain_hashes(prompt, self.block_size, nb_full)
            # route guess: the max-headroom shard an admission would pick;
            # a different landing shard just wastes the copies
            shard = max(range(self.topo.data_size), key=self._headroom)
            ring = self.tier.staging
            rows_by_key = {}
            try:
                for key in keys:
                    if budget <= 0:
                        break
                    if not self.tier.has_kv(shard, key):
                        break           # contiguous leading run only
                    rows = self.tier.get_kv(shard, key)
                    if rows is None:
                        break
                    # private host copies: prefetch holds no pins, and the
                    # ring's device_put is async — a slab view could be
                    # evicted and rewritten under an in-flight upload
                    ring.stage((key,), [np.array(a) for a in rows])
                    budget -= 1
                    if len(ring) >= ring.depth:
                        (k2,), devs = ring.take()
                        rows_by_key[k2] = devs
                while True:
                    item = ring.take()
                    if item is None:
                        break
                    (k2,), devs = item
                    rows_by_key[k2] = devs
            except Exception:
                ring.clear()
                self.metrics.staging_errors += 1
                self.tier.record_failure()
            if rows_by_key:
                self._prefetched[req.uid] = (shard, rows_by_key)

    # -- admission -----------------------------------------------------------
    def _worst_case_blocks(self, req: Request) -> int:
        # every prompt+generation block a fresh allocation, window at W_max
        return -(-(len(req.prompt) + req.new_tokens + self.W_max)
                 // self.block_size)

    def _outstanding_reservations(self, shard: int) -> int:
        """Blocks already promised to the shard's in-flight slots but not
        yet allocated (their tables grow lazily as n advances)."""
        return int(sum(max(0, int(self.reserved[b]) - len(self.owned[b]))
                       for b in self.topo.slot_range(shard, self.B)
                       if self.slots[b] is not None))

    def _free_slot_in(self, shard: int) -> Optional[int]:
        for b in self.topo.slot_range(shard, self.B):
            if self.slots[b] is None:
                return b
        return None

    def _headroom(self, shard: int) -> int:
        return (self.pool.available(shard)
                - self._outstanding_reservations(shard))

    def _route(self, req: Request) -> Optional[int]:
        """Pool-pressure admission routing: the free slot on the shard with
        the most block headroom that still covers the request's worst case
        (single shard: the lowest free slot, iff the pool fits it)."""
        headroom = {}
        for s in range(self.topo.data_size):
            if self._free_slot_in(s) is not None:
                headroom[s] = self._headroom(s)
        shard = self.pool.route(self._worst_case_blocks(req), headroom)
        return None if shard is None else self._free_slot_in(shard)

    def _try_rebalance(self, req: Request) -> Optional[int]:
        """Shard rebalancing: when no single shard has a free slot AND
        enough headroom for ``req``, look for a resident whose migration to
        another shard both fits there (its full remaining reservation) and
        frees enough capacity — slot and blocks — on its home shard to
        admit ``req``. Cheapest sufficient move (fewest copied blocks)
        wins. Returns the admission slot, or None."""
        if not self.rebalance or self.topo.data_size == 1:
            return None
        need = self._worst_case_blocks(req)
        best = None
        for v in range(self.B):
            if self.slots[v] is None:
                continue
            s_v = self.topo.shard_of_slot(v, self.B)
            # once v leaves, its slot frees and its blocks + outstanding
            # reservation return to s_v's headroom
            if self._headroom(s_v) + int(self.reserved[v]) < need:
                continue
            for t in range(self.topo.data_size):
                if t == s_v:
                    continue
                b_dst = self._free_slot_in(t)
                if b_dst is None or self._headroom(t) < int(self.reserved[v]):
                    continue
                cand = (len(self.owned[v]), v, b_dst)
                if best is None or cand < best:
                    best = cand
        if best is None:
            return None
        _, v, b_dst = best
        try:
            self.migrate_slot(v, b_dst)
        except MemoryError:
            # injected landing-block allocation failure (§14): nothing was
            # mutated before begin_migration's alloc, so just don't move
            return None
        return self._route(req)

    def _evictable(self, head: Request) -> list[int]:
        """Running slots the queue head may preempt: strictly lower
        priority AND below the progress floor (slots past
        ``preempt_floor`` of their generation target are protected — they
        free their slot soon anyway). Lowest priority first, then cheapest
        park."""
        out = []
        for b in range(self.B):
            r = self.slots[b]
            if r is None or r.priority <= head.priority:
                continue
            prog = (int(self.n_host[b]) - len(r.prompt)) / max(
                1, r.new_tokens)
            if prog >= self.preempt_floor:
                continue
            out.append(b)
        out.sort(key=lambda b: (-self.slots[b].priority,
                                self._live_blocks(b)))
        return out

    def _try_preempt(self, head: Request) -> Optional[int]:
        """Priority preemption: evict, on a single shard, the smallest
        prefix of evictable (lowest-priority, below-floor) slots whose
        freed reservations plus current headroom cover the head's worst
        case; park each victim for exact resume; route the head."""
        if not self.preempt:
            return None
        need = self._worst_case_blocks(head)
        by_shard: dict[int, list[int]] = {}
        for b in self._evictable(head):
            by_shard.setdefault(
                self.topo.shard_of_slot(b, self.B), []).append(b)
        best = None
        for s, vs in by_shard.items():
            gain = self._headroom(s)
            took = []
            for b in vs:
                gain += int(self.reserved[b])
                took.append(b)
                if gain >= need:
                    break
            if gain >= need and (best is None or len(took) < len(best)):
                best = took
        if best is None:
            return None
        for b in best:
            self.preempt_slot(b)
        return self._route(head)

    def _poll_queue_deadlines(self):
        """Count SLO expiries of requests still queued or parked — without
        this, a request that blows its deadline before ever running (or
        while parked by preemption) is invisible until it happens to
        finish (the ``deadline_miss_count`` undercount bug)."""
        now = time.monotonic()
        for req in self.queue.requests():
            if (req.deadline is not None and not req.queue_deadline_missed
                    and now > req.deadline_time):
                req.queue_deadline_missed = True
                self.metrics.deadline_missed_in_queue += 1

    def _admit_pending(self):
        """Lookahead admission (DESIGN.md §12): scan up to ``lookahead``
        queued requests in queue order and admit the first routable one —
        a small fitting request behind an oversized head no longer
        head-of-line blocks. The head may additionally claim capacity by
        shard rebalancing (any candidate may) or priority preemption (head
        only — preempting for a lower-ranked request would invert the
        queue order). Every admission that jumps the head ages it
        (``Request.bypassed``); at ``max_head_bypass`` the scan narrows to
        the head alone, so the head admits next and cannot starve."""
        while self.queue:
            cands = self.queue.lookahead(self.lookahead)
            head = cands[0]
            if head.bypassed >= self.max_head_bypass:
                cands = [head]            # aging bound reached: head-only
            admitted = None
            faulted = False
            for req in cands:
                b = self._route(req)
                if b is None:
                    b = self._try_rebalance(req)
                if b is None and req is head:
                    b = self._try_preempt(head)
                if b is not None:
                    self.queue.remove(req)
                    try:
                        self._admit(req, b)
                    except Exception as e:
                        # quarantine the failure to THIS request (§14):
                        # unwind the half-built slot (releasing whatever
                        # blocks it had claimed), then retry or fail it —
                        # the other slots and the queue are untouched, so
                        # rescan the lookahead and keep admitting (a fault
                        # here must not head-of-line block the pass; the
                        # retry budget bounds re-admission attempts)
                        self.slots[b] = None
                        self._clear_row(b)
                        self._fail_request(
                            req, "admission", f"{type(e).__name__}: {e}",
                            retryable=True)
                        faulted = True
                    admitted = req
                    break
            if admitted is None:
                break
            if faulted:
                continue
            if admitted is not head:
                head.bypassed += 1
                self.metrics.head_bypass_admissions += 1

    def _admit(self, req: Request, b: int):
        parked = self.parked.pop(req.uid, None)
        if parked is not None:            # preempted: exact resume path
            try:
                self._resume(req, b, parked)
            except Exception:
                # the park is consumed/unreliable after a failed resume:
                # release its tier resources; a retry re-admits from the
                # prompt (a full restart on the same stream is bit-exact)
                self._discard_park(req.uid, parked)
                raise
            self._journal("admit", uid=int(req.uid))
            kill_point("post_admit")
            return
        req.admit_time = time.monotonic()
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        mgr = self._mgr(b)
        shard = self.topo.shard_of_slot(b, self.B)

        # prefix-cache: reuse full blocks strictly below position L_p - 1
        # (the verify window rewrites position n-1 = L_p-1 onward, so those
        # blocks stay read-only and shareable). Per-shard cache: hits can
        # only come from the sub-pool this slot decodes through; device
        # misses fall through to the host tier (DESIGN.md §13).
        hits, keys, host_keys = [], [], []
        nb_full = (L_p - 1) // self.block_size
        if self._kv_share and nb_full:
            hits, keys, host_keys = mgr.lookup_prefix_tiered(
                prompt, nb_full, tier=self.tier, shard=shard)
        elif self.rec_prefix and nb_full:
            keys = chain_hashes(prompt, self.block_size, nb_full)

        rec_rows, rec_bound = None, 0
        if self.rec_prefix and nb_full:
            # a prefix hit for a recurrent stack needs BOTH halves at one
            # block boundary j: KV blocks [0, j) coverable (device hits +
            # the contiguous host run; trivially all of them when the arch
            # has no attention layers) AND the recurrent-state snapshot at
            # keys[j-1] host-resident. Pick the largest such j.
            cover = (len(hits) + len(host_keys)) if self.has_attn else nb_full
            for jj in range(cover, 0, -1):
                rows = self.tier.get_rec(shard, keys[jj - 1])
                if rows is not None:
                    # copied out now: block allocs below spill into the
                    # same arena and could recycle these buffers
                    rec_rows, rec_bound = [np.array(a) for a in rows], jj
                    break
            # prefill rewrites blocks >= j through the table, so device
            # hits past the snapshot boundary are unusable SHARED blocks —
            # release them and let prefill write fresh private ones
            if len(hits) > rec_bound:
                mgr.release_all(hits[rec_bound:])
                hits = hits[:rec_bound]
            host_keys = (keys[len(hits):rec_bound] if self.has_attn else [])

        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._tables_dev = None
        pre = self._take_prefetched(req.uid, shard)
        staged = self._stage_host_blocks(b, mgr, host_keys, len(hits),
                                         prefetched=pre) \
            if host_keys else 0
        self._ensure_capacity(b, L_p)

        if self.rec_prefix and rec_bound > (len(hits) + staged
                                            if self.has_attn else nb_full):
            # staging truncated under arena pressure: fall back to the
            # best boundary the staged KV coverage still supports
            rec_rows, rec_bound = None, 0
            for jj in range(len(hits) + staged, 0, -1):
                rows = self.tier.get_rec(shard, keys[jj - 1])
                if rows is not None:
                    rec_rows, rec_bound = [np.array(a) for a in rows], jj
                    break

        start_blocks = rec_bound if self.rec_prefix else len(hits) + staged
        req.prefix_hit_blocks = start_blocks

        # per-slot state
        self.tokens = self.tokens.at[b].set(0).at[b, :L_p].set(
            jnp.asarray(prompt, jnp.int32))
        self.n = self.n.at[b].set(L_p)
        self.cand = self.cand.at[b].set(0).at[b, 0].set(int(prompt[-1]))
        self.seq_ids[b] = req.seq_id
        self._seq_dev = None
        if _has_recurrent(self.cfg):
            self._reset_recurrent_row(b)
            if rec_rows is not None and start_blocks > 0:
                # state after positions [0, start_blocks * bs): the
                # snapshot captured at this boundary by an earlier
                # admission — a recurrent prefix hit
                self._restore_rec_row(b, rec_rows)
                self.metrics.rec_snapshot_restores += 1

        # chunked row-local prefill of the un-cached prompt tail (global
        # pool ids: local table + the slot's shard offset). Recurrent
        # archs segment the tail at registerable block boundaries so the
        # state row can be checkpointed into the tier at each one —
        # chunk decomposition is bitwise-invariant (sequential scans), so
        # tokens are unchanged; attention-only archs keep the single
        # greedy pow2 cover.
        start = start_blocks * self.block_size
        table_row = jnp.asarray(self.tables[b:b + 1] + self._table_offset(b))
        row = jnp.asarray([b], jnp.int32)
        seg_ends = ([jb * self.block_size
                     for jb in range(start_blocks + 1, nb_full + 1)]
                    if self.rec_prefix else [])
        if not seg_ends or seg_ends[-1] != L_p - 1:
            seg_ends.append(L_p - 1)
        for end in seg_ends:
            for C in prefill_chunks(end - start, self.prefill_chunk):
                chunk = jnp.asarray(prompt[None, start:start + C], jnp.int32)
                pf = self._prefill_fn(C)
                pf_args = (self.params, self.paged, table_row, row, chunk,
                           jnp.asarray([start], jnp.int32))
                self._contract_check("prefill", pf, pf_args)
                self.paged = pf(*pf_args)
                start += C
                req.prefill_calls += 1
                self.metrics.prefill_calls += 1
            if (self.rec_prefix and end > 0 and end == start
                    and end % self.block_size == 0
                    and end <= nb_full * self.block_size):
                kb = end // self.block_size - 1
                if not self.tier.has_rec(shard, keys[kb]):
                    if self.tier.put_rec(shard, keys[kb],
                                         self._collect_rec_row(b)):
                        self.metrics.rec_snapshot_captures += 1

        # publish this prompt's freshly computed full blocks (host-staged
        # ones were registered as they merged)
        if self._kv_share:
            for j in range(len(hits) + staged, nb_full):
                mgr.register(self.owned[b][j], keys[j])

        self.slots[b] = req
        self._set_poison(b, req)
        self.target[b] = L_p + req.new_tokens
        self._target_dev = None
        if self.plen[b] != L_p:
            self.plen[b] = L_p
            self._plen_dev = None
        self.reserved[b] = self._worst_case_blocks(req)
        self.n_host[b] = L_p
        self._journal("admit", uid=int(req.uid))
        kill_point("post_admit")

    # -- failure / cancellation (DESIGN.md §14) ------------------------------
    def _fail_request(self, req: Request, code: str, detail: str = "", *,
                      retryable: bool = False, fresh_stream: bool = False):
        """Retire or retry a request that hit a fault. Retryable failures
        under the retry budget requeue (original arrival order — the
        request does not lose its place); ``fresh_stream`` additionally
        derives a new noise-stream id (skipping scripted poison streams) so
        a quarantined row does not replay the same poisoned stream.
        Otherwise the request finishes with a structured ``RequestError``
        and ``result=None``."""
        if retryable and req.retries < self.request_retries:
            req.retries += 1
            self.metrics.retries += 1
            if fresh_stream:
                seed = int(req.seq_id)
                poisoned = (self.faults.poison_streams
                            if self.faults is not None else frozenset())
                while True:     # splitmix-style LCG walk over 31-bit seeds
                    seed = (seed * 6364136223846793005
                            + 1442695040888963407) % (2 ** 31)
                    if seed not in poisoned and seed != 0:
                        break
                req.noise_seed = seed
            self.queue.requeue(req)
            # the new stream id must survive a crash: replaying the retry
            # record restores determinism (seq_id keys the eps stream)
            self._journal("retry", uid=int(req.uid),
                          noise_seed=req.noise_seed, retries=req.retries)
            return
        req.error = RequestError(code, detail, retryable=retryable,
                                 attempts=req.retries + 1)
        req.result = None
        req.finish_time = time.monotonic()
        self.metrics.requests_failed += 1
        self.done.append(req)
        self._journal("fail", uid=int(req.uid), code=code)

    def _fail_slot(self, b: int, code: str, detail: str = "", *,
                   retryable: bool = False, fresh_stream: bool = False):
        """Quarantine one running slot: free it (blocks released, row
        device state cleared to the inactive no-op lane) and route its
        request through :meth:`_fail_request`. The other rows never see a
        discontinuity — slot release is exactly the path a finished
        request takes."""
        req = self.slots[b]
        assert req is not None, f"slot {b} is not occupied"
        self.slots[b] = None
        self._clear_row(b)
        self._fail_request(req, code, detail, retryable=retryable,
                           fresh_stream=fresh_stream)

    def cancel(self, uid: int) -> bool:
        """Cancel a request wherever it currently lives — queued, parked
        (parked requests sit in the queue awaiting resume), or running in a
        slot. Returns False when ``uid`` is unknown (already finished or
        never submitted). The cancelled request finishes through ``done``
        with ``error.code == "cancelled"``."""
        for req in self.queue.requests():
            if req.uid == uid:
                self.queue.remove(req)
                self._drop_prefetched(uid)
                parked = self.parked.pop(uid, None)
                if parked is not None:
                    self._discard_park(uid, parked)
                self._finalize_cancel(req)
                return True
        for s in range(self.topo.data_size):
            for i, e in enumerate(self.staged[s]):
                if e.req.uid == uid:
                    # staged but not yet adopted: the device has only a
                    # descriptor copy, and the next dispatch re-packs from
                    # these lists — dropping the entry here is exact
                    self.pool.manager(s).release_all(e.blocks)
                    self.ledger.release(s, uid)
                    del self.staged[s][i]
                    self._drop_prefetched(uid)
                    self._finalize_cancel(e.req)
                    return True
        for b in range(self.B):
            req = self.slots[b]
            if req is not None and req.uid == uid:
                self.slots[b] = None
                self._clear_row(b)
                self._finalize_cancel(req)
                return True
        return False

    def _finalize_cancel(self, req: Request):
        req.error = RequestError("cancelled", retryable=False,
                                 attempts=req.retries + 1)
        req.result = None
        req.finish_time = time.monotonic()
        self.metrics.requests_cancelled += 1
        self.done.append(req)
        self._journal("cancel", uid=int(req.uid), code="cancelled")

    # -- main loop -----------------------------------------------------------
    def _harvest_adoptions(self, adopt: np.ndarray, out_tok: np.ndarray,
                           now: float) -> tuple[int, int, int]:
        """Reconstruct the in-loop adoption chain from the packed
        ``adopt_stats`` array and replay it on the host mirrors.

        The device adopts staged descriptors in shard-major FIFO order, so
        walking descriptors ascending per shard replays adoptions in
        chronological order: at descriptor ``i`` the slot's host-side
        occupant is exactly the request the device displaced (the original
        occupant for the first adoption into a row, the previously adopted
        entry for a chain). Each displaced episode carries its terminal
        ``(n, acc, act, bad, gen)`` snapshot — finished episodes deliver
        their tokens from ``out_tokens`` (captured at displacement, before
        the buffer was overwritten), quarantined ones route through
        :meth:`_fail_request`. Mirrors for the adopted entry are installed
        WITHOUT invalidating the device caches: the device row already
        switched inside the loop, and the returned arrays are authoritative.
        Returns ``(accepted, active_row_rounds, generating_row_rounds)``
        credited to displaced episodes (the final stats array only covers
        each row's current occupant)."""
        acc_extra = act_extra = gen_extra = 0
        S = self.staging_slots
        for s in range(self.topo.data_size):
            mgr = self.pool.manager(s)
            n_adopted = 0
            for i in range(len(self.staged[s])):
                row = adopt[s * S + i]
                if row[0] < 0:
                    break               # FIFO: adopted descriptors are a prefix
                n_adopted += 1
                entry = self.staged[s][i]
                g = self.topo.global_slot(s, int(row[0]), self.B)
                ep_n, ep_acc, ep_act = int(row[1]), int(row[2]), int(row[3])
                ep_bad, ep_gen = int(row[4]), int(row[5])
                prev = self.slots[g]
                if prev is not None:
                    prev.calls_used += ep_act
                    acc_extra += ep_acc
                    act_extra += ep_act
                    gen_extra += ep_gen
                    mgr.release_all(self.owned[g])
                    self.owned[g] = []
                    self.slots[g] = None
                    if ep_bad:
                        self._fail_request(
                            prev, "nonfinite" if ep_bad & 1 else "stuck",
                            f"health bits 0b{ep_bad:02b} at n={ep_n} "
                            "(displaced in-loop)", retryable=True,
                            fresh_stream=True)
                    else:
                        prev.result = out_tok[s * S + i, :ep_n].copy()
                        prev.finish_time = now
                        self.metrics.observe_finish(prev)
                        self.done.append(prev)
                        self._journal("finish", uid=int(prev.uid),
                                      tokens=[int(t) for t in prev.result])
                req = entry.req
                req.admit_time = now
                self.ledger.release(s, req.uid)
                self.slots[g] = req
                self.owned[g] = list(entry.blocks)
                self.tables[g] = entry.table_row
                self.target[g] = entry.target
                self.plen[g] = entry.plen
                self.seq_ids[g] = req.seq_id
                self.poison[g] = entry.poison
                self.reserved[g] = len(entry.blocks)
                self.metrics.in_loop_adoptions += 1
            self.staged[s] = self.staged[s][n_adopted:]
        return acc_extra, act_extra, gen_extra

    def step(self) -> bool:
        """Admit what fits (lookahead scan, pool-pressure routing, shard
        rebalancing, priority preemption), run one device dispatch of up to
        ``rounds_per_sync`` verify rounds, harvest finished requests. The
        host touches exactly ONE small packed stats array per step — no
        ``n``/``cand`` pulls per round.

        Without staging (``staging_slots == 0``) the loop yields every
        round (``k = 1``) while admission backlog is queued, so freed
        slots refill promptly. With staging the inversion of §15 applies:
        backlog is exactly when long loops pay off (freed rows adopt
        staged descriptors WITHOUT a sync), so ``k`` comes from the
        adaptive :class:`RoundsPerSyncController` (or stays at
        ``rounds_per_sync`` when adaptivity is off and the backlog is
        staged). Returns True while there is (or may be) work left."""
        self._poll_queue_deadlines()
        self._reconcile_staging()
        self._admit_pending()
        self._stage_pending()
        self._prefetch_queued()

        if not any(s is not None for s in self.slots):
            # _reconcile_staging unstages whenever a slot is free, so an
            # empty engine implies an empty staging area
            if self.queue:
                raise MemoryError(
                    "admission deadlock: queued request cannot fit an empty "
                    "engine (prompt+target exceeds the block pool)")
            return False

        W = self.controller.window
        staged_now = self._staged_total()
        backlog_now = len(self.queue) + staged_now
        if self.staging_slots:
            if self.adaptive_rounds:
                k = min(self.rounds_ctrl.k, self.rounds_per_sync)
            else:
                # static staging policy: stay resident while the backlog is
                # fully staged (adoption refills in-loop); an UNstaged
                # backlog still needs the host every round
                k = self.rounds_per_sync if (staged_now or not self.queue) \
                    else 1
        else:
            k = 1 if self.queue else self.rounds_per_sync
        for b in range(self.B):
            if self.slots[b] is not None:
                try:
                    self._ensure_capacity(b, int(self.target[b]) + W)
                except MemoryError as e:
                    # reservation guarantees this never fires organically;
                    # an injected alloc fault fails ONLY this slot (§14)
                    self._fail_slot(b, "capacity", str(e), retryable=True)
        if not any(s is not None for s in self.slots):
            return bool(self.queue) or self._staged_total() > 0
        adopt = otok_dev = None
        round_fn = self._round_loop_fn(W, k)
        round_args = self._round_args()
        self._contract_check(
            "round" if self.staging_slots == 0 else "staged_round",
            round_fn, round_args)
        if self.staging_slots == 0:
            (self.paged, self.tokens, self.n, self.cand, stats_dev) = \
                round_fn(*round_args)
        else:
            # staged ABI: row state comes BACK as outputs (adoption mutates
            # tables/seq/target/poison/plen in-loop) and becomes the new
            # device cache; host mirrors for adopted rows are updated in
            # the harvest walk below WITHOUT invalidating these caches
            (self.paged, self._tables_dev, self.tokens, self.n, self.cand,
             self._seq_dev, self._target_dev, self._poison_dev,
             self._plen_dev, stats_dev, adopt_dev, otok_dev) = \
                round_fn(*round_args)
            adopt = np.asarray(adopt_dev)
            self.metrics.staging_occupancy_hist.append(
                staged_now / (self.topo.data_size * self.staging_slots))
        # THE host sync: one small packed int32 pull per loop
        stats = np.asarray(stats_dev)
        accepted, rounds_active, n_host = stats[:, 0], stats[:, 1], stats[:, 2]
        bad = stats[:, 4]                      # §14 quarantine health bits
        rounds_exec = int(stats[:, 3].max())   # critical path across shards
        self.n_host[:] = n_host                # preemption progress mirror
        self._last_rounds_exec = rounds_exec   # run()'s convergence budget

        now = time.monotonic()
        acc_extra = act_extra = gen_extra = 0
        if adopt is not None and bool((adopt[:, 0] >= 0).any()):
            acc_extra, act_extra, gen_extra = self._harvest_adoptions(
                adopt, np.asarray(otok_dev), now)

        slot_rows = [b for b in range(self.B) if self.slots[b] is not None]
        # accumulators reset at adoption, so a row's final stats belong to
        # its CURRENT occupant; displaced episodes were credited above
        for b in slot_rows:
            self.slots[b].calls_used += int(rounds_active[b])
        act_row_rounds = act_extra + (int(rounds_active[slot_rows].sum())
                                      if slot_rows else 0)
        acc_total = acc_extra + (int(accepted[slot_rows].sum())
                                 if slot_rows else 0)
        self.metrics.observe_loop(W, rounds_exec, act_row_rounds, self.B,
                                  acc_total, backlog=backlog_now)
        if self.staging_slots:
            # W retunes from GENERATING row-rounds: forced-prefill rounds
            # accept at the prompt rate, not the stream's accept rate, and
            # would bias the window signal
            gen_total = gen_extra + (int(stats[slot_rows, 5].sum())
                                     if slot_rows else 0)
            idle_total = int(stats[:, 6].sum())
            self.metrics.idle_row_rounds += idle_total
            self.controller.observe_aggregate(acc_total, gen_total)
            self.rounds_ctrl.observe(
                rounds_exec, idle_total, self.B,
                len(self.queue) + self._staged_total())
        else:
            self.controller.observe_aggregate(acc_total, act_row_rounds)

        for b in slot_rows:
            req = self.slots[b]
            if bad[b]:
                # quarantine verdict from the packed stats: fail only this
                # slot; a retry gets a FRESH noise stream (replaying a
                # poisoned stream would just fail again)
                code = "nonfinite" if bad[b] & 1 else "stuck"
                self._fail_slot(
                    b, code, f"health bits 0b{int(bad[b]):02b} at "
                    f"n={int(n_host[b])}", retryable=True, fresh_stream=True)
                continue
            if n_host[b] >= self.target[b]:
                req.result = np.asarray(self.tokens[b, :n_host[b]])
                req.finish_time = now
                self.metrics.observe_finish(req)
                self.done.append(req)
                self._journal("finish", uid=int(req.uid),
                              tokens=[int(t) for t in req.result])
                self.slots[b] = None
                self._clear_row(b)
                continue
            if (self.max_request_rounds is not None
                    and req.calls_used >= self.max_request_rounds):
                self._fail_slot(
                    b, "round_budget", f"{req.calls_used} verify rounds "
                    f">= {self.max_request_rounds}")
                continue
            if (self.max_request_seconds is not None
                    and now - req.submit_time > self.max_request_seconds):
                self._fail_slot(
                    b, "timeout", f"{now - req.submit_time:.3f}s "
                    f"> {self.max_request_seconds}s wall time")
        # sync boundary (DESIGN.md §16): force the journal to media and
        # snapshot the scheduler, so a crash from here on recovers to
        # exactly this round's committed state
        if self.journal is not None:
            self.journal.sync()
            self._checkpoint(now)
            kill_point("post_sync")
        return True

    def run(self, max_rounds: int = 10_000) -> list[Request]:
        """Drain the queue; returns completed Requests with stats.

        ``max_rounds`` bounds *executed verify rounds* (the packed stats'
        per-sync ``loop_rounds``), not host steps — with ``rounds_per_sync
        = 4`` a per-step count would silently allow 4x the documented
        convergence budget."""
        budget = int(max_rounds)
        while (self.queue or self._staged_total()
               or any(s is not None for s in self.slots)):
            if not self.step():
                break
            budget -= self._last_rounds_exec
            if budget <= 0 and (self.queue or self._staged_total()
                                or any(s is not None for s in self.slots)):
                raise RuntimeError(
                    f"serving engine did not converge within {max_rounds} "
                    "verify rounds")
        return self.done

    def close(self) -> None:
        """Orderly shutdown of the durability layer: final checkpoint,
        journal fsync, file handles closed. A no-op for volatile engines —
        and never *required*: crash-safety is the whole point, so an
        engine that simply dies recovers identically."""
        if self.journal is not None:
            self._checkpoint()
            self.journal.close()

    # -- checkpoint / restore (DESIGN.md §16) --------------------------------
    def _checkpoint(self, now: Optional[float] = None) -> None:
        """Snapshot the scheduler at a sync boundary, atomically (temp +
        fsync + rename — a reader sees the whole snapshot or the previous
        one, never a torn JSON). What goes in: every live request's clocks
        as *elapsed durations* (``clock_export`` — monotonic stamps die
        with the process), arrival rank, retry/stream counters; for each
        parked sequence the resume snapshot (n, token row, cand row) and
        its kv chain keys — which are first force-flushed to the disk tier
        so the references are durable, not merely cached. Parked *private*
        payloads and running rows are deliberately NOT here: they are
        recomputed on restore (journaled identity + determinism makes that
        bitwise-exact), which keeps the checkpoint small and the fsync
        cheap."""
        if self._ckpt_path is None:
            return
        if now is None:
            now = time.monotonic()
        live = list(self.queue.requests())
        for s in range(self.topo.data_size):
            live += [e.req for e in self.staged[s]]
        live += [r for r in self.slots if r is not None]
        reqs = [{"uid": int(r.uid),
                 "rank": None if r._seq is None else int(r._seq),
                 "retries": int(r.retries), "noise_seed": r.noise_seed,
                 "bypassed": int(r.bypassed),
                 "queue_deadline_missed": bool(r.queue_deadline_missed),
                 "clocks": r.clock_export(now)} for r in live]
        parked = {}
        for uid, p in self.parked.items():
            if self.tier is not None and p.kv_keys:
                self.tier.flush_to_disk(p.shard, p.kv_keys)
            parked[str(int(uid))] = {
                "n": int(p.n),
                "tokens": [int(t) for t in np.asarray(p.tokens).ravel()],
                "cand": [int(t) for t in np.asarray(p.cand).ravel()],
                "nb_live": int(p.nb_live),
                "kv_keys": [int(k) for k in p.kv_keys],
                "shard": int(p.shard)}
        snap = {"version": 1, "requests": reqs, "parked": parked}
        tmp = self._ckpt_path + ".tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(snap, f)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, self._ckpt_path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return       # degraded to journal-only recovery, never an error
        self.metrics.checkpoints_written += 1

    def _load_checkpoint(self) -> dict:
        """The latest snapshot, or {} when missing/corrupt — recovery then
        runs journal-only (full re-prefill, clocks restart at zero
        elapsed); it never errors."""
        if self._ckpt_path is None:
            return {}
        try:
            with open(self._ckpt_path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    def restore(self) -> int:
        """Recover accepted-but-unfinished requests after a crash (§16).

        Replays the journal (repairing any torn tail), folds in the latest
        checkpoint, and re-enqueues every pending request with its original
        arrival rank and rebased clocks. Requests the checkpoint holds a
        parked snapshot for get a *cold* :class:`ParkedSequence` — resume
        pulls their prompt blocks back through the arena/disk fall-through
        and re-prefills only ``[covered, n-1)``; everything else re-admits
        from its journaled prompt. Either way tokens are bitwise those of
        an uninterrupted run: out = f(context, eps), and both context
        (prompt + accepted row) and eps identity (seq_id) were durable.

        Journaled *terminal* outcomes are re-delivered through ``done``:
        a crash can land between the finish record hitting the journal and
        the client draining the result, so every journaled finish (tokens
        travel in the record) and fail/cancel (error code) is surfaced
        again — at-least-once delivery, deduped by uid on the client side,
        and bitwise-identical on re-delivery by the determinism invariant.
        Returns the number of requests re-enqueued (re-deliveries not
        counted)."""
        assert self.journal is not None, "restore() requires durable_dir"
        records = RequestJournal.replay(self.journal.path,
                                        faults=self.faults)
        pending, _, delivered = RequestJournal.pending(records)
        ckpt = self._load_checkpoint()
        by_uid = {int(r["uid"]): r for r in ckpt.get("requests", [])}
        snaps = {int(u): p for u, p in ckpt.get("parked", {}).items()}
        now = time.monotonic()
        max_rank = -1
        recovered = 0
        # original queue order: ranked submits first, by rank
        for uid, rec in sorted(
                pending.items(),
                key=lambda kv: (kv[1].get("rank") is None,
                                kv[1].get("rank") or 0)):
            req = Request(uid=int(uid),
                          prompt=np.asarray(rec["prompt"], np.int64),
                          new_tokens=int(rec["new_tokens"]),
                          priority=int(rec.get("priority", 0)),
                          deadline=rec.get("deadline"),
                          noise_seed=rec.get("noise_seed"))
            req.retries = int(rec.get("retries", 0))
            req._seq = None if rec.get("rank") is None else int(rec["rank"])
            c = by_uid.get(req.uid)
            if c is not None:
                req.bypassed = int(c.get("bypassed", 0))
                req.queue_deadline_missed = bool(
                    c.get("queue_deadline_missed", False))
                req.clock_rebase(c.get("clocks", {}), now)
            else:
                req.submit_time = now     # journal-only: clock restarts
            if req._seq is None:
                req._seq = max_rank + 1
            max_rank = max(max_rank, req._seq)
            snap = snaps.get(req.uid)
            if snap is not None and rec.get("parked"):
                self.parked[req.uid] = ParkedSequence(
                    n=int(snap["n"]),
                    tokens=np.asarray(snap["tokens"], np.int32),
                    cand=np.asarray(snap["cand"], np.int32),
                    nb_live=int(snap["nb_live"]),
                    kv_keys=tuple(int(k) for k in snap["kv_keys"]),
                    shard=int(snap["shard"]), cold=True)
                self.metrics.recovered_parked += 1
            self.queue.requeue(req)       # rank pinned: original order
            recovered += 1
        self.queue.advance_seq(max_rank)
        self.metrics.recovered_requests += recovered
        # re-deliver journaled outcomes whose pickup the crash may have
        # swallowed (see docstring); no journal write — these records are
        # already terminal, replaying them again is idempotent
        for uid, rec in delivered.items():
            req = Request(uid=int(uid),
                          prompt=np.asarray(rec["prompt"], np.int64),
                          new_tokens=int(rec["new_tokens"]),
                          priority=int(rec.get("priority", 0)),
                          deadline=rec.get("deadline"),
                          noise_seed=rec.get("noise_seed"))
            if rec["terminal"] == "finish" and "tokens" in rec:
                req.result = np.asarray(rec["tokens"], np.int32)
            else:
                req.error = RequestError(
                    rec.get("code", rec["terminal"]), "re-delivered (§16)")
            self.done.append(req)
        return recovered

    # -- telemetry -----------------------------------------------------------
    def export_metrics(self) -> dict:
        out = self.metrics.export(
            self.pool.stats_export(),
            self.tier.stats_export() if self.tier is not None else None)
        out["blocks_in_use"] = self.pool.blocks_in_use()
        out["blocks_available"] = self.pool.available()
        out["parked_requests"] = len(self.parked)
        out["queue_depth"] = len(self.queue)
        out["staged_requests"] = self._staged_total()
        out["prefetched_requests"] = len(self._prefetched)
        out["rounds_per_sync_final"] = (self.rounds_ctrl.k
                                        if self.staging_slots
                                        else self.rounds_per_sync)
        # §14 failure counters are always present (chaos-job assertions):
        # tier-backed ones default to 0 when no tier is configured
        out.setdefault("checksum_failures", 0)
        out.setdefault("tier_tripped", 0)
        out.setdefault("tier_state", "closed")
        out.setdefault("tier_denied_ops", 0)
        out["faults_injected"] = (self.faults.total_fired
                                  if self.faults is not None else 0)
        if self.faults is not None:
            # per-seam fired counts (zero-filled over every known seam) so
            # a chaos run shows WHICH seams actually exercised (§14/§16)
            out.update(self.faults.fired_export())
        # durability observability (§16): disk breaker + journal counters
        # present whenever configured; zero-filled defaults otherwise so
        # the recovery CI job can assert on them unconditionally
        if (self.disk is not None
                and (self.tier is None or self.tier.disk is None)):
            out.update(self.disk.stats_export())
        out.setdefault("disk_state", "closed")
        out.setdefault("disk_tripped", 0)
        out.setdefault("disk_hits", 0)
        out.setdefault("disk_promotes", 0)
        out.setdefault("disk_spills", 0)
        if self.journal is not None:
            out.update(self.journal.stats_export())
        if self.topo.data_size > 1:
            out["blocks_available_by_shard"] = [
                self.pool.available(s) for s in range(self.topo.data_size)]
        return out
