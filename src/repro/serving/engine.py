"""`ServingEngine`: paged predictive-sampling serving runtime (DESIGN.md §6-10).

Subsumes the seed ``ContinuousBatcher`` (kept as a thin alias in
``repro.engine.scheduler``): requests are admitted from a priority/deadline
queue into free slots of a fixed-width batch, every verify round advances
each sequence by its own accept length, and finished sequences free their
slot and blocks immediately. What's new over the dense batcher:

* **Paged KV cache** — attention K/V lives in fixed-size blocks of a shared
  physical pool (``TransformerLM.init_paged_cache``); verify rounds and
  prefill decode *through the block tables* (``decode_window_paged`` /
  DESIGN.md §9): each layer writes its window K/V into physical blocks and
  attends via the paged flash-decode Pallas kernel (TPU) or the gather-view
  exact fallback (CPU). No dense attention K/V view of the whole cache is
  built on the round hot path — ``paged_attention=False`` restores the
  legacy gather/scatter round-trip (kept as the benchmark baseline).
  Admission allocates blocks instead of zeroing a whole cache row.
* **Mesh sharding** — a ``ServingTopology`` splits the batch slots and the
  physical pool into per-data-shard halves; the verify round runs under
  shard_map manual over "data", so each shard decodes its rows against its
  own sub-pool through *shard-local* block tables (zero collectives on the
  round hot path; DESIGN.md §10). Admission routes requests to the shard
  with the most block headroom. Tokens are bit-identical to the
  single-device engine (placement-independent noise streams).
* **Prefix cache** — full prompt blocks are content-hashed (chained keys);
  admissions sharing a prompt prefix point their tables at the cached blocks
  and skip recomputing them (attention-only models; recurrent stacks carry
  un-paged per-slot state, so they always prefill — see ``_has_recurrent``).
  Under a mesh the cache is per-shard (blocks never cross shards).
* **Row-local chunked prefill** — an admitted row prefills through batch-1
  windows over its own blocks; nothing scales with the batch width.
* **Device-resident verify rounds** — a verify round is a SINGLE device
  dispatch (the fused paged kernel commits window K/V as an aliased
  epilogue — no standalone scatter before the pallas_call), and up to
  ``rounds_per_sync`` rounds run inside one ``lax.while_loop`` dispatch
  between host syncs: the host pulls one packed (B, 4) stats array per
  loop instead of ``n``/``cand`` every round (DESIGN.md §11). Under a mesh
  each shard's loop stops on its own rows — no cross-shard collective.
* **Adaptive speculation** — the verify window W is retuned per host sync
  from the observed accept-length EWMA (``AdaptiveWindowController``),
  bounded to powers of two in ``[1, w_max]`` so at most ``log2(w_max)+1``
  round shapes compile; the loop runs at fixed W, so the sync IS the
  retune boundary.
* **Donated round buffers** — the physical pool and per-slot device state
  are dead the moment a round returns their successors, so the jitted round
  and prefill steps donate them (``donate_argnums``): XLA updates the pool
  in place instead of holding two full copies live per round
  (``donate=False`` restores the copying behaviour for A/B measurement).
* **Telemetry** — per-request latency/accept/ARM-call counters, deadline
  (SLO) misses, and engine gauges exported as plain dicts (``EngineMetrics``).

Exactness: every path emits tokens bit-identical to a per-request
``PredictiveSampler.generate`` run with the same eps key and noise-stream id
(``Request.seq_id``) — asserted in tests/serving/test_engine.py and, for the
mesh paths, tests/serving/test_mesh_engine.py.
"""
from __future__ import annotations

import time
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.spec_decode import GenState, make_eps_fn, verify_round
from repro.kernels import resolve_interpret
from repro.models.transformer import PagedView, TransformerLM
from repro.serving.admission import AdmissionQueue, Request, prefill_chunks
from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.blocks import ShardedBlockPool
from repro.serving.metrics import EngineMetrics
from repro.serving.topology import ServingTopology


def _has_recurrent(cfg) -> bool:
    return any(m in ("mamba", "rwkv") or f == "rwkv_cmix"
               for m, f in cfg.layer_specs())


class ServingEngine:
    def __init__(self, cfg, params, *, batch: int, window_max: int = 8,
                 max_len: int = 256, eps_key=None, eps_fn=None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 adaptive: bool = True, window_init: int = 0,
                 prefix_cache: bool = True, prefill_chunk: int = 64,
                 use_forecast_heads: bool = False,
                 use_verify_kernel: bool = False,
                 paged_attention: bool = True,
                 use_attention_kernel: Optional[bool] = None,
                 topology: Optional[ServingTopology] = None,
                 donate: bool = True, rounds_per_sync: int = 4):
        assert block_size >= 1, f"block_size must be >= 1, got {block_size}"
        assert window_max >= 1, f"window_max must be >= 1, got {window_max}"
        assert rounds_per_sync >= 1, rounds_per_sync
        self.cfg = cfg
        self.params = params
        self.B = batch
        self.W_max = window_max
        self.max_len = max_len
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.use_forecast_heads = (use_forecast_heads
                                   and "forecast" in params
                                   and cfg.forecast_horizon > 0)
        self.use_verify_kernel = use_verify_kernel
        # paged_attention: decode through block tables (no dense K/V view on
        # the round hot path). The Pallas kernel is the compiled TPU fast
        # path; elsewhere the default is the gather-view fallback, which is
        # bit-exact vs the dense engine (resolve_interpret's dispatch).
        self.paged_attention = paged_attention
        if use_attention_kernel is None:
            use_attention_kernel = not resolve_interpret(None)
        self.use_attention_kernel = use_attention_kernel
        # donate the pool + per-slot state into the jitted round/prefill
        # steps (their previous values are dead once the step returns)
        self.donate = donate
        # device-resident rounds: up to this many verify rounds run inside
        # one dispatch (lax.while_loop) between host syncs; 1 = host-driven
        self.rounds_per_sync = rounds_per_sync
        self.eps_fn = eps_fn if eps_fn is not None else make_eps_fn(
            eps_key if eps_key is not None else jax.random.PRNGKey(0),
            cfg.vocab)

        # ---- topology (slot ranges + block sub-pools per data shard) -----
        self.topo = topology if topology is not None else ServingTopology()
        D = self.topo.data_size
        self.B_local = self.topo.slots_per_shard(batch)

        # ---- paged cache ------------------------------------------------
        self.nb = -(-(max_len + window_max) // block_size)  # table width
        if num_blocks is None:
            # per shard: full occupancy + slack so unreferenced prefix
            # blocks survive
            num_blocks = 1 + self.B_local * self.nb + 2 * self.nb
        # ``num_blocks`` is PER DATA SHARD; the device pool holds D of them
        self.pool = ShardedBlockPool(D, num_blocks, block_size)
        self.paged = self.topo.put_paged(cfg, TransformerLM.init_paged_cache(
            cfg, batch, D * num_blocks, block_size, dtype=cfg.param_dtype))
        self._paged_specs = TransformerLM.paged_partition_specs(
            cfg, self.paged, data_axis=self.topo.data_axis)
        # block tables hold SHARD-LOCAL ids (each shard's sink is local 0);
        # host-side code converts to global pool ids via the shard offset
        self.tables = np.zeros((batch, self.nb), np.int32)
        self.owned: list[list[int]] = [[] for _ in range(batch)]
        # prefix-cache hits need the post-prefix recurrent state too, which
        # is per-slot (not paged) — so recurrent stacks always prefill
        self.prefix_enabled = prefix_cache and not _has_recurrent(cfg)

        # ---- control / telemetry ---------------------------------------
        self.controller = AdaptiveWindowController(
            w_max=window_max, w_init=window_init, enabled=adaptive)
        self.metrics = EngineMetrics()
        self.queue = AdmissionQueue()
        self.slots: list[Optional[Request]] = [None] * batch
        self.done: list[Request] = []
        self.target = np.zeros(batch, np.int64)
        # worst-case block need reserved per slot at admission (run-to-
        # completion guarantee: lazy growth may never exhaust the pool)
        self.reserved = np.zeros(batch, np.int64)

        # ---- per-slot device state (slot dim sharded over "data") -------
        self.tokens = self.topo.put_batch(jnp.zeros((batch, max_len),
                                                    jnp.int32))
        self.n = self.topo.put_batch(jnp.ones((batch,), jnp.int32))
        # ^ cleared-row sentinel n=1
        self.cand = self.topo.put_batch(jnp.zeros((batch, window_max),
                                                  jnp.int32))
        self.seq_ids = self.topo.put_batch(jnp.zeros((batch,), jnp.int32))
        # cached device copies of host-owned admission state; invalidated
        # only when the host actually mutates them (admission, slot clear,
        # table growth) instead of re-uploading every round
        self._tables_dev = None
        self._target_dev = None

        self._round_fns: dict[tuple[int, int], callable] = {}
        self._prefill_fns: dict[int, callable] = {}

    # -- seed-API compatibility -------------------------------------------
    @property
    def state(self):
        """Seed ``ContinuousBatcher`` exposed ``state.rounds``; preserved."""
        return SimpleNamespace(rounds=self.metrics.rounds, n=self.n,
                               tokens=self.tokens)

    def submit(self, req: Request):
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.new_tokens <= self.max_len, \
            (len(req.prompt), req.new_tokens, self.max_len)
        self.queue.push(req)

    # -- jitted steps -------------------------------------------------------
    def _round_loop_fn(self, W: int, k: int):
        """Up to ``k`` verify rounds in ONE device dispatch. The round body
        decodes through the block tables — the fused paged kernel commits
        the window K/V into its physical blocks as an aliased epilogue while
        attention streams the pool (one pallas_call per layer, no standalone
        window scatter; per-round HBM traffic independent of pool size).
        Legacy mode is the dense round-trip: gather the whole view, decode,
        write the window span back through the same aliased writeback.

        A ``lax.while_loop`` re-runs the body until every local row is done
        or ``k`` rounds have run (the window-retune boundary): the host
        syncs one small packed stats array per *loop*, not per round —
        (R, 4) int32 ``[accepted, rounds_active, new_length, loop_rounds]``
        (DESIGN.md §11). Inactive rows are no-ops inside the loop, so extra
        rounds never change tokens.

        Under a mesh topology the whole loop runs shard_map-manual over
        "data": each shard sees its local rows, its local tables, and its
        local block sub-pool, and — crucially — its while_loop stops on its
        OWN rows, so the stop condition needs no cross-shard collective
        (shards may run different trip counts; the compiled HLO stays
        collective-free). The old pool and per-slot state are donated (dead
        after the loop), so XLA updates the pool in place round over round
        instead of copying it."""
        if (W, k) not in self._round_fns:
            cfg = self.cfg

            def fn(params, paged, tables, tokens, n, cand, seq_ids, target):
                R = tokens.shape[0]          # rows on this shard (B/D)
                rows = jnp.arange(R)

                def one_round(paged, tokens, n, cand):
                    if self.paged_attention:
                        cache = paged
                        pv = PagedView(tables, rows,
                                       self.use_attention_kernel)
                    else:
                        cache = TransformerLM.gather_paged(cfg, paged,
                                                           tables, rows)
                        pv = None
                    st = GenState(tokens, n, cand[:, :W], cache,
                                  jnp.zeros((), jnp.int32),
                                  jnp.zeros((R,), jnp.int32),
                                  jnp.zeros((R,), jnp.int32), seq_ids)
                    st2, rstats = verify_round(
                        params, cfg, self.eps_fn, st, target,
                        use_forecast_heads=self.use_forecast_heads,
                        use_verify_kernel=self.use_verify_kernel, paged=pv)
                    if self.paged_attention:
                        paged2 = st2.cache
                    else:
                        active = n < target
                        paged2 = TransformerLM.scatter_paged(
                            cfg, paged, st2.cache, tables, rows,
                            jnp.maximum(n - 1, 0), W, active)
                    cand2 = jnp.zeros_like(cand).at[:, :W].set(st2.cand)
                    return paged2, st2.tokens, st2.n, cand2, rstats

                def cond(carry):
                    _, _, n_c, _, _, _, r = carry
                    return (r < k) & jnp.any(n_c < target)

                def body(carry):
                    paged_c, tokens_c, n_c, cand_c, acc, act_rounds, r = \
                        carry
                    active = (n_c < target).astype(jnp.int32)
                    paged_c, tokens_c, n_c, cand_c, rstats = one_round(
                        paged_c, tokens_c, n_c, cand_c)
                    # consume the §11 per-round stats ABI: col 0 = accepted
                    return (paged_c, tokens_c, n_c, cand_c,
                            acc + rstats[:, 0], act_rounds + active, r + 1)

                init = (paged, tokens, n, cand, jnp.zeros((R,), jnp.int32),
                        jnp.zeros((R,), jnp.int32), jnp.zeros((), jnp.int32))
                (paged2, tokens2, n2, cand2, acc, act_rounds, r) = \
                    jax.lax.while_loop(cond, body, init)
                stats = jnp.stack(
                    [acc, act_rounds, n2,
                     jnp.broadcast_to(r, (R,))], axis=1)
                return paged2, tokens2, n2, cand2, stats

            wrapped = self.topo.wrap_round(fn, self._paged_specs,
                                           n_batch_in=6, n_batch_out=4)
            # donate pool + tokens/n/cand (dead after the loop); tables,
            # seq_ids and target are cached host-owned uploads — kept alive
            donate = (1, 3, 4, 5) if self.donate else ()
            self._round_fns[(W, k)] = jax.jit(wrapped, donate_argnums=donate)
        return self._round_fns[(W, k)]

    def _prefill_fn(self, C: int):
        """Row-local chunked prefill. Runs as a plain (GSPMD) jit even under
        a mesh — a batch-1 write into one shard's sub-pool is admission-path
        work, so cross-shard traffic here is acceptable; ``table_row``
        carries GLOBAL pool ids (local id + shard offset). The old pool is
        donated, exactly like the round step."""
        if C not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, paged, table_row, row, chunk, start):
                if self.paged_attention:
                    view = PagedView(table_row, row,
                                     self.use_attention_kernel)
                    _, _, nc = TransformerLM.decode_window_paged(
                        params, cfg, chunk, paged, view, start)
                    sel = TransformerLM.select_states(
                        cfg, nc, jnp.full((1,), C, jnp.int32))
                    return TransformerLM.adopt_states_paged(
                        cfg, paged, sel, row)
                view = TransformerLM.gather_paged(cfg, paged, table_row, row)
                _, _, nc = TransformerLM.decode_window(
                    params, cfg, chunk, view, start)
                sel = TransformerLM.select_states(
                    cfg, nc, jnp.full((1,), C, jnp.int32))
                return TransformerLM.scatter_paged(
                    cfg, paged, sel, table_row, row, start, C,
                    jnp.ones((1,), bool))

            kw = {}
            if self.topo.mesh is not None:
                from repro.sharding.rules import paged_cache_shardings
                kw["out_shardings"] = paged_cache_shardings(
                    cfg, self.paged, self.topo.mesh,
                    data_axis=self.topo.data_axis)
            donate = (1,) if self.donate else ()
            self._prefill_fns[C] = jax.jit(fn, donate_argnums=donate, **kw)
        return self._prefill_fns[C]

    # -- slot / block plumbing ---------------------------------------------
    def _mgr(self, b: int):
        """The BlockManager of the data shard owning batch slot ``b``."""
        return self.pool.manager(self.topo.shard_of_slot(b, self.B))

    def _table_offset(self, b: int) -> int:
        """Global pool id of slot ``b``'s shard-local block 0."""
        return self.topo.block_offset(self.topo.shard_of_slot(b, self.B),
                                      self.pool.blocks_per_shard)

    def _ensure_capacity(self, b: int, upto_pos: int):
        """Grow slot ``b``'s block table to cover positions [0, upto_pos)."""
        need = -(-upto_pos // self.block_size)
        assert need <= self.nb, (need, self.nb)
        mgr = self._mgr(b)
        while len(self.owned[b]) < need:
            blk = mgr.alloc(1)[0]
            self.tables[b, len(self.owned[b])] = blk
            self.owned[b].append(blk)
            self._tables_dev = None

    def _clear_row(self, b: int):
        """Reset a released slot so its (inactive) lane reads no stale or
        garbage cache positions: n=1, cache_len=0 -> only its own window."""
        self._mgr(b).release_all(self.owned[b])
        self.owned[b] = []
        self.tables[b] = 0
        self.target[b] = 0
        self.reserved[b] = 0
        self._tables_dev = None
        self._target_dev = None
        self.tokens = self.tokens.at[b].set(0)
        self.n = self.n.at[b].set(1)
        self.cand = self.cand.at[b].set(0)

    def _reset_recurrent_row(self, b: int):
        def rec(stacked, leaf):
            return leaf.at[:, b].set(0) if stacked else leaf.at[b].set(0)

        self.paged = TransformerLM._map_paged(
            self.cfg, (self.paged,), lambda stacked, leaf: leaf, rec)

    def _tables_device(self):
        if self._tables_dev is None:
            self._tables_dev = self.topo.put_batch(self.tables)
        return self._tables_dev

    def _target_device(self):
        if self._target_dev is None:
            self._target_dev = self.topo.put_batch(
                self.target.astype(np.int32))
        return self._target_dev

    # -- admission -----------------------------------------------------------
    def _worst_case_blocks(self, req: Request) -> int:
        # every prompt+generation block a fresh allocation, window at W_max
        return -(-(len(req.prompt) + req.new_tokens + self.W_max)
                 // self.block_size)

    def _outstanding_reservations(self, shard: int) -> int:
        """Blocks already promised to the shard's in-flight slots but not
        yet allocated (their tables grow lazily as n advances)."""
        return int(sum(max(0, int(self.reserved[b]) - len(self.owned[b]))
                       for b in self.topo.slot_range(shard, self.B)
                       if self.slots[b] is not None))

    def _free_slot_in(self, shard: int) -> Optional[int]:
        for b in self.topo.slot_range(shard, self.B):
            if self.slots[b] is None:
                return b
        return None

    def _route(self, req: Request) -> Optional[int]:
        """Pool-pressure admission routing: the free slot on the shard with
        the most block headroom that still covers the request's worst case
        (single shard: the lowest free slot, iff the pool fits it)."""
        headroom = {}
        for s in range(self.topo.data_size):
            if self._free_slot_in(s) is not None:
                headroom[s] = (self.pool.available(s)
                               - self._outstanding_reservations(s))
        shard = self.pool.route(self._worst_case_blocks(req), headroom)
        return None if shard is None else self._free_slot_in(shard)

    def _admit(self, req: Request, b: int):
        req.admit_time = time.monotonic()
        prompt = np.asarray(req.prompt, np.int64)
        L_p = len(prompt)
        mgr = self._mgr(b)

        # prefix-cache: reuse full blocks strictly below position L_p - 1
        # (the verify window rewrites position n-1 = L_p-1 onward, so those
        # blocks stay read-only and shareable). Per-shard cache: hits can
        # only come from the sub-pool this slot decodes through.
        hits, keys = [], []
        nb_full = (L_p - 1) // self.block_size
        if self.prefix_enabled and nb_full:
            hits, keys = mgr.lookup_prefix(prompt, nb_full)
        req.prefix_hit_blocks = len(hits)
        self.owned[b] = list(hits)
        self.tables[b] = 0
        self.tables[b, :len(hits)] = hits
        self._tables_dev = None
        self._ensure_capacity(b, L_p)

        # per-slot state
        self.tokens = self.tokens.at[b].set(0).at[b, :L_p].set(
            jnp.asarray(prompt, jnp.int32))
        self.n = self.n.at[b].set(L_p)
        self.cand = self.cand.at[b].set(0).at[b, 0].set(int(prompt[-1]))
        self.seq_ids = self.seq_ids.at[b].set(req.seq_id)
        if _has_recurrent(self.cfg):
            self._reset_recurrent_row(b)

        # chunked row-local prefill of the un-cached prompt tail (global
        # pool ids: local table + the slot's shard offset)
        start = len(hits) * self.block_size
        table_row = jnp.asarray(self.tables[b:b + 1] + self._table_offset(b))
        row = jnp.asarray([b], jnp.int32)
        for C in prefill_chunks(L_p - 1 - start, self.prefill_chunk):
            chunk = jnp.asarray(prompt[None, start:start + C], jnp.int32)
            self.paged = self._prefill_fn(C)(
                self.params, self.paged, table_row, row, chunk,
                jnp.asarray([start], jnp.int32))
            start += C
            req.prefill_calls += 1
            self.metrics.prefill_calls += 1

        # publish this prompt's freshly computed full blocks
        if self.prefix_enabled:
            for j in range(len(hits), nb_full):
                mgr.register(self.owned[b][j], keys[j])

        self.slots[b] = req
        self.target[b] = L_p + req.new_tokens
        self._target_dev = None
        self.reserved[b] = self._worst_case_blocks(req)

    # -- main loop -----------------------------------------------------------
    def step(self) -> bool:
        """Admit what fits (routing by pool pressure), run one device
        dispatch of up to ``rounds_per_sync`` verify rounds, harvest
        finished requests. The host touches exactly ONE small packed stats
        array per step — no ``n``/``cand`` pulls per round. While admission
        backlog is queued the loop yields every round (``k = 1``) so freed
        slots refill promptly; with no backlog it stays device-resident for
        the full ``rounds_per_sync``. Returns True while there is (or may
        be) work left."""
        while self.queue:
            b = self._route(self.queue.peek())
            if b is None:
                break
            self._admit(self.queue.pop(), b)

        if not any(s is not None for s in self.slots):
            if self.queue:
                raise MemoryError(
                    "admission deadlock: queued request cannot fit an empty "
                    "engine (prompt+target exceeds the block pool)")
            return False

        W = self.controller.window
        k = 1 if self.queue else self.rounds_per_sync
        for b in range(self.B):
            if self.slots[b] is not None:
                self._ensure_capacity(b, int(self.target[b]) + W)
        (self.paged, self.tokens, self.n, self.cand, stats_dev) = \
            self._round_loop_fn(W, k)(self.params, self.paged,
                                      self._tables_device(), self.tokens,
                                      self.n, self.cand, self.seq_ids,
                                      self._target_device())
        # THE host sync: one (B, 4) int32 pull per loop
        stats = np.asarray(stats_dev)
        accepted, rounds_active, n_host = stats[:, 0], stats[:, 1], stats[:, 2]
        rounds_exec = int(stats[:, 3].max())   # critical path across shards

        slot_rows = [b for b in range(self.B) if self.slots[b] is not None]
        for b in slot_rows:
            self.slots[b].calls_used += int(rounds_active[b])
        act_row_rounds = int(rounds_active[slot_rows].sum()) \
            if slot_rows else 0
        acc_total = int(accepted[slot_rows].sum()) if slot_rows else 0
        self.metrics.observe_loop(W, rounds_exec, act_row_rounds, self.B,
                                  acc_total)
        self.controller.observe_aggregate(acc_total, act_row_rounds)

        for b in slot_rows:
            req = self.slots[b]
            if n_host[b] >= self.target[b]:
                req.result = np.asarray(self.tokens[b, :n_host[b]])
                req.finish_time = time.monotonic()
                self.metrics.observe_finish(req)
                self.done.append(req)
                self.slots[b] = None
                self._clear_row(b)
        return True

    def run(self, max_rounds: int = 10_000) -> list[Request]:
        """Drain the queue; returns completed Requests with stats."""
        while self.queue or any(s is not None for s in self.slots):
            if not self.step():
                break
            max_rounds -= 1
            if max_rounds <= 0:
                raise RuntimeError("serving engine did not converge")
        return self.done

    # -- telemetry -----------------------------------------------------------
    def export_metrics(self) -> dict:
        out = self.metrics.export(self.pool.stats_export())
        out["blocks_in_use"] = self.pool.blocks_in_use()
        out["blocks_available"] = self.pool.available()
        if self.topo.data_size > 1:
            out["blocks_available_by_shard"] = [
                self.pool.available(s) for s in range(self.topo.data_size)]
        return out
