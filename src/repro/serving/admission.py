"""Request admission: priority/deadline/FCFS queueing for the serving engine.

The queue orders by ``(priority, deadline, arrival_seq)`` — lower priority
value first; within a class, earliest absolute deadline first (EDF;
requests without a deadline sort last and fall back to FIFO via the arrival
sequence number) — and admits a request only when the engine has both a
free batch slot and enough physical blocks to cover its prompt plus its full
generation target (admission control, not mid-flight preemption: a request
admitted here can always run to completion). ``Request.deadline`` is a
latency SLO in seconds from submission; the engine counts blown SLOs in
``EngineMetrics.deadline_miss_count``.

Prefill itself is *row-local and chunked* (DESIGN.md §6): the admitted row's
blocks are gathered into a batch-1 cache view and the un-cached tail of the
prompt is pushed through ``decode_window`` in power-of-two chunks, so
admitting one request never pays a full-batch forward pass (the seed
``ContinuousBatcher`` re-ran the whole batch per admission chunk).
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L_p,) int
    new_tokens: int
    priority: int = 0            # lower = sooner (EDF/FCFS within a class)
    deadline: Optional[float] = None   # latency SLO seconds from submit
    noise_seed: Optional[int] = None   # noise-stream id; defaults to uid
    result: Optional[np.ndarray] = None
    calls_used: int = 0          # verify rounds this request participated in
    prefill_calls: int = 0       # row-local prefill chunks paid at admission
    prefix_hit_blocks: int = 0   # prompt blocks served from the prefix cache
    submit_time: float = 0.0
    admit_time: float = 0.0
    finish_time: float = 0.0

    @property
    def seq_id(self) -> int:
        return self.uid if self.noise_seed is None else self.noise_seed

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.submit_time

    @property
    def deadline_time(self) -> float:
        """Absolute SLO expiry (monotonic clock); +inf without a deadline."""
        if self.deadline is None:
            return math.inf
        return self.submit_time + self.deadline

    @property
    def missed_deadline(self) -> bool:
        return self.deadline is not None and self.finish_time > self.deadline_time


def prefill_chunks(length: int, max_chunk: int = 64) -> list[int]:
    """Greedy power-of-two cover of ``length`` positions (largest first).

    Bounds distinct compiled prefill widths to ``log2(max_chunk) + 1``
    while covering any prompt length exactly (no padding writes).
    """
    out, c = [], max_chunk
    while length > 0:
        while c > length:
            c //= 2
        out.append(c)
        length -= c
    return out


class AdmissionQueue:
    """Priority + earliest-deadline + FCFS admission queue."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, req: Request):
        req.submit_time = time.monotonic()
        heapq.heappush(self._heap, (req.priority, req.deadline_time,
                                    next(self._seq), req))

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[-1]

    def peek(self) -> Optional[Request]:
        return self._heap[0][-1] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
