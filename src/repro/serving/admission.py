"""Request admission: priority/FCFS queueing for the serving engine.

The queue orders by ``(priority, arrival_seq)`` — lower priority value first,
FIFO within a class — and admits a request only when the engine has both a
free batch slot and enough physical blocks to cover its prompt plus its full
generation target (admission control, not mid-flight preemption: a request
admitted here can always run to completion).

Prefill itself is *row-local and chunked* (DESIGN.md §6): the admitted row's
blocks are gathered into a batch-1 cache view and the un-cached tail of the
prompt is pushed through ``decode_window`` in power-of-two chunks, so
admitting one request never pays a full-batch forward pass (the seed
``ContinuousBatcher`` re-ran the whole batch per admission chunk).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L_p,) int
    new_tokens: int
    priority: int = 0            # lower = sooner (FCFS within a class)
    noise_seed: Optional[int] = None   # noise-stream id; defaults to uid
    result: Optional[np.ndarray] = None
    calls_used: int = 0          # verify rounds this request participated in
    prefill_calls: int = 0       # row-local prefill chunks paid at admission
    prefix_hit_blocks: int = 0   # prompt blocks served from the prefix cache
    submit_time: float = 0.0
    admit_time: float = 0.0
    finish_time: float = 0.0

    @property
    def seq_id(self) -> int:
        return self.uid if self.noise_seed is None else self.noise_seed

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.submit_time


def prefill_chunks(length: int, max_chunk: int = 64) -> list[int]:
    """Greedy power-of-two cover of ``length`` positions (largest first).

    Bounds distinct compiled prefill widths to ``log2(max_chunk) + 1``
    while covering any prompt length exactly (no padding writes).
    """
    out, c = [], max_chunk
    while length > 0:
        while c > length:
            c //= 2
        out.append(c)
        length -= c
    return out


class AdmissionQueue:
    """Priority + FCFS admission queue with simple occupancy accounting."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, req: Request):
        req.submit_time = time.monotonic()
        heapq.heappush(self._heap, (req.priority, next(self._seq), req))

    def pop(self) -> Request:
        _, _, req = heapq.heappop(self._heap)
        return req

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
