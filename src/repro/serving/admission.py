"""Request admission: priority/deadline/FCFS queueing for the serving engine.

The queue orders by ``(priority, deadline, arrival_seq)`` — lower priority
value first; within a class, earliest absolute deadline first (EDF;
requests without a deadline sort last and fall back to FIFO via the arrival
sequence number) — and admits a request only when the engine has both a
free batch slot and enough physical blocks to cover its prompt plus its full
generation target (run-to-completion admission control; a request admitted
can always finish — preemption parks it *exactly*, never kills it).
``Request.deadline`` is a latency SLO in seconds from submission; the
engine counts blown SLOs in ``EngineMetrics.deadline_miss_count`` (and, for
requests that expire while still queued or parked,
``deadline_missed_in_queue`` — detected at admission poll time, not only
when the request happens to finish).

Saturation-safe scheduling (DESIGN.md §12): the engine no longer stops at
the first unroutable request. ``lookahead(k)`` exposes the first ``k``
requests in queue order so a small fitting request behind an oversized head
can admit (bounded lookahead); every such bypass ages the head
(``Request.bypassed``) and once the head's aging bound is reached admission
goes head-only until it lands — so the head cannot starve. ``requeue``
re-inserts a preempted request *without* resetting its submit time or
arrival order, keeping EDF/FIFO ordering and SLO accounting stable across
park/resume cycles.

Prefill itself is *row-local and chunked* (DESIGN.md §6): the admitted row's
blocks are gathered into a batch-1 cache view and the un-cached tail of the
prompt is pushed through ``decode_window`` in power-of-two chunks, so
admitting one request never pays a full-batch forward pass (the seed
``ContinuousBatcher`` re-ran the whole batch per admission chunk).
"""
from __future__ import annotations

import heapq
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serving.faults import RequestError


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L_p,) int
    new_tokens: int
    priority: int = 0            # lower = sooner (EDF/FCFS within a class)
    deadline: Optional[float] = None   # latency SLO seconds from submit
    noise_seed: Optional[int] = None   # noise-stream id; defaults to uid
    result: Optional[np.ndarray] = None
    error: Optional[RequestError] = None  # structured failure (DESIGN.md §14)
    retries: int = 0             # re-admissions consumed after failures
    calls_used: int = 0          # verify rounds this request participated in
    prefill_calls: int = 0       # row-local prefill chunks paid at admission
    prefix_hit_blocks: int = 0   # prompt blocks served from the prefix cache
    preemptions: int = 0         # times parked by a higher-priority request
    migrations: int = 0          # times moved to another slot/shard mid-flight
    bypassed: int = 0            # admissions that jumped this request while
    #                              it sat at the queue head (aging signal)
    queue_deadline_missed: bool = False  # SLO expired while queued/parked
    submit_time: float = 0.0
    admit_time: float = 0.0
    finish_time: float = 0.0
    _seq: Optional[int] = None   # arrival order, pinned at first push

    @property
    def seq_id(self) -> int:
        return self.uid if self.noise_seed is None else self.noise_seed

    @property
    def ok(self) -> bool:
        """Finished successfully (result delivered, no structured error)."""
        return self.error is None and self.result is not None

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def queue_wait(self) -> float:
        return self.admit_time - self.submit_time

    @property
    def deadline_time(self) -> float:
        """Absolute SLO expiry (monotonic clock); +inf without a deadline."""
        if self.deadline is None:
            return math.inf
        return self.submit_time + self.deadline

    @property
    def missed_deadline(self) -> bool:
        return self.deadline is not None and self.finish_time > self.deadline_time

    # -- restart-safe clocks (DESIGN.md §16) --------------------------------
    def clock_export(self, now: Optional[float] = None) -> dict:
        """Elapsed-duration snapshot of this request's clocks. Raw
        ``time.monotonic`` stamps are meaningless in another process (the
        clock origin is per-boot/per-process), so checkpoints durable-ize
        *how long* the request has been waiting/running, never *when* it
        started."""
        if now is None:
            now = time.monotonic()
        return {"elapsed": (now - self.submit_time
                            if self.submit_time else 0.0),
                "admit_elapsed": (now - self.admit_time
                                  if self.admit_time else None)}

    def clock_rebase(self, clocks: dict,
                     now: Optional[float] = None) -> None:
        """Re-anchor exported durations on *this* process's monotonic clock
        (the restore-side inverse of ``clock_export``): afterwards
        ``max_request_seconds``, ``deadline_time`` and the latency metrics
        keep counting from where the dead process left off."""
        if now is None:
            now = time.monotonic()
        self.submit_time = now - float(clocks.get("elapsed") or 0.0)
        admit = clocks.get("admit_elapsed")
        self.admit_time = (now - float(admit)) if admit is not None else 0.0


def pow2_at_most(x: int) -> int:
    """Largest power of two <= x (x >= 1)."""
    assert x >= 1, x
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def prefill_chunks(length: int, max_chunk: int = 64) -> list[int]:
    """Greedy power-of-two cover of ``length`` positions (largest first).

    Bounds distinct compiled prefill widths to ``log2(max_chunk) + 1``
    while covering any prompt length exactly (no padding writes).
    ``max_chunk`` is normalized DOWN to a power of two first — a non-pow2
    bound (say 48) would otherwise emit non-pow2 widths (48, 24, ...) and
    silently break the compiled-width guarantee (the halving loop only
    preserves pow2-ness of a pow2 start).
    """
    out, c = [], pow2_at_most(max(1, max_chunk))
    while length > 0:
        while c > length:
            c //= 2
        out.append(c)
        length -= c
    return out


@dataclass
class StagedEntry:
    """One pre-staged request awaiting in-loop adoption (DESIGN.md §15).

    Host admission builds these for queued requests while every slot is
    occupied: worst-case blocks are allocated up front (run-to-completion —
    an adopted row never allocates mid-loop), device/host prefix hits cover
    the first ``n0 - 1`` positions, and the descriptor fields below are
    what ``pack_staged_descriptors`` uploads for the device-side adoption
    scan. ``key`` is the request's admission-queue rank — staging commits
    strictly in queue order, and a higher-ranked arrival unstages the area
    (``_reconcile_staging``) rather than jumping it."""
    req: Request
    shard: int
    prompt: np.ndarray           # (L_p,) int32 — fills the staged row buffer
    n0: int                      # adoption start: covered positions + 1
    plen: int                    # prompt length (forced-accept boundary)
    target: int                  # plen + new_tokens
    blocks: list                 # shard-local ids, worst case, table order
    table_row: np.ndarray        # (nb,) int32
    poison: int                  # §14 poison-mask value for this stream
    key: tuple                   # (priority, deadline_time, _seq)


def pack_staged_descriptors(staged, slots_per_shard: int, nb: int,
                            max_len: int) -> tuple:
    """Pack per-shard staged-entry lists into the eight descriptor arrays
    of the §15 round ABI, shard-major (``index = shard * S + i``, FIFO
    within a shard — the order the device adoption scan consumes them):
    ``(valid, tables, tokens, n, target, seq, poison, plen)``. Unused
    descriptors are zero/invalid; an all-invalid pack is the bit-exact
    no-op the adoption scan reduces to when nothing is staged."""
    S = slots_per_shard
    D = len(staged)
    valid = np.zeros(D * S, np.int32)
    tables = np.zeros((D * S, nb), np.int32)
    tokens = np.zeros((D * S, max_len), np.int32)
    n0 = np.ones(D * S, np.int32)
    target = np.zeros(D * S, np.int32)
    seq = np.zeros(D * S, np.int32)
    poison = np.zeros(D * S, np.int32)
    plen = np.zeros(D * S, np.int32)
    for s, entries in enumerate(staged):
        assert len(entries) <= S, (len(entries), S)
        for i, e in enumerate(entries):
            j = s * S + i
            valid[j] = 1
            tables[j] = e.table_row
            tokens[j, :len(e.prompt)] = e.prompt
            n0[j] = e.n0
            target[j] = e.target
            seq[j] = e.req.seq_id
            poison[j] = e.poison
            plen[j] = e.plen
    return valid, tables, tokens, n0, target, seq, poison, plen


class AdmissionQueue:
    """Priority + earliest-deadline + FCFS admission queue with bounded
    lookahead and exact-resume requeue."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def _entry(self, req: Request):
        if req._seq is None:               # arrival order pinned once
            req._seq = next(self._seq)
        return (req.priority, req.deadline_time, req._seq, req)

    def push(self, req: Request):
        req.submit_time = time.monotonic()
        heapq.heappush(self._heap, self._entry(req))

    def requeue(self, req: Request):
        """Re-insert a preempted (parked) request for exact resume: submit
        time and arrival order are preserved, so its EDF/FIFO rank and SLO
        clock are those of the original submission."""
        heapq.heappush(self._heap, self._entry(req))

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[-1]

    def peek(self) -> Optional[Request]:
        return self._heap[0][-1] if self._heap else None

    def lookahead(self, k: int) -> list[Request]:
        """The first ``k`` requests in queue order (head first) without
        removing them — the admission window the engine scans past an
        unroutable head."""
        return [e[-1] for e in heapq.nsmallest(k, self._heap)]

    def remove(self, req: Request) -> bool:
        """Remove a specific request (a lookahead admission that is not the
        head). O(n) — admission-path work, never on the round hot path."""
        for i, e in enumerate(self._heap):
            if e[-1] is req:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return True
        return False

    def advance_seq(self, past: int) -> None:
        """Restore path (DESIGN.md §16): restart the arrival counter past
        the highest recovered rank, so requests submitted *after* the
        restart sort behind every request recovered with its original
        ``_seq`` pinned."""
        self._seq = itertools.count(max(int(past) + 1, 0))

    def requests(self) -> list[Request]:
        """All queued requests, unordered (deadline-expiry polling)."""
        return [e[-1] for e in self._heap]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
