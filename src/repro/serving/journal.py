"""Write-ahead request journal (DESIGN.md §16).

Append-only log of request lifecycle transitions — submit / admit / park /
retry / finish / cancel / fail — so a restarted engine knows exactly which
requests were accepted and which of those already reached a terminal state.
The journal records *intent, identity, and outcomes*, never device
tensors: a replayed ``submit`` carries the full prompt and sampling
identity (uid, noise seed, priority, deadline), which by the engine's
determinism invariant (out = f(context, eps)) is sufficient to regenerate
bitwise-identical tokens from scratch; cached state only makes that
cheaper. ``finish`` records carry the delivered token ids (host-side ints,
same order of magnitude as the journaled prompt), making the journal the
durable delivery channel: a crash between journaling a finish and the
client draining it re-delivers the exact same tokens on restore.

Frame format (one record)::

    u32 len(payload) | u32 crc32(payload) | payload (JSON, utf-8)

Fsync discipline: ``append`` buffers; every ``fsync_every`` records (and on
every explicit ``sync()``, which the engine calls at each round-sync
boundary) the file is flushed and fsynced. With ``fsync_every=1`` (the
default) an accepted submit is durable before ``submit()`` returns — a
crash at *any* later instant loses no accepted request. Larger values
batch the fsync cost; the exposure window is then at most
``fsync_every - 1`` records past the last sync boundary.

Replay discipline: records are read sequentially; the first frame whose
length field runs past EOF or whose crc fails is a torn tail from a crash
mid-append — replay stops there and **truncates** the file back to the
last good frame boundary (never errors, never resurrects partial bytes),
so the journal is again well-formed for appending. The
``journal_truncate`` fault seam simulates exactly that crash by tearing
off the last good record before parsing.
"""
from __future__ import annotations

import os
import struct
import json
import zlib
from typing import Optional

from repro.serving.faults import kill_point

_FRAME = struct.Struct("<II")            # payload length, crc32(payload)

# Record types. ``submit`` is the only one carrying payload enough to
# recreate a Request; the rest reference it by uid.
TYPES = ("submit", "admit", "park", "retry", "finish", "cancel", "fail")
TERMINAL = frozenset(("finish", "cancel", "fail"))


def _encode(rec: dict) -> bytes:
    payload = json.dumps(rec, separators=(",", ":"),
                         sort_keys=True).encode()
    return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload


class RequestJournal:
    """Crc-framed append-only WAL with batched fsync and torn-tail repair."""

    def __init__(self, path: str, fsync_every: int = 1, *, faults=None):
        assert fsync_every >= 1, fsync_every
        self.path = path
        self.fsync_every = int(fsync_every)
        self.faults = faults
        self.appends = 0             # records appended this process
        self.syncs = 0               # fsyncs issued
        self._unsynced = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Repair a torn tail *before* opening for append, so new records
        # land on a frame boundary, not on top of half a dead frame.
        if os.path.exists(path):
            self.replay(path)
        self._f = open(path, "ab")

    # -- writing --------------------------------------------------------------
    def append(self, type: str, **fields) -> None:
        """Buffer one record; fsyncs every ``fsync_every`` appends."""
        assert type in TYPES, type
        rec = {"type": type, **fields}
        self._f.write(_encode(rec))
        self.appends += 1
        self._unsynced += 1
        if self._unsynced >= self.fsync_every:
            self.sync()

    def sync(self) -> None:
        """Flush + fsync the journal. The ``pre_fsync`` kill point sits
        between the two: a process killed there has handed its records to
        the OS (a SIGKILL does not lose flushed data — only power loss
        does, which the torn-tail replay covers) but not forced them to
        media."""
        if self._f.closed:
            return
        self._f.flush()
        kill_point("pre_fsync")
        os.fsync(self._f.fileno())
        self.syncs += 1
        self._unsynced = 0

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def stats_export(self) -> dict:
        return {"journal_appends": self.appends,
                "journal_syncs": self.syncs,
                "journal_unsynced": self._unsynced}

    # -- replay ---------------------------------------------------------------
    @classmethod
    def replay(cls, path: str, *, faults=None) -> list:
        """Read every intact record; truncate the file at the first torn
        frame. Returns the records in append order ([] for a missing or
        empty journal)."""
        try:
            with open(path, "rb") as f:
                buf = f.read()
        except OSError:
            return []
        records, offsets, off = [], [], 0
        while off + _FRAME.size <= len(buf):
            plen, crc = _FRAME.unpack_from(buf, off)
            start = off + _FRAME.size
            payload = buf[start:start + plen]
            if len(payload) != plen or zlib.crc32(payload) != crc:
                break                            # torn tail: crash mid-append
            try:
                rec = json.loads(payload)
            except ValueError:
                break
            records.append(rec)
            off = start + plen
            offsets.append(off)
        if faults is not None and faults.fire("journal_truncate") and records:
            records.pop()                        # simulate losing the tail
            offsets.pop()
            off = offsets[-1] if offsets else 0
        if off < len(buf):
            try:
                with open(path, "r+b") as f:
                    f.truncate(off)
            except OSError:
                pass
        return records

    @staticmethod
    def pending(records) -> "tuple[dict, dict, dict]":
        """Fold replayed records into recovery state.

        Returns ``(pending, parked, delivered)``: ``pending`` maps uid ->
        its submit record with later ``retry`` fields (noise_seed, retries)
        folded in and ``admitted``/``parked`` flags, for every accepted
        request that never reached a terminal record; ``parked`` maps
        uid -> the last park record for uids still pending (the checkpoint
        may hold a resumable snapshot for these); ``delivered`` maps
        uid -> its submit record with the terminal outcome folded in
        (``terminal`` type plus the finish ``tokens`` or failure ``code``).
        Terminal records are the *commit of the result*, not of its
        pickup — a crash can land between journaling a finish and the
        client draining it — so restore re-delivers every journaled
        outcome (at-least-once; re-delivery is bitwise-identical by the
        determinism invariant, so clients dedup by uid trivially)."""
        pending: dict = {}
        parked: dict = {}
        delivered: dict = {}
        for rec in records:
            uid = rec.get("uid")
            t = rec.get("type")
            if t == "submit":
                pending[uid] = dict(rec, admitted=False, parked=False)
            elif uid not in pending:
                continue                 # terminal already folded, or alien
            elif t in TERMINAL:
                delivered[uid] = dict(pending.pop(uid), terminal=t,
                                      **{k: rec[k] for k in
                                         ("tokens", "code") if k in rec})
                parked.pop(uid, None)
            elif t == "admit":
                pending[uid]["admitted"] = True
                pending[uid]["parked"] = False
                parked.pop(uid, None)
            elif t == "park":
                pending[uid]["parked"] = True
                parked[uid] = rec
            elif t == "retry":
                pending[uid]["noise_seed"] = rec.get(
                    "noise_seed", pending[uid].get("noise_seed"))
                pending[uid]["retries"] = rec.get(
                    "retries", pending[uid].get("retries", 0))
                pending[uid]["admitted"] = False
        return pending, parked, delivered
