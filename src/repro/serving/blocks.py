"""Paged KV-cache block manager with a hash-based prefix cache (DESIGN.md §6).

Host-side bookkeeping for the physical block pool that
``TransformerLM.init_paged_cache`` allocates on device: a free list of
fixed-size blocks, per-sequence block tables, refcounts, and a chained-hash
prefix cache so requests sharing a prompt prefix reuse already-computed KV
blocks instead of re-running prefill over them.

Invariants:

* Physical block 0 is a reserved write sink (masked scatter lanes land
  there); it is never allocated and never enters the prefix cache.
* A block is *registerable* (hashable, shareable) only once it holds a full
  ``block_size`` run of prompt positions that the serving engine will never
  rewrite — i.e. blocks entirely below position ``L_p - 1``, because the
  verify window rewrites position ``n - 1`` every round and ``n`` starts at
  ``L_p``. Shared blocks are therefore read-only by construction; no
  copy-on-write is ever needed (copy-on-admit: a new sequence pointing its
  table at them is the admission fast path).
* Releasing a sequence decrements refcounts; blocks that carry a prefix hash
  go to a *cached-free* LRU pool (still hittable) and are evicted only when
  the plain free list runs dry.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def chain_hashes(tokens, block_size: int, n_blocks: Optional[int] = None):
    """Chained content hashes for the leading full blocks of ``tokens``.

    ``key_j = hash(key_{j-1}, tokens[j*bs:(j+1)*bs])`` — a block's KV depends
    on the whole prefix, so the key must too (vLLM-style prefix keys).
    """
    tokens = np.asarray(tokens)
    total = len(tokens) // block_size if n_blocks is None else n_blocks
    keys, prev = [], 0
    for j in range(total):
        blk = tuple(int(t) for t in tokens[j * block_size:(j + 1) * block_size])
        prev = hash((prev,) + blk)
        keys.append(prev)
    return keys


@dataclass
class BlockStats:
    allocated: int = 0
    freed: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    evictions: int = 0
    spilled: int = 0             # block contents preserved host-side (parked
    #                              payloads, evictions saved by the host tier)
    dropped: int = 0             # hashed contents evicted outright — no host
    #                              tier, or its arena refused the spill
    migrated_in: int = 0         # landing blocks allocated for a migration
    migrated_out: int = 0        # blocks released by a departing migration

    def export(self) -> dict:
        total = self.prefix_hits + self.prefix_misses
        return {
            "blocks_allocated": self.allocated,
            "blocks_freed": self.freed,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_rate": (self.prefix_hits / total) if total else 0.0,
            "evictions": self.evictions,
            "blocks_spilled": self.spilled,
            "blocks_dropped": self.dropped,
            "blocks_migrated_in": self.migrated_in,
            "blocks_migrated_out": self.migrated_out,
        }


class BlockManager:
    """Free-list allocator + prefix cache over ``num_blocks`` physical blocks
    of ``block_size`` token positions each (block 0 reserved)."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: list[int] = list(range(num_blocks - 1, 0, -1))  # pop() -> 1 first
        self.refcount = np.zeros(num_blocks, np.int32)
        self.hash_of: dict[int, int] = {}          # block id -> prefix key
        self.block_of: dict[int, int] = {}         # prefix key -> block id
        self.cached_free: OrderedDict[int, None] = OrderedDict()  # LRU, oldest first
        self.stats = BlockStats()
        # host-tier escape hatch (DESIGN.md §13): called when a registered
        # cached-free block is about to be evicted for reallocation, with
        # ``(block_id, prefix_key)`` — still registered, contents readable.
        # Returns True iff the contents were preserved host-side (counted
        # ``spilled``); False/None drops them outright (``dropped``).
        self.spill_hook = None
        # fault-injection seam (DESIGN.md §14): a no-arg callable consulted
        # at the top of every ``alloc`` call; True raises MemoryError as if
        # the pool were exhausted (serving/faults.py ``alloc`` seam)
        self.fault_hook = None

    # -- capacity ----------------------------------------------------------
    def available(self) -> int:
        return len(self.free) + len(self.cached_free)

    def blocks_in_use(self) -> int:
        return int((self.refcount > 0).sum())

    # -- allocation --------------------------------------------------------
    def alloc(self, n: int = 1) -> list[int]:
        """Take ``n`` fresh private blocks (refcount 1, no hash)."""
        if self.fault_hook is not None and self.fault_hook():
            raise MemoryError(
                "injected block allocation failure (FaultPlan seam 'alloc')")
        if self.available() < n:
            raise MemoryError(
                f"block pool exhausted: want {n}, have {self.available()}")
        out = []
        for _ in range(n):
            if self.free:
                b = self.free.pop()
            else:
                b, _ = self.cached_free.popitem(last=False)  # evict oldest
                key = self.hash_of.get(b)
                saved = bool(key is not None and self.spill_hook is not None
                             and self.spill_hook(b, key))
                if saved:
                    self.stats.spilled += 1
                else:
                    self.stats.dropped += 1
                self._unregister(b)
                self.stats.evictions += 1
            self.refcount[b] = 1
            self.stats.allocated += 1
            out.append(b)
        return out

    def _unregister(self, b: int):
        key = self.hash_of.pop(b, None)
        if key is not None and self.block_of.get(key) == b:
            del self.block_of[key]

    # -- prefix cache ------------------------------------------------------
    def lookup_prefix(self, tokens, max_blocks: int) -> tuple[list[int], list[int]]:
        """Longest cached chain for ``tokens``' leading full blocks (at most
        ``max_blocks`` of them). Returns (hit block ids with refcount taken,
        chained keys for all ``max_blocks`` leading blocks)."""
        keys = chain_hashes(tokens, self.block_size, max_blocks)
        hits = []
        for key in keys:
            b = self.block_of.get(key)
            if b is None:
                break
            self.acquire(b)
            hits.append(b)
        self.stats.prefix_hits += len(hits)
        self.stats.prefix_misses += len(keys) - len(hits)
        return hits, keys

    def lookup_prefix_tiered(self, tokens, max_blocks: int, tier=None,
                             shard: int = 0):
        """``lookup_prefix`` with host-tier fall-through (DESIGN.md §13):
        device misses past the hit run are probed against the tier's spilled
        KV blocks. Returns ``(hits, keys, host_keys)`` where ``host_keys``
        is the contiguous run of chained keys, starting right after the
        device hits, whose contents are resident host-side — the engine
        stages those back instead of recomputing them. Chained keys make any
        resident *prefix* run valid; a resident block behind a gap is not."""
        hits, keys = self.lookup_prefix(tokens, max_blocks)
        host_keys: list[int] = []
        if tier is not None and len(hits) < len(keys):
            run = tier.kv_run(shard, keys[len(hits):])
            host_keys = keys[len(hits):len(hits) + run]
        return hits, keys, host_keys

    def register(self, b: int, key: int):
        """Publish a (still-referenced) block under a prefix key so later
        admissions can share it. First writer wins; duplicates stay private."""
        assert self.refcount[b] > 0 and b != 0
        if key not in self.block_of and b not in self.hash_of:
            self.block_of[key] = b
            self.hash_of[b] = key

    def acquire(self, b: int):
        """Add a reference to an existing block (prefix-cache hit)."""
        if self.refcount[b] == 0:        # resurrect from cached-free pool
            self.cached_free.pop(b, None)
        self.refcount[b] += 1

    def release(self, b: int):
        """Drop a reference. Unreferenced hashed blocks become cached-free
        (still hittable); unhashed ones return to the plain free list."""
        assert self.refcount[b] > 0, f"double free of block {b}"
        self.refcount[b] -= 1
        if self.refcount[b] == 0:
            self.stats.freed += 1
            if b in self.hash_of:
                self.cached_free[b] = None
                self.cached_free.move_to_end(b)
            else:
                self.free.append(b)

    def release_all(self, blocks):
        for b in blocks:
            self.release(b)

    def spill(self, blocks) -> int:
        """Release a preempted sequence's blocks (their contents have been
        parked host-side). Hashed prompt blocks drop into the cached-free
        pool, so an exact resume can re-hit them without re-uploading."""
        self.release_all(blocks)
        self.stats.spilled += len(blocks)
        return len(blocks)


class StagingLedger:
    """Staged-block reservation accounting for in-loop adoption
    (DESIGN.md §15).

    Staging pre-allocates a queued request's worst-case block need *before*
    the dispatch that may adopt it, so the device loop never allocates. The
    ledger guards the pool from staging starving resident sequences: a
    stage claim is granted only out of the headroom the caller computes
    *net of resident reservations*, and is tracked per shard + per request
    so reconciliation (unstage / adopt / cancel) releases exactly what was
    claimed. The ledger never touches the ``BlockManager`` free lists — the
    engine allocates/releases the actual blocks; the ledger is the
    admission-side bookkeeping that says whether it may.
    """

    def __init__(self, slots_per_shard: int):
        assert slots_per_shard >= 0
        self.slots_per_shard = slots_per_shard
        self._claims: dict[tuple[int, int], int] = {}   # (shard, uid) -> blocks
        self._by_shard: dict[int, int] = {}             # shard -> blocks claimed
        self._count: dict[int, int] = {}                # shard -> staged entries

    # -- queries -----------------------------------------------------------
    def staged_blocks(self, shard: int) -> int:
        return self._by_shard.get(shard, 0)

    def staged_count(self, shard: int) -> int:
        return self._count.get(shard, 0)

    def has(self, shard: int, uid: int) -> bool:
        return (shard, uid) in self._claims

    # -- lifecycle ---------------------------------------------------------
    def try_claim(self, shard: int, uid: int, need: int,
                  headroom: int) -> bool:
        """Claim ``need`` blocks of ``shard``'s pool for staged request
        ``uid``. ``headroom`` is the caller's free-block count net of
        resident worst-case reservations AND of this ledger's existing
        claims on the shard. Refuses when the shard's staging slots are
        full or the claim would eat into resident headroom."""
        assert (shard, uid) not in self._claims, (shard, uid)
        if self._count.get(shard, 0) >= self.slots_per_shard:
            return False
        if need > headroom:
            return False
        self._claims[(shard, uid)] = need
        self._by_shard[shard] = self._by_shard.get(shard, 0) + need
        self._count[shard] = self._count.get(shard, 0) + 1
        return True

    def release(self, shard: int, uid: int) -> int:
        """Drop a claim (the request was unstaged, adopted — its blocks now
        counted as resident — or cancelled). Returns the claimed size."""
        need = self._claims.pop((shard, uid))
        self._by_shard[shard] -= need
        self._count[shard] -= 1
        assert self._by_shard[shard] >= 0 and self._count[shard] >= 0
        return need


class ShardedBlockPool:
    """Per-data-shard ``BlockManager``s with pool-pressure routing on top
    (DESIGN.md §10).

    Each shard owns an independent sub-pool of ``blocks_per_shard`` physical
    blocks. Ids are *shard-local* (global pool id = shard * blocks_per_shard
    + local id) and each sub-pool keeps its own reserved sink block (local
    id 0) and its own prefix cache — block sharing never crosses shards,
    which is what keeps the mesh round's table indirection shard-local.
    ``num_shards=1`` is the single-device engine's pool, bit-for-bit the old
    bare ``BlockManager`` behaviour.
    """

    def __init__(self, num_shards: int, blocks_per_shard: int,
                 block_size: int):
        assert num_shards >= 1
        self.num_shards = num_shards
        self.blocks_per_shard = blocks_per_shard
        self.block_size = block_size
        self.shards = [BlockManager(blocks_per_shard, block_size)
                       for _ in range(num_shards)]

    def manager(self, shard: int) -> BlockManager:
        return self.shards[shard]

    # -- sequence migration (block accounting half) ------------------------
    def begin_migration(self, src_shard: int, dst_shard: int,
                        n: int) -> list[int]:
        """Allocate ``n`` landing blocks on ``dst_shard`` for a sequence
        moving off ``src_shard``. Returns the fresh shard-LOCAL ids; the
        caller device-copies the block contents and then calls
        ``finish_migration`` to release the source blocks. Raises
        MemoryError if the destination sub-pool cannot take them."""
        assert src_shard != dst_shard, (src_shard, dst_shard)
        out = self.shards[dst_shard].alloc(n)
        self.shards[dst_shard].stats.migrated_in += n
        return out

    def finish_migration(self, src_shard: int, blocks) -> None:
        """Release a migrated sequence's source blocks (contents now live in
        the destination sub-pool). Shared prefix blocks just drop a ref."""
        self.shards[src_shard].release_all(blocks)
        self.shards[src_shard].stats.migrated_out += len(blocks)

    # -- host tier -----------------------------------------------------------
    def set_spill_hook(self, make_hook) -> None:
        """Install a per-shard eviction spill hook: ``make_hook(shard)``
        returns the hook (or None) for that shard's sub-pool."""
        for s, m in enumerate(self.shards):
            m.spill_hook = make_hook(s)

    def set_fault_hook(self, hook) -> None:
        """Install one shared allocation fault hook on every sub-pool
        (DESIGN.md §14; host-side calls are sequential, so a shared
        FaultPlan counter stays deterministic across shards)."""
        for m in self.shards:
            m.fault_hook = hook

    # -- aggregate capacity ------------------------------------------------
    def available(self, shard: Optional[int] = None) -> int:
        if shard is not None:
            return self.shards[shard].available()
        return sum(m.available() for m in self.shards)

    def blocks_in_use(self) -> int:
        return sum(m.blocks_in_use() for m in self.shards)

    # -- admission routing -------------------------------------------------
    @staticmethod
    def route(need: int, headroom_by_shard: dict) -> Optional[int]:
        """Pool-pressure routing: among candidate shards (caller filters to
        those with a free batch slot), pick the one with the most headroom
        (free blocks minus outstanding reservations) that still covers the
        request's worst-case ``need``; lowest shard id breaks ties. Returns
        None when no shard can take the request."""
        best = None
        for s in sorted(headroom_by_shard):
            head = headroom_by_shard[s]
            if head >= need and (best is None or head > best[1]):
                best = (s, head)
        return None if best is None else best[0]

    def stats_export(self) -> dict:
        """Counters summed across shards; hit rate recomputed globally."""
        out: dict = {}
        for m in self.shards:
            for k, v in m.stats.export().items():
                out[k] = out.get(k, 0) + v
        total = out.get("prefix_hits", 0) + out.get("prefix_misses", 0)
        out["prefix_hit_rate"] = (out["prefix_hits"] / total) if total else 0.0
        return out
