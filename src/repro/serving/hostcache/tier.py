"""HostTier: the facade the engine consults between the device prefix
cache and fresh compute (DESIGN.md §13).

One shared :class:`~repro.serving.hostcache.arena.HostArena` (a single
byte budget for the whole process — mesh topologies partition *keys* per
data shard, not bytes, so a hot shard can use headroom an idle shard is
not) serves three clients through namespaced keys:

* ``("kv", shard, chain_key)`` — spilled prefix blocks: the per-layer pool
  rows of one hashed KV block, keyed by the same chained prompt hash
  ``blocks.chain_hashes`` registers on device. Spill writes them on
  BlockManager eviction; ``kv_run`` answers lookup-miss fall-through with
  the longest contiguous resident run so the engine only stages blocks it
  can actually use (chained keys make any resident prefix run valid).
* ``("rec", shard, chain_key)`` — recurrent-state snapshots: a slot's
  ssm/rwkv/hybrid state rows checkpointed at a registerable block
  boundary. Same keying as KV blocks, so a shared system prompt hits for
  recurrent archs exactly where it hits for attention.
* ``("park", uid)`` — a parked sequence's *private* payload (partial
  blocks + live recurrent rows), pinned until resume. The shared hashed
  prefix blocks are NOT duplicated here — they live once in the ``kv``
  namespace, refcount-pinned by each parked victim (satellite: dedup).

All payloads are flat lists of numpy arrays; the engine owns pytree
(de)composition so the tier stays model-agnostic.

Robustness (DESIGN.md §14): the arena stamps/verifies checksums (corrupt
entries demote to misses — see ``arena.HostArena``), and a
:class:`~repro.serving.faults.CircuitBreaker` sits in front of every
arena-touching op. Repeated integrity/staging failures trip it: an *open*
tier answers every probe as a total miss (puts refused, gets None, runs 0)
so the engine quietly recomputes instead of erroring each admission, then
half-open re-probes after a deterministic op-count cooldown. ``unpin`` and
``drop`` stay ungated — refcount hygiene must run even while tripped.

Durability (DESIGN.md §16): an optional :class:`DiskTier` sits below the
arena. Arena LRU victims in the ``kv``/``rec`` namespaces demote to
crc-framed files (the ``on_evict`` hook fires before the victim's buffers
are slab-recycled); lookups fall through arena -> disk -> miss, promoting
disk hits back into the arena. ``park`` payloads never spill — they are
pinned (so never eviction victims) and private to a live process; a crash
loses them by design and the journal re-admits the request instead.
``flush_to_disk`` force-demotes still-resident keys at a checkpoint
boundary so the snapshot's references are durable, not merely cached.
"""
from __future__ import annotations

from typing import Optional

from repro.serving.faults import CircuitBreaker

from .arena import HostArena
from .disk import DiskTier, durable_name
from .staging import StagingRing


class HostTier:
    def __init__(self, capacity_bytes: int, num_shards: int = 1,
                 staging_depth: int = 2, *, integrity: bool = True,
                 faults=None, breaker: Optional[CircuitBreaker] = None,
                 disk: Optional[DiskTier] = None):
        self.breaker = breaker
        self.disk = disk
        self.arena = HostArena(capacity_bytes, integrity=integrity,
                               faults=faults,
                               on_corruption=lambda key: self.record_failure(),
                               on_evict=(self._spill_to_disk
                                         if disk is not None else None))
        self.num_shards = num_shards
        self.staging = StagingRing(depth=staging_depth, faults=faults)
        self.disk_promotes = 0       # disk hits copied back into the arena
        self.disk_spills = 0         # arena victims demoted to disk

    # -- disk demotion/promotion (DESIGN.md §16) ----------------------------
    def _spill_to_disk(self, key, arrays) -> None:
        """Arena-eviction hook: demote ``kv``/``rec`` victims to the disk
        tier (chain keys are process-stable ints, so the file outlives this
        engine). ``park`` entries never arrive here — they are pinned."""
        ns, shard, chain_key = key[0], key[1], key[-1]
        if ns not in ("kv", "rec"):
            return
        if self.disk.put(durable_name(ns, shard, chain_key), arrays):
            self.disk_spills += 1

    def _disk_get(self, ns: str, shard: int, key, pin: bool = False):
        """Arena-miss fall-through: verified disk read, promoted back into
        the arena (so the next probe is a memory hit and ``pin`` has an
        entry to hold). Returns the arrays or None."""
        if self.disk is None:
            return None
        arrays = self.disk.get(durable_name(ns, shard, key))
        if arrays is None:
            return None
        self.arena.put((ns, shard, key), arrays, pin=pin)
        self.disk_promotes += 1
        return arrays

    def _disk_has(self, ns: str, shard: int, key) -> bool:
        return (self.disk is not None
                and self.disk.contains(durable_name(ns, shard, key)))

    def flush_to_disk(self, shard: int, keys, ns: str = "kv") -> int:
        """Force-demote still-resident arena entries to disk without
        evicting them (checkpoint boundary: the snapshot references these
        chain keys, so make them durable now, not at some future eviction).
        Returns the number of keys durable on disk afterwards."""
        if self.disk is None:
            return 0
        n = 0
        for key in keys:
            name = durable_name(ns, shard, key)
            if self.disk.contains(name):
                n += 1
                continue
            arrays = self.arena.get((ns, shard, key))
            if arrays is not None and self.disk.put(name, arrays):
                self.disk_spills += 1
                n += 1
        return n

    # -- circuit breaker (DESIGN.md §14) ------------------------------------
    def _allow(self) -> bool:
        return self.breaker is None or self.breaker.allow()

    def record_failure(self):
        """An integrity or staging failure involving this tier."""
        if self.breaker is not None:
            self.breaker.record_failure()

    def _verified(self, arrays):
        """A get that passed the integrity check counts as breaker health."""
        if arrays is not None and self.breaker is not None:
            self.breaker.record_success()
        return arrays

    # -- prefix-spill client ------------------------------------------------
    def put_kv(self, shard: int, key, arrays, pin: bool = False) -> bool:
        if not self._allow():
            return False
        return self.arena.put(("kv", shard, key), arrays, pin=pin)

    def has_kv(self, shard: int, key) -> bool:
        if not self._allow():
            return False
        return (self.arena.contains(("kv", shard, key))
                or self._disk_has("kv", shard, key))

    def get_kv(self, shard: int, key) -> Optional[list]:
        if not self._allow():
            return None
        arrays = self._verified(self.arena.get(("kv", shard, key)))
        if arrays is None:
            arrays = self._disk_get("kv", shard, key)
        return arrays

    def pin_kv(self, shard: int, key) -> bool:
        if not self._allow():
            return False
        if self.arena.pin(("kv", shard, key)):
            return True
        # not in memory: a disk hit is promoted *pinned* so the pin has an
        # arena entry to hold until the owner unpins
        return self._disk_get("kv", shard, key, pin=True) is not None

    def unpin_kv(self, shard: int, key):
        self.arena.unpin(("kv", shard, key))      # never breaker-gated

    def kv_run(self, shard: int, keys) -> int:
        """Longest contiguous resident run of ``keys`` (chained hashes,
        oldest block first). Touches each resident key so a popular prefix
        stays warm. Stops at the first gap — a later resident block is
        useless without its predecessors."""
        if not self._allow():
            return 0
        n = 0
        for k in keys:
            if not (self.arena.contains(("kv", shard, k), touch=True)
                    or self._disk_has("kv", shard, k)):
                break
            n += 1
        return n

    # -- recurrent-snapshot client ------------------------------------------
    def put_rec(self, shard: int, key, arrays) -> bool:
        if not self._allow():
            return False
        return self.arena.put(("rec", shard, key), arrays)

    def has_rec(self, shard: int, key) -> bool:
        if not self._allow():
            return False
        return (self.arena.contains(("rec", shard, key), touch=True)
                or self._disk_has("rec", shard, key))

    def get_rec(self, shard: int, key) -> Optional[list]:
        if not self._allow():
            return None
        arrays = self._verified(self.arena.get(("rec", shard, key)))
        if arrays is None:
            arrays = self._disk_get("rec", shard, key)
        return arrays

    # -- parked-sequence client ---------------------------------------------
    def put_park(self, uid: int, arrays) -> bool:
        if not self._allow():
            return False
        return self.arena.put(("park", uid), arrays, pin=True)

    def take_park(self, uid: int) -> Optional[list]:
        """Consume a parked payload: returns the arrays and removes the
        (pinned) entry — parking is one-shot, resume owns the copy-out.
        None (tripped tier / corrupt entry) sends the caller down the
        cold-resume recompute path."""
        if not self._allow():
            return None
        arrays = self._verified(self.arena.get(("park", uid)))
        if arrays is None:
            return None
        arrays = [a.copy() for a in arrays]      # buffers return to the slab
        self.arena.drop(("park", uid))
        return arrays

    def drop_park(self, uid: int) -> bool:
        """Discard a parked payload without reading it (cancel / failed
        resume)."""
        return self.arena.drop(("park", uid))    # never breaker-gated

    # -- misc ---------------------------------------------------------------
    def stats_export(self) -> dict:
        out = self.arena.stats_export()
        out.update(self.staging.stats_export())
        out.update(self.breaker.stats_export() if self.breaker is not None
                   else {"tier_state": "closed", "tier_tripped": 0,
                         "tier_denied_ops": 0})
        if self.disk is not None:
            out.update(self.disk.stats_export())
            out["disk_promotes"] = self.disk_promotes
            out["disk_spills"] = self.disk_spills
        return out
