"""HostTier: the facade the engine consults between the device prefix
cache and fresh compute (DESIGN.md §13).

One shared :class:`~repro.serving.hostcache.arena.HostArena` (a single
byte budget for the whole process — mesh topologies partition *keys* per
data shard, not bytes, so a hot shard can use headroom an idle shard is
not) serves three clients through namespaced keys:

* ``("kv", shard, chain_key)`` — spilled prefix blocks: the per-layer pool
  rows of one hashed KV block, keyed by the same chained prompt hash
  ``blocks.chain_hashes`` registers on device. Spill writes them on
  BlockManager eviction; ``kv_run`` answers lookup-miss fall-through with
  the longest contiguous resident run so the engine only stages blocks it
  can actually use (chained keys make any resident prefix run valid).
* ``("rec", shard, chain_key)`` — recurrent-state snapshots: a slot's
  ssm/rwkv/hybrid state rows checkpointed at a registerable block
  boundary. Same keying as KV blocks, so a shared system prompt hits for
  recurrent archs exactly where it hits for attention.
* ``("park", uid)`` — a parked sequence's *private* payload (partial
  blocks + live recurrent rows), pinned until resume. The shared hashed
  prefix blocks are NOT duplicated here — they live once in the ``kv``
  namespace, refcount-pinned by each parked victim (satellite: dedup).

All payloads are flat lists of numpy arrays; the engine owns pytree
(de)composition so the tier stays model-agnostic.
"""
from __future__ import annotations

from typing import Optional

from .arena import HostArena
from .staging import StagingRing


class HostTier:
    def __init__(self, capacity_bytes: int, num_shards: int = 1,
                 staging_depth: int = 2):
        self.arena = HostArena(capacity_bytes)
        self.num_shards = num_shards
        self.staging = StagingRing(depth=staging_depth)

    # -- prefix-spill client ------------------------------------------------
    def put_kv(self, shard: int, key, arrays, pin: bool = False) -> bool:
        return self.arena.put(("kv", shard, key), arrays, pin=pin)

    def has_kv(self, shard: int, key) -> bool:
        return self.arena.contains(("kv", shard, key))

    def get_kv(self, shard: int, key) -> Optional[list]:
        return self.arena.get(("kv", shard, key))

    def pin_kv(self, shard: int, key) -> bool:
        return self.arena.pin(("kv", shard, key))

    def unpin_kv(self, shard: int, key):
        self.arena.unpin(("kv", shard, key))

    def kv_run(self, shard: int, keys) -> int:
        """Longest contiguous resident run of ``keys`` (chained hashes,
        oldest block first). Touches each resident key so a popular prefix
        stays warm. Stops at the first gap — a later resident block is
        useless without its predecessors."""
        n = 0
        for k in keys:
            if not self.arena.contains(("kv", shard, k), touch=True):
                break
            n += 1
        return n

    # -- recurrent-snapshot client ------------------------------------------
    def put_rec(self, shard: int, key, arrays) -> bool:
        return self.arena.put(("rec", shard, key), arrays)

    def has_rec(self, shard: int, key) -> bool:
        return self.arena.contains(("rec", shard, key), touch=True)

    def get_rec(self, shard: int, key) -> Optional[list]:
        return self.arena.get(("rec", shard, key))

    # -- parked-sequence client ---------------------------------------------
    def put_park(self, uid: int, arrays) -> bool:
        return self.arena.put(("park", uid), arrays, pin=True)

    def take_park(self, uid: int) -> Optional[list]:
        """Consume a parked payload: returns the arrays and removes the
        (pinned) entry — parking is one-shot, resume owns the copy-out."""
        arrays = self.arena.get(("park", uid))
        if arrays is None:
            return None
        arrays = [a.copy() for a in arrays]      # buffers return to the slab
        self.arena.drop(("park", uid))
        return arrays

    # -- misc ---------------------------------------------------------------
    def stats_export(self) -> dict:
        out = self.arena.stats_export()
        out.update(self.staging.stats_export())
        return out
