"""Async H2D staging ring for host-tier re-admission (DESIGN.md §13).

``jax.device_put`` is asynchronous: it returns a ``jax.Array`` immediately
and the copy proceeds in the background. The ring exploits that to overlap
host-tier uploads with the prefill chunks the engine is already paying for
a new admission: stage block ``k+1`` while block ``k``'s merge (or the next
prefill chunk) is executing, bounded to ``depth`` in-flight uploads so host
pressure cannot pile up unbounded device allocations.

The ring also measures how much overlap it actually got: an upload counts
as *overlapped* when, at issue time, the previously staged array had not
yet landed (``not is_ready()``) — i.e. the copy engine was still busy and
this dispatch queued behind useful work instead of blocking the host. The
exported ``h2d_overlap_frac`` is the serving-bench "H2D overlap fraction".

Buffers returned by ``take()`` are plain device arrays; the engine merges
them into the pool with the same ``.at[gids].set`` pattern the resume path
uses, so nothing here touches the verify-round jaxpr.
"""
from __future__ import annotations

from collections import deque

import jax
import numpy as np

from repro.serving.faults import StagingFault


class StagingRing:
    """Depth-bounded asynchronous host->device upload ring.

    ``faults`` wires the ``stage_drop`` seam: an injected fault raises
    :class:`~repro.serving.faults.StagingFault` from ``stage`` exactly as a
    died H2D upload would. The caller's recovery contract (DESIGN.md §14)
    is ``clear()``: discard everything in flight so the next caller cannot
    take a previous admission's half-staged blocks."""

    def __init__(self, depth: int = 2, faults=None):
        assert depth >= 1, depth
        self.depth = depth
        self.faults = faults
        self._ring: deque = deque()          # in flight: (tag, [jax.Array])
        self._landed: deque = deque()        # drained, awaiting take()
        self.staged = 0                      # uploads issued
        self.staged_bytes = 0
        self.overlapped = 0                  # issued while ring was busy
        self.dropped = 0                     # uploads discarded by clear()
        self._last: "jax.Array | None" = None

    def _busy(self) -> bool:
        return self._last is not None and not self._last.is_ready()

    def stage(self, tag, arrays) -> None:
        """Dispatch async uploads of ``arrays`` (numpy) under ``tag``.
        Blocks only when the ring is full (depth uploads in flight); the
        upload it waits for moves to the landed queue, never dropped."""
        if self.faults is not None and self.faults.fire("stage_drop"):
            raise StagingFault(f"injected staging drop at {tag!r}")
        while len(self._ring) >= self.depth:
            self._landed.append(self._drain_one())
        if self._busy():
            self.overlapped += 1
        devs = [jax.device_put(np.asarray(a)) for a in arrays]
        self.staged += 1
        self.staged_bytes += int(sum(a.nbytes for a in arrays))
        if devs:
            self._last = devs[-1]
        self._ring.append((tag, devs))

    def _drain_one(self):
        tag, devs = self._ring.popleft()
        for d in devs:
            d.block_until_ready()
        return (tag, devs)

    def take(self):
        """Pop the oldest staged upload as ``(tag, [device arrays])``,
        waiting for it to land. Returns None when nothing is staged."""
        if self._landed:
            return self._landed.popleft()
        if not self._ring:
            return None
        return self._drain_one()

    def clear(self) -> int:
        """Discard every in-flight and landed upload (partial-failure
        recovery, DESIGN.md §14): a caller that aborts mid-ring MUST clear,
        or the next admission would ``take()`` block payloads staged for a
        different slot's table. Returns the number of uploads dropped."""
        n = len(self)
        self._ring.clear()
        self._landed.clear()
        self._last = None
        self.dropped += n
        return n

    def __len__(self) -> int:
        return len(self._ring) + len(self._landed)

    def stats_export(self) -> dict:
        frac = self.overlapped / self.staged if self.staged else 0.0
        return {
            "h2d_staged": self.staged,
            "h2d_staged_bytes": self.staged_bytes,
            "h2d_overlapped": self.overlapped,
            "h2d_overlap_frac": frac,
            "h2d_dropped": self.dropped,
        }
