"""Durable disk tier below the host arena (DESIGN.md §16).

The arena's LRU victims — spilled prefix blocks and recurrent-state
snapshots, keyed by the same chained content hashes the device prefix
cache registers — land here as crc32-framed files instead of vanishing, so
``lookup_prefix_tiered`` falls through arena -> disk -> recompute and a
*restarted* engine re-hits the prefixes a dead process computed. Chain
keys survive restarts by construction: ``blocks.chain_hashes`` hashes
tuples of ints, which Python hashes deterministically across processes
(only str/bytes hashing is PYTHONHASHSEED-salted).

File format (one entry per file, named by the caller's durable key)::

    b"RDT1" | u32 crc32(payload) | u64 len(payload) | payload
    payload = u32 n_arrays, then per array:
              u16 len(dtype_str) | dtype_str | u8 ndim | u32 dims... | bytes

Durability discipline:

* **Atomic visibility.** Every put writes ``<name>.tmp``, flushes, fsyncs,
  then renames over the final path — a reader (or a restarted process)
  only ever sees complete frames or nothing; ``.tmp`` orphans from a crash
  are swept at startup. The ``mid_spill`` kill point sits between the tmp
  write and the rename: a process killed there leaves only the orphan.
* **Byte-budgeted LRU.** ``capacity_bytes`` bounds the directory;
  admission evicts oldest-touch entries first. The index is in-memory and
  rebuilt at startup from a directory scan in mtime order (approximate LRU
  across restarts — exactness never depends on it).
* **Verified reads.** Every get re-checks the crc; a mismatch (torn write
  that still got renamed by an injected ``disk_torn_write``, bit rot)
  deletes the file and reports a miss — corrupt bytes never reach the
  caller, exactly the arena's §14 demotion contract.
* **Breaker-isolated.** The tier sits behind its own
  :class:`~repro.serving.faults.CircuitBreaker`: ENOSPC (or the injected
  ``disk_full`` seam), repeated checksum failures, any OSError — all
  degrade the engine to host-only caching, never to an error.
"""
from __future__ import annotations

import os
import struct
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.serving.faults import CircuitBreaker, kill_point

_MAGIC = b"RDT1"
_HEADER = struct.Struct("<4sIQ")      # magic, crc32, payload length


def encode_entry(arrays) -> bytes:
    """Frame a flat list of ndarrays as one crc-checked payload."""
    parts = [struct.pack("<I", len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        dt = a.dtype.str.encode()
        parts.append(struct.pack("<H", len(dt)) + dt)
        parts.append(struct.pack("<B", a.ndim)
                     + struct.pack(f"<{a.ndim}I", *a.shape))
        parts.append(a.tobytes())
    payload = b"".join(parts)
    return _HEADER.pack(_MAGIC, zlib.crc32(payload), len(payload)) + payload


def decode_entry(buf: bytes) -> Optional[list]:
    """Parse a framed entry; None on any inconsistency (torn/corrupt)."""
    if len(buf) < _HEADER.size:
        return None
    magic, crc, plen = _HEADER.unpack_from(buf)
    payload = buf[_HEADER.size:]
    if magic != _MAGIC or len(payload) != plen or zlib.crc32(payload) != crc:
        return None
    try:
        off = 4
        (n,) = struct.unpack_from("<I", payload)
        out = []
        for _ in range(n):
            (dlen,) = struct.unpack_from("<H", payload, off)
            off += 2
            dt = np.dtype(payload[off:off + dlen].decode())
            off += dlen
            (ndim,) = struct.unpack_from("<B", payload, off)
            off += 1
            shape = struct.unpack_from(f"<{ndim}I", payload, off)
            off += 4 * ndim
            nb = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
            a = np.frombuffer(payload[off:off + nb], dt).reshape(shape)
            off += nb
            out.append(a)
        return out if off == len(payload) else None
    except (struct.error, ValueError, UnicodeDecodeError):
        return None


def durable_name(namespace: str, shard: int, key: int) -> str:
    """Filesystem name of one namespaced chain key. The key is an int
    (chained tuple hash — process-stable); masking to 64 bits keeps the
    name fixed-width and is injective over Python's +-2**61 hash range."""
    return f"{namespace}_{shard}_{key & 0xFFFFFFFFFFFFFFFF:016x}.blk"


@dataclass
class DiskStats:
    puts: int = 0                # entries admitted (file renamed into place)
    dedup_hits: int = 0          # puts of an already-resident name
    hits: int = 0                # gets that returned verified arrays
    misses: int = 0              # gets/probes that found nothing
    evictions: int = 0           # LRU files deleted for space
    rejections: int = 0          # puts refused (budget / breaker / ENOSPC)
    checksum_failures: int = 0   # reads whose crc verify failed (file
    #                              deleted, demoted to a miss — §14)
    orphans_swept: int = 0       # crash-leftover .tmp files removed at boot
    bytes_written: int = 0       # payload bytes fsynced to disk


class DiskTier:
    """Byte-budgeted directory of crc-framed spill files with LRU eviction,
    behind its own circuit breaker. All methods are total: every failure
    path (ENOSPC, torn frame, unreadable directory) is a miss or a refused
    put, never an exception — a dead disk degrades the engine to host-only
    caching."""

    def __init__(self, root: str, capacity_bytes: int = 1 << 30, *,
                 faults=None, breaker: Optional[CircuitBreaker] = None,
                 fsync: bool = True):
        assert capacity_bytes >= 0, capacity_bytes
        self.root = root
        self.capacity_bytes = int(capacity_bytes)
        self.faults = faults
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.fsync = fsync
        self.stats = DiskStats()
        # name -> file size; insertion/touch order IS the LRU order
        self._index: "OrderedDict[str, int]" = OrderedDict()
        self.bytes_resident = 0
        os.makedirs(root, exist_ok=True)
        self._rebuild_index()

    def _rebuild_index(self):
        """Startup scan: sweep crash orphans, index entries in mtime order
        (the best cross-restart LRU approximation the filesystem keeps)."""
        entries = []
        try:
            for name in os.listdir(self.root):
                path = os.path.join(self.root, name)
                if name.endswith(".tmp"):
                    try:
                        os.unlink(path)
                        self.stats.orphans_swept += 1
                    except OSError:
                        pass
                    continue
                if not name.endswith(".blk"):
                    continue
                try:
                    st = os.stat(path)
                except OSError:
                    continue
                entries.append((st.st_mtime, name, st.st_size))
        except OSError:
            return
        for _, name, size in sorted(entries):
            self._index[name] = size
            self.bytes_resident += size

    # -- breaker --------------------------------------------------------------
    def _allow(self) -> bool:
        return self.breaker.allow()

    def _fail(self):
        self.breaker.record_failure()

    # -- capacity -------------------------------------------------------------
    def _evict_for(self, want: int) -> bool:
        if want > self.capacity_bytes:
            return False
        while self._index and self.bytes_resident + want > self.capacity_bytes:
            name, size = self._index.popitem(last=False)     # oldest touch
            self.bytes_resident -= size
            try:
                os.unlink(os.path.join(self.root, name))
            except OSError:
                pass
            self.stats.evictions += 1
        return self.bytes_resident + want <= self.capacity_bytes

    def _forget(self, name: str):
        size = self._index.pop(name, None)
        if size is not None:
            self.bytes_resident -= size
        try:
            os.unlink(os.path.join(self.root, name))
        except OSError:
            pass

    # -- entry API ------------------------------------------------------------
    def contains(self, name: str) -> bool:
        """Presence probe (no accounting, no touch — planning passes)."""
        return self.breaker.state != "open" and name in self._index

    def put(self, name: str, arrays) -> bool:
        """Spill ``arrays`` under ``name``: frame, write a temp file,
        flush+fsync, rename into place. False — never an exception — when
        the budget, the breaker, an injected ``disk_full``, or a real
        OSError refuses it."""
        if not self._allow():
            self.stats.rejections += 1
            return False
        if name in self._index:
            self._index.move_to_end(name)
            self.stats.dedup_hits += 1
            self.breaker.record_success()
            return True
        frame = encode_entry(arrays)
        if self.faults is not None and self.faults.fire("disk_full"):
            self.stats.rejections += 1
            self._fail()                 # injected ENOSPC: breaker failure
            return False
        if not self._evict_for(len(frame)):
            self.stats.rejections += 1
            return False
        torn = (self.faults is not None
                and self.faults.fire("disk_torn_write"))
        path = os.path.join(self.root, name)
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                # a torn write is a crash mid-frame that still reached the
                # final name: half the frame, so the crc verify at the next
                # get (or the restarted process's) demotes it to a miss
                f.write(frame[:len(frame) // 2] if torn else frame)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
            kill_point("mid_spill")
            os.rename(tmp, path)
        except OSError:
            self.stats.rejections += 1
            try:
                os.unlink(tmp)
            except OSError:
                pass
            self._fail()
            return False
        size = len(frame) // 2 if torn else len(frame)
        self._index[name] = size
        self.bytes_resident += size
        self.stats.puts += 1
        self.stats.bytes_written += size
        self.breaker.record_success()
        return True

    def get(self, name: str) -> Optional[list]:
        """Verified read. A frame that fails the crc (torn write, bit rot)
        is deleted, counted, and reported as a miss; repeated failures trip
        the breaker (§14) so a rotting disk stops being consulted."""
        if not self._allow():
            self.stats.misses += 1
            return None
        if name not in self._index:
            self.stats.misses += 1
            return None
        if self.faults is not None and self.faults.fire("disk_slow"):
            time.sleep(0.002)            # degraded device: latency, no error
        try:
            with open(os.path.join(self.root, name), "rb") as f:
                buf = f.read()
        except OSError:
            self._forget(name)
            self.stats.misses += 1
            self._fail()
            return None
        arrays = decode_entry(buf)
        if arrays is None:
            self._forget(name)
            self.stats.checksum_failures += 1
            self.stats.misses += 1
            self._fail()
            return None
        self._index.move_to_end(name)
        self.stats.hits += 1
        self.breaker.record_success()
        return arrays

    def drop(self, name: str) -> bool:
        """Remove an entry outright (never breaker-gated — hygiene must run
        even while tripped, like the arena's ``drop``/``unpin``)."""
        if name not in self._index:
            return False
        self._forget(name)
        return True

    def __len__(self) -> int:
        return len(self._index)

    def stats_export(self) -> dict:
        out = {
            "disk_puts": self.stats.puts,
            "disk_dedup_hits": self.stats.dedup_hits,
            "disk_hits": self.stats.hits,
            "disk_misses": self.stats.misses,
            "disk_evictions": self.stats.evictions,
            "disk_rejections": self.stats.rejections,
            "disk_checksum_failures": self.stats.checksum_failures,
            "disk_orphans_swept": self.stats.orphans_swept,
            "disk_bytes_written": self.stats.bytes_written,
            "disk_bytes_resident": self.bytes_resident,
            "disk_bytes_capacity": self.capacity_bytes,
            "disk_entries": len(self._index),
        }
        out.update(self.breaker.stats_export(prefix="disk"))
        return out
