"""Host-memory cache tier: bounded LRU arena + staging + HostTier facade.

See DESIGN.md §13. The tier sits between the device prefix cache and
fresh prefill compute: spilled KV blocks, parked-sequence payloads, and
recurrent-state snapshots share one byte-budgeted arena. Below it,
``DiskTier`` (§16) makes arena LRU victims durable: crc-framed files keyed
by the same chain hashes, so prefixes survive engine restarts.
"""
from .arena import ArenaStats, HostArena
from .disk import DiskTier, durable_name
from .staging import StagingRing
from .tier import HostTier

__all__ = ["ArenaStats", "HostArena", "StagingRing", "HostTier",
           "DiskTier", "durable_name"]
