"""Host-memory cache tier: bounded LRU arena + staging + HostTier facade.

See DESIGN.md §13. The tier sits between the device prefix cache and
fresh prefill compute: spilled KV blocks, parked-sequence payloads, and
recurrent-state snapshots share one byte-budgeted arena.
"""
from .arena import ArenaStats, HostArena
from .staging import StagingRing
from .tier import HostTier

__all__ = ["ArenaStats", "HostArena", "StagingRing", "HostTier"]
