"""Bounded host-memory arena: slab-recycled buffers, refcount pinning,
strict LRU eviction (DESIGN.md §13).

The arena is the single byte-budgeted store under the host cache tier
(``tier.HostTier``). An *entry* is a flat list of numpy arrays under one
hashable key — a spilled prefix block's pool rows, a parked sequence's
private payload, or a recurrent-state snapshot. The arena never interprets
the arrays; clients own the keying and the (de)composition.

Invariants:

* **Bounded.** ``bytes_resident + bytes_slab <= capacity_bytes`` always.
  A ``put`` that cannot fit after evicting every unpinned entry is
  *rejected* (returns False, counted) — the caller falls back to dropping
  the data or keeping it outside the arena; the arena never grows past its
  budget and never throws on pressure.
* **Slab allocation per block shape.** Evicted entries donate their
  buffers to per-``(shape, dtype)`` free lists instead of returning them
  to the allocator; a later ``put`` of the same shape copies into a
  recycled slab (serving traffic is dominated by a handful of block
  shapes, so steady-state spill traffic allocates nothing). Slab bytes
  count against the budget and are trimmed first under pressure.
* **Refcount pinning.** ``refs > 0`` entries (parked payloads, prefix
  blocks a parked sequence depends on, entries mid-staging) are exempt
  from eviction. Pins are explicit (``pin``/``unpin`` or the ``pin=``
  flags); a pinned ``put`` still respects the budget.
* **Strict LRU.** Unpinned entries are evicted oldest-touch first; every
  ``get`` hit and dedup ``put`` refreshes recency.
* **Integrity-checked (DESIGN.md §14).** Every put stamps a crc32 over the
  entry's bytes; every get re-verifies it. A mismatch (bit rot, a buggy
  slab recycle, an injected ``arena_corrupt`` fault) is demoted to a cache
  miss: the entry is dropped — pinned or not; a corrupt pin protects
  nothing — ``checksum_failures`` counts it, and the ``on_corruption``
  callback lets the tier's circuit breaker see repeated failures. The
  engine then recomputes (re-prefill / cold resume); corruption is never
  returned to a caller. ``integrity=False`` (--no-integrity-checks) skips
  the stamp+verify for A/B measurement of its host-path cost.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def _nbytes(arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


def _checksum(arrays) -> int:
    """crc32 over the concatenated bytes of a flat ndarray list."""
    c = 0
    for a in arrays:
        if a.size:
            c = zlib.crc32(memoryview(np.ascontiguousarray(a)).cast("B"), c)
    return c


@dataclass
class ArenaStats:
    hits: int = 0                # get() found the key
    misses: int = 0              # get()/contains probe found nothing
    puts: int = 0                # new entries admitted
    dedup_hits: int = 0          # put() of an already-resident key
    evictions: int = 0           # LRU entries dropped for space
    rejections: int = 0          # puts refused (budget/pins)
    slab_reuses: int = 0         # buffers recycled from the slab pool
    bytes_in: int = 0            # payload bytes copied into the arena
    checksum_failures: int = 0   # gets whose crc32 verify failed (entry
    #                              dropped, demoted to a miss — §14)

    def export(self, arena: "HostArena") -> dict:
        return {
            "checksum_failures": self.checksum_failures,
            "host_hits": self.hits,
            "host_misses": self.misses,
            "host_puts": self.puts,
            "host_dedup_hits": self.dedup_hits,
            "host_evictions": self.evictions,
            "host_rejections": self.rejections,
            "host_slab_reuses": self.slab_reuses,
            "host_bytes_in": self.bytes_in,
            "host_bytes_resident": arena.bytes_resident,
            "host_bytes_slab": arena.bytes_slab,
            "host_bytes_capacity": arena.capacity_bytes,
            "host_entries": len(arena._entries),
            "host_entries_pinned": sum(
                1 for e in arena._entries.values() if e.refs > 0),
        }


@dataclass
class _Entry:
    arrays: list
    nbytes: int
    refs: int = 0
    crc: int = 0                 # crc32 stamped at put (0 when unchecked)


class HostArena:
    """Fixed-budget key -> list-of-ndarray store with LRU + pinning.

    ``integrity`` stamps/verifies crc32 checksums (DESIGN.md §14);
    ``faults`` is an optional :class:`~repro.serving.faults.FaultPlan`
    wired to the ``arena_put`` / ``arena_corrupt`` seams; ``on_corruption``
    is called (with the key) whenever a verify fails — the host tier points
    it at its circuit breaker; ``on_evict`` is called with ``(key,
    arrays)`` for every LRU victim *before* its buffers are recycled — the
    tier points it at the disk spill (DESIGN.md §16), so an evicted entry's
    bytes are still intact when the demotion hook sees them."""

    def __init__(self, capacity_bytes: int, *, integrity: bool = True,
                 faults=None, on_corruption=None, on_evict=None):
        assert capacity_bytes >= 0, capacity_bytes
        self.capacity_bytes = int(capacity_bytes)
        self.integrity = integrity
        self.faults = faults
        self.on_corruption = on_corruption
        self.on_evict = on_evict
        # insertion/touch order IS the LRU order (oldest first)
        self._entries: "OrderedDict[object, _Entry]" = OrderedDict()
        self._slab: dict[tuple, list] = {}       # (shape, dtype) -> buffers
        self.bytes_resident = 0
        self.bytes_slab = 0
        self.stats = ArenaStats()

    # -- slab pool ----------------------------------------------------------
    def _slab_key(self, a: np.ndarray) -> tuple:
        return (a.shape, a.dtype.str)

    def _slab_take(self, src: np.ndarray) -> np.ndarray:
        free = self._slab.get(self._slab_key(src))
        if free:
            buf = free.pop()
            self.bytes_slab -= buf.nbytes
            self.stats.slab_reuses += 1
        else:
            buf = np.empty_like(src)
        np.copyto(buf, src)
        return buf

    def _slab_give(self, arrays):
        for a in arrays:
            self._slab.setdefault(self._slab_key(a), []).append(a)
            self.bytes_slab += a.nbytes

    def _trim_slab(self, want: int):
        """Drop slab buffers (any shape, arbitrary order) until ``want``
        bytes fit alongside the resident set."""
        for key in list(self._slab):
            free = self._slab[key]
            while free and self._free_bytes() < want:
                self.bytes_slab -= free.pop().nbytes
            if not free:
                del self._slab[key]
            if self._free_bytes() >= want:
                return

    # -- capacity -----------------------------------------------------------
    def _free_bytes(self) -> int:
        return self.capacity_bytes - self.bytes_resident - self.bytes_slab

    def _evict_for(self, want: int) -> bool:
        """Make room for ``want`` payload bytes: trim slab first (pure
        bookkeeping), then evict unpinned entries strictly oldest-first.
        Returns False if even a full sweep cannot free enough."""
        if want > self.capacity_bytes:
            return False
        self._trim_slab(want)
        if self._free_bytes() >= want:
            return True
        for key in list(self._entries):
            e = self._entries[key]
            if e.refs > 0:
                continue
            del self._entries[key]
            self.bytes_resident -= e.nbytes
            if self.on_evict is not None:
                self.on_evict(key, e.arrays)
            self._slab_give(e.arrays)
            self.stats.evictions += 1
            self._trim_slab(want)
            if self._free_bytes() >= want:
                return True
        self._trim_slab(want)
        return self._free_bytes() >= want

    # -- entry API ----------------------------------------------------------
    def contains(self, key, touch: bool = False) -> bool:
        """Presence probe with NO hit/miss accounting (planning passes use
        it to size an admission before committing to it)."""
        if key in self._entries:
            if touch:
                self._entries.move_to_end(key)
            return True
        return False

    def put(self, key, arrays, pin: bool = False) -> bool:
        """Copy ``arrays`` (a flat list of ndarrays) into the arena under
        ``key``. Duplicate keys are a *dedup hit*: the resident entry is
        kept (contents are content-addressed by construction), refreshed,
        and optionally pinned — nothing is copied twice. Returns False iff
        the arena cannot make room (entry never partially admitted) or an
        injected ``arena_put`` fault rejects it."""
        if self.faults is not None and self.faults.fire("arena_put"):
            self.stats.rejections += 1         # as if the host alloc failed
            return False
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            if pin:
                e.refs += 1
            self.stats.dedup_hits += 1
            return True
        arrays = [np.asarray(a) for a in arrays]
        want = _nbytes(arrays)
        if not self._evict_for(want):
            self.stats.rejections += 1
            return False
        copies = [self._slab_take(a) for a in arrays]
        self._entries[key] = _Entry(copies, want, refs=1 if pin else 0,
                                    crc=_checksum(copies) if self.integrity
                                    else 0)
        self.bytes_resident += want
        self.stats.puts += 1
        self.stats.bytes_in += want
        return True

    def get(self, key, pin: bool = False) -> Optional[list]:
        """LRU-refreshing lookup. Returns the entry's arrays (the arena's
        own buffers — callers must not mutate them) or None. The stored
        checksum is re-verified first (DESIGN.md §14): a mismatch drops the
        entry — pinned or not — counts ``checksum_failures``, notifies
        ``on_corruption``, and reports a miss, so corrupt bytes never reach
        the device."""
        e = self._entries.get(key)
        if e is None:
            self.stats.misses += 1
            return None
        if self.faults is not None and self.faults.fire("arena_corrupt"):
            self._corrupt(e)
        if self.integrity and e.crc != _checksum(e.arrays):
            self.stats.checksum_failures += 1
            self.stats.misses += 1
            self.drop(key)
            if self.on_corruption is not None:
                self.on_corruption(key)
            return None
        self._entries.move_to_end(key)
        if pin:
            e.refs += 1
        self.stats.hits += 1
        return e.arrays

    @staticmethod
    def _corrupt(e: _Entry):
        """Injected-fault seam: flip one byte of the stored entry in place
        (the integrity verify on the same get must catch it)."""
        for a in e.arrays:
            if a.size:               # stored arrays are contiguous slab copies
                a.view(np.uint8).flat[0] ^= 0xFF
                return

    def pin(self, key) -> bool:
        e = self._entries.get(key)
        if e is None:
            return False
        e.refs += 1
        return True

    def unpin(self, key):
        """Drop one pin. Tolerant of a missing entry: integrity failures
        drop corrupt entries even while pinned, and the pin owner still
        unpins on its normal path afterwards (§14)."""
        e = self._entries.get(key)
        if e is None or e.refs <= 0:
            return
        e.refs -= 1

    def drop(self, key) -> bool:
        """Remove an entry outright (e.g. a consumed parked payload); its
        buffers go to the slab pool. Pinned entries may be dropped — the
        owner of the last pin is the one calling."""
        e = self._entries.pop(key, None)
        if e is None:
            return False
        self.bytes_resident -= e.nbytes
        self._slab_give(e.arrays)
        return True

    def __len__(self) -> int:
        return len(self._entries)

    def stats_export(self) -> dict:
        return self.stats.export(self)
