"""Bounded host-memory arena: slab-recycled buffers, refcount pinning,
strict LRU eviction (DESIGN.md §13).

The arena is the single byte-budgeted store under the host cache tier
(``tier.HostTier``). An *entry* is a flat list of numpy arrays under one
hashable key — a spilled prefix block's pool rows, a parked sequence's
private payload, or a recurrent-state snapshot. The arena never interprets
the arrays; clients own the keying and the (de)composition.

Invariants:

* **Bounded.** ``bytes_resident + bytes_slab <= capacity_bytes`` always.
  A ``put`` that cannot fit after evicting every unpinned entry is
  *rejected* (returns False, counted) — the caller falls back to dropping
  the data or keeping it outside the arena; the arena never grows past its
  budget and never throws on pressure.
* **Slab allocation per block shape.** Evicted entries donate their
  buffers to per-``(shape, dtype)`` free lists instead of returning them
  to the allocator; a later ``put`` of the same shape copies into a
  recycled slab (serving traffic is dominated by a handful of block
  shapes, so steady-state spill traffic allocates nothing). Slab bytes
  count against the budget and are trimmed first under pressure.
* **Refcount pinning.** ``refs > 0`` entries (parked payloads, prefix
  blocks a parked sequence depends on, entries mid-staging) are exempt
  from eviction. Pins are explicit (``pin``/``unpin`` or the ``pin=``
  flags); a pinned ``put`` still respects the budget.
* **Strict LRU.** Unpinned entries are evicted oldest-touch first; every
  ``get`` hit and dedup ``put`` refreshes recency.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def _nbytes(arrays) -> int:
    return int(sum(a.nbytes for a in arrays))


@dataclass
class ArenaStats:
    hits: int = 0                # get() found the key
    misses: int = 0              # get()/contains probe found nothing
    puts: int = 0                # new entries admitted
    dedup_hits: int = 0          # put() of an already-resident key
    evictions: int = 0           # LRU entries dropped for space
    rejections: int = 0          # puts refused (budget/pins)
    slab_reuses: int = 0         # buffers recycled from the slab pool
    bytes_in: int = 0            # payload bytes copied into the arena

    def export(self, arena: "HostArena") -> dict:
        return {
            "host_hits": self.hits,
            "host_misses": self.misses,
            "host_puts": self.puts,
            "host_dedup_hits": self.dedup_hits,
            "host_evictions": self.evictions,
            "host_rejections": self.rejections,
            "host_slab_reuses": self.slab_reuses,
            "host_bytes_in": self.bytes_in,
            "host_bytes_resident": arena.bytes_resident,
            "host_bytes_slab": arena.bytes_slab,
            "host_bytes_capacity": arena.capacity_bytes,
            "host_entries": len(arena._entries),
            "host_entries_pinned": sum(
                1 for e in arena._entries.values() if e.refs > 0),
        }


@dataclass
class _Entry:
    arrays: list
    nbytes: int
    refs: int = 0


class HostArena:
    """Fixed-budget key -> list-of-ndarray store with LRU + pinning."""

    def __init__(self, capacity_bytes: int):
        assert capacity_bytes >= 0, capacity_bytes
        self.capacity_bytes = int(capacity_bytes)
        # insertion/touch order IS the LRU order (oldest first)
        self._entries: "OrderedDict[object, _Entry]" = OrderedDict()
        self._slab: dict[tuple, list] = {}       # (shape, dtype) -> buffers
        self.bytes_resident = 0
        self.bytes_slab = 0
        self.stats = ArenaStats()

    # -- slab pool ----------------------------------------------------------
    def _slab_key(self, a: np.ndarray) -> tuple:
        return (a.shape, a.dtype.str)

    def _slab_take(self, src: np.ndarray) -> np.ndarray:
        free = self._slab.get(self._slab_key(src))
        if free:
            buf = free.pop()
            self.bytes_slab -= buf.nbytes
            self.stats.slab_reuses += 1
        else:
            buf = np.empty_like(src)
        np.copyto(buf, src)
        return buf

    def _slab_give(self, arrays):
        for a in arrays:
            self._slab.setdefault(self._slab_key(a), []).append(a)
            self.bytes_slab += a.nbytes

    def _trim_slab(self, want: int):
        """Drop slab buffers (any shape, arbitrary order) until ``want``
        bytes fit alongside the resident set."""
        for key in list(self._slab):
            free = self._slab[key]
            while free and self._free_bytes() < want:
                self.bytes_slab -= free.pop().nbytes
            if not free:
                del self._slab[key]
            if self._free_bytes() >= want:
                return

    # -- capacity -----------------------------------------------------------
    def _free_bytes(self) -> int:
        return self.capacity_bytes - self.bytes_resident - self.bytes_slab

    def _evict_for(self, want: int) -> bool:
        """Make room for ``want`` payload bytes: trim slab first (pure
        bookkeeping), then evict unpinned entries strictly oldest-first.
        Returns False if even a full sweep cannot free enough."""
        if want > self.capacity_bytes:
            return False
        self._trim_slab(want)
        if self._free_bytes() >= want:
            return True
        for key in list(self._entries):
            e = self._entries[key]
            if e.refs > 0:
                continue
            del self._entries[key]
            self.bytes_resident -= e.nbytes
            self._slab_give(e.arrays)
            self.stats.evictions += 1
            self._trim_slab(want)
            if self._free_bytes() >= want:
                return True
        self._trim_slab(want)
        return self._free_bytes() >= want

    # -- entry API ----------------------------------------------------------
    def contains(self, key, touch: bool = False) -> bool:
        """Presence probe with NO hit/miss accounting (planning passes use
        it to size an admission before committing to it)."""
        if key in self._entries:
            if touch:
                self._entries.move_to_end(key)
            return True
        return False

    def put(self, key, arrays, pin: bool = False) -> bool:
        """Copy ``arrays`` (a flat list of ndarrays) into the arena under
        ``key``. Duplicate keys are a *dedup hit*: the resident entry is
        kept (contents are content-addressed by construction), refreshed,
        and optionally pinned — nothing is copied twice. Returns False iff
        the arena cannot make room (entry never partially admitted)."""
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            if pin:
                e.refs += 1
            self.stats.dedup_hits += 1
            return True
        arrays = [np.asarray(a) for a in arrays]
        want = _nbytes(arrays)
        if not self._evict_for(want):
            self.stats.rejections += 1
            return False
        self._entries[key] = _Entry([self._slab_take(a) for a in arrays],
                                    want, refs=1 if pin else 0)
        self.bytes_resident += want
        self.stats.puts += 1
        self.stats.bytes_in += want
        return True

    def get(self, key, pin: bool = False) -> Optional[list]:
        """LRU-refreshing lookup. Returns the entry's arrays (the arena's
        own buffers — callers must not mutate them) or None."""
        e = self._entries.get(key)
        if e is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        if pin:
            e.refs += 1
        self.stats.hits += 1
        return e.arrays

    def pin(self, key) -> bool:
        e = self._entries.get(key)
        if e is None:
            return False
        e.refs += 1
        return True

    def unpin(self, key):
        e = self._entries.get(key)
        assert e is not None and e.refs > 0, f"unpin of unpinned key {key!r}"
        e.refs -= 1

    def drop(self, key) -> bool:
        """Remove an entry outright (e.g. a consumed parked payload); its
        buffers go to the slab pool. Pinned entries may be dropped — the
        owner of the last pin is the one calling."""
        e = self._entries.pop(key, None)
        if e is None:
            return False
        self.bytes_resident -= e.nbytes
        self._slab_give(e.arrays)
        return True

    def __len__(self) -> int:
        return len(self._entries)

    def stats_export(self) -> dict:
        return self.stats.export(self)
