"""Acceptance-driven adaptive speculation window (DESIGN.md §7).

The verify window W is the engine's one speculation knob: each round costs
one ARM pass over W positions and yields ``a in [1, W]`` accepted tokens.
On weakly-coupled (repetitive) streams acceptance saturates the window and a
deep W amortizes the pass over many tokens; on strongly-coupled streams
acceptance hugs 1 and every extra slot is wasted compute (the paper's §2.4
cascading-errors regime). Wiggers & Hoogeboom fix W offline; Yoo et al.'s
confidence-guided sampling (PAPERS.md) motivates adapting depth online — and
since predictive sampling's acceptance is *exact* (not a heuristic draft
score), the observed accept length is the natural control signal.

The controller tracks an EWMA of per-round mean accept lengths and proposes
``W = clip(round(headroom * ewma), 1, w_max)`` quantized to powers of two, so
a serving engine compiles at most ``log2(w_max) + 1`` round shapes. Hysteresis
(a proposal must repeat ``patience`` rounds before adoption) keeps the window
from thrashing between adjacent shapes. Exactness is indifferent to W —
candidates gate only acceptance, never token values — so the controller can
retune freely mid-request.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


def _pow2_at_most(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


@dataclass
class AdaptiveWindowController:
    w_max: int = 16
    w_init: int = 0              # 0 -> start at w_max (optimistic)
    alpha: float = 0.3           # EWMA weight of the newest observation
    headroom: float = 1.7        # W targets headroom * expected accept
    patience: int = 2            # rounds a proposal must persist
    enabled: bool = True
    history_cap: int = 4096      # telemetry ring bound (a long-lived server
    #                              syncs millions of times; never leak)

    def __post_init__(self):
        assert self.w_max >= 1
        assert self.history_cap >= 1
        if self.w_init <= 0:
            self._w = self.w_max       # optimistic start at the bound
        else:
            # pin to the grid: pow2 rungs plus w_max itself
            w = min(self.w_init, self.w_max)
            self._w = w if w == self.w_max else _pow2_at_most(w)
        self._ewma = float(self._w)   # optimistic: assume the window fills
        self._pending = self._w
        self._streak = 0
        self.history: deque[int] = deque(maxlen=self.history_cap)

    @property
    def window(self) -> int:
        return self._w

    @property
    def ewma_accept(self) -> float:
        return self._ewma

    def observe(self, accepts) -> int:
        """Feed one round's accept lengths (active rows only); returns the
        window to use next round."""
        accepts = np.asarray(accepts, np.float64)
        return self.observe_aggregate(float(accepts.sum()),
                                      int(accepts.size))

    def observe_aggregate(self, accepted_total: float,
                          active_row_rounds: int) -> int:
        """Feed a device-resident loop's aggregated stats: total tokens
        accepted over the loop and the number of (row, round) pairs that
        were active. The EWMA advances once per host sync with the loop-mean
        accept length (the loop runs at fixed W, so per-round feedback could
        not have retuned mid-loop anyway — the retune boundary IS the sync);
        hysteresis ``patience`` therefore counts host syncs. Returns the
        window to use for the next loop."""
        self.history.append(self._w)
        if not self.enabled or active_row_rounds <= 0:
            return self._w
        mean = float(accepted_total) / float(active_row_rounds)
        self._ewma += self.alpha * (mean - self._ewma)
        want = int(np.clip(round(self.headroom * self._ewma), 1, self.w_max))
        # quantize to the pow2 grid (plus w_max itself as the top rung),
        # rounding up: the next rung above a pow2 is its double, capped at
        # the w_max rung itself
        prop = _pow2_at_most(want)
        if want > prop:
            prop = min(prop * 2, self.w_max)
        if prop == self._pending:
            self._streak += 1
        else:
            self._pending, self._streak = prop, 1
        if self._streak >= self.patience and prop != self._w:
            self._w = prop
        return self._w


@dataclass
class RoundsPerSyncController:
    """Adaptive ``rounds_per_sync`` (DESIGN.md §15): retune the device-loop
    length k from observed *idle row-rounds* the way W is retuned from
    acceptance.

    With in-loop slot adoption the old binary heuristic (``k = 1`` whenever
    backlog is queued) inverts: a queued backlog is exactly when long loops
    pay off, because freed rows adopt staged work without a sync. The
    remaining cost of a long loop is idle tail — rows that finished and
    found the staging area drained. The controller tracks an EWMA of the
    per-loop idle fraction (idle row-rounds over total row-rounds) and
    walks k on the pow2 grid up to ``k_max``: grow while loops run full
    with negligible idle, shrink when the idle fraction says the host
    should have synced earlier to restage. Hysteresis mirrors
    :class:`AdaptiveWindowController` — a proposal must persist
    ``patience`` syncs. k only gates WHEN the host syncs, never token
    values, so exactness is indifferent to it.
    """
    k_max: int = 8
    k_init: int = 0              # 0 -> start at 1 (sync-heavy, observe first)
    alpha: float = 0.4           # EWMA weight of the newest loop
    grow_below: float = 0.05     # idle_frac under which a full loop grows k
    shrink_above: float = 0.25   # idle_frac above which k shrinks
    patience: int = 2            # syncs a proposal must persist
    enabled: bool = True
    history_cap: int = 4096

    def __post_init__(self):
        assert self.k_max >= 1
        assert self.history_cap >= 1
        k = self.k_init if self.k_init > 0 else 1
        k = min(k, self.k_max)
        self._k = k if k == self.k_max else _pow2_at_most(k)
        self._idle_ewma = 0.0
        self._pending = self._k
        self._streak = 0
        self.history: deque[int] = deque(maxlen=self.history_cap)

    @property
    def k(self) -> int:
        return self._k

    @property
    def ewma_idle(self) -> float:
        return self._idle_ewma

    def observe(self, loop_rounds: int, idle_row_rounds: int,
                rows: int, backlog: int) -> int:
        """Feed one sync's loop stats: rounds the loop actually executed,
        row-rounds spent idle (row free, staging drained), batch rows, and
        the host-side backlog still queued after restaging. Returns k for
        the next dispatch."""
        self.history.append(self._k)
        if not self.enabled or loop_rounds <= 0 or rows <= 0:
            return self._k
        idle = float(idle_row_rounds) / float(rows * loop_rounds)
        self._idle_ewma += self.alpha * (idle - self._idle_ewma)
        ran_full = loop_rounds >= self._k
        if self._idle_ewma > self.shrink_above:
            prop = max(self._k // 2, 1)
        elif ran_full and self._idle_ewma < self.grow_below:
            prop = self._k * 2
            prop = prop if prop <= self.k_max else self.k_max
            if prop != self.k_max:
                prop = _pow2_at_most(prop)
        else:
            prop = self._k
        if backlog <= 0 and prop > self._k:
            prop = self._k          # nothing to adopt: growth buys no refill
        if prop == self._pending:
            self._streak += 1
        else:
            self._pending, self._streak = prop, 1
        if self._streak >= self.patience and prop != self._k:
            self._k = prop
        return self._k
