"""Fault model of the serving runtime: structured request errors, a
deterministic fault-injection harness, and the host-tier circuit breaker
(DESIGN.md §14).

The serving stack fails *per request*, never per process: every failure the
engine can survive is routed through a :class:`RequestError` attached to the
offending ``Request`` while the rest of the batch stays bit-exact. To make
every one of those paths testable the same way ``SCHED_SCRIPT`` exercises
preemption, :class:`FaultPlan` scripts faults at **named seams**:

==================  =====================================================
seam                fires inside
==================  =====================================================
``alloc``           ``BlockManager.alloc`` — raises MemoryError before
                    taking a block (admission / capacity-growth faults)
``arena_put``       ``HostArena.put`` — the put is rejected as if the
                    host allocation failed (spill/park/snapshot lost)
``arena_corrupt``   ``HostArena.get`` — flips a byte of the stored entry
                    *before* the integrity check reads it
``stage_drop``      ``StagingRing.stage`` — raises :class:`StagingFault`
                    mid-ring (H2D upload died)
``disk_full``       ``DiskTier.put`` — the spill write is refused as if
                    the filesystem returned ENOSPC (breaker failure)
``disk_torn_write`` ``DiskTier.put`` — the frame is written truncated
                    (a crash mid-write; the crc verify at read catches it)
``disk_slow``       ``DiskTier.get`` — a small deterministic stall (a
                    degraded device; latency only, never an error)
``journal_truncate````RequestJournal.replay`` — the journal tail is torn
                    off at the last record boundary before parsing (a
                    crash mid-append; replay must truncate, not error)
==================  =====================================================

plus ``poison_streams``: noise-stream ids whose verify-round logits are
NaN-replaced on device (the model-wrapper seam — exercises the packed-stats
health flag end to end).

Every seam keeps an invocation counter; a fault fires either at explicitly
scripted invocation indices (``alloc=@2;5`` -> the 3rd and 6th calls) or at
a seeded rate (``arena_corrupt=0.05``) decided by a counter-keyed hash —
**never** by ``random``/time, so a plan replays identically across runs,
processes, and the CI chaos job (``REPRO_FAULT_PLAN`` env).
"""
from __future__ import annotations

import os
import signal
import sys
import zlib
from dataclasses import dataclass, field
from typing import Optional

SEAMS = ("alloc", "arena_put", "arena_corrupt", "stage_drop",
         "disk_full", "disk_torn_write", "disk_slow", "journal_truncate")

# -- kill-point crash harness (DESIGN.md §16) --------------------------------
# Named host-side sites at which the recovery test harness SIGKILLs a
# subprocess engine: ``REPRO_KILL_POINT=<point>`` dies at the first hit of
# that site, ``<point>:<i>`` at the (i+1)-th. SIGKILL (not an exception) is
# the point — no finally-blocks, no atexit, no buffered flushes: exactly the
# state a power-cut process leaves behind, which is what checkpoint/restore
# must recover from. Counters are per-process; the spec is re-read per call
# so a test can arm/disarm points without re-importing.
KILL_POINTS = ("post_admit", "mid_spill", "pre_fsync", "post_sync")
_kill_hits: dict[str, int] = {}


def kill_point(name: str) -> None:
    """Die here (SIGKILL) iff ``REPRO_KILL_POINT`` names this site."""
    spec = os.environ.get("REPRO_KILL_POINT", "")
    if not spec:
        return
    point, _, idx = spec.partition(":")
    if point != name:
        return
    i = _kill_hits.get(name, 0)
    _kill_hits[name] = i + 1
    if i == (int(idx) if idx else 0):
        sys.stdout.flush()       # results already delivered stay delivered
        sys.stderr.flush()
        os.kill(os.getpid(), signal.SIGKILL)


@dataclass
class RequestError:
    """Structured failure attached to ``Request.error`` (result stays None).

    ``code`` is machine-readable: submit-time rejections (``empty_prompt``,
    ``bad_new_tokens``, ``too_long``, ``token_out_of_range``,
    ``over_capacity``), quarantine verdicts (``nonfinite``, ``stuck``),
    host-side faults (``admission``, ``capacity``), runaway aborts
    (``timeout``, ``round_budget``) and ``cancelled``."""
    code: str
    detail: str = ""
    retryable: bool = False
    attempts: int = 1            # admission attempts consumed (retries + 1)

    def __str__(self):
        return f"{self.code}({self.detail})" if self.detail else self.code


class StagingFault(RuntimeError):
    """Injected (or real) H2D staging failure inside ``StagingRing.stage``."""


class FaultPlan:
    """Deterministic per-seam fault schedule (see module docstring).

    ``schedule`` maps a seam to explicit 0-based invocation indices;
    ``rates`` maps a seam to a per-invocation firing probability decided by
    ``crc32(seed:seam:index)`` — deterministic, replayable, process-safe.
    ``fire(seam)`` is the single entry point every instrumented seam calls.
    """

    def __init__(self, schedule: Optional[dict] = None,
                 rates: Optional[dict] = None, seed: int = 0,
                 poison_streams=()):
        self.schedule = {k: frozenset(int(i) for i in v)
                         for k, v in (schedule or {}).items()}
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        self.seed = int(seed)
        self.poison_streams = frozenset(int(s) for s in poison_streams)
        self.calls: dict[str, int] = {}      # invocations seen per seam
        self.fired: dict[str, int] = {}      # faults injected per seam

    def fire(self, seam: str) -> bool:
        """Advance ``seam``'s invocation counter; True iff a fault fires."""
        i = self.calls.get(seam, 0)
        self.calls[seam] = i + 1
        hit = i in self.schedule.get(seam, ())
        rate = self.rates.get(seam, 0.0)
        if not hit and rate > 0.0:
            h = zlib.crc32(f"{self.seed}:{seam}:{i}".encode())
            hit = (h & 0xFFFFFFFF) / 2.0 ** 32 < rate
        if hit:
            self.fired[seam] = self.fired.get(seam, 0) + 1
        return hit

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def fired_export(self) -> dict:
        """Per-seam injected-fault counts for telemetry: one
        ``faults_fired_<seam>`` entry per known seam (zero-filled so chaos
        dashboards see every seam, fired or not)."""
        return {f"faults_fired_{seam}": self.fired.get(seam, 0)
                for seam in SEAMS}

    # -- parsing ------------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> Optional["FaultPlan"]:
        """``"seed=7,alloc=@2;5,arena_corrupt=0.05,poison=3;9"`` — comma-
        separated fields; ``@``-values are explicit invocation indices
        (``;``-separated), bare floats are rates, ``poison`` lists noise-
        stream ids, ``seed`` keys the rate hash. Empty/None -> no plan."""
        if not spec or not spec.strip():
            return None
        schedule, rates, seed, poison = {}, {}, 0, ()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k, v = k.strip(), v.strip()
            if k == "seed":
                seed = int(v)
            elif k == "poison":
                poison = tuple(int(s) for s in v.split(";") if s)
            elif v.startswith("@"):
                schedule[k] = tuple(int(s) for s in v[1:].split(";") if s)
            else:
                rates[k] = float(v)
        for k in list(schedule) + list(rates):
            assert k in SEAMS, f"unknown fault seam {k!r} (have {SEAMS})"
        return cls(schedule=schedule, rates=rates, seed=seed,
                   poison_streams=poison)

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULT_PLAN") -> Optional["FaultPlan"]:
        return cls.parse(os.environ.get(var, ""))

    def __repr__(self):
        return (f"FaultPlan(schedule={dict(self.schedule)}, "
                f"rates={self.rates}, seed={self.seed}, "
                f"poison={sorted(self.poison_streams)}, "
                f"fired={self.fired})")


@dataclass
class CircuitBreaker:
    """Count-based closed/open/half-open breaker for the host tier.

    Deterministic (counts ops, not wall time): ``threshold`` *consecutive*
    failures trip it open; while open every ``allow()`` is denied and counts
    toward ``cooldown``; the first ``allow()`` past the cooldown is the
    half-open probe — a success re-closes, a failure re-opens. A tripped
    tier behaves as a total cache miss (the engine recomputes), never as an
    error — that is the whole point."""
    threshold: int = 3
    cooldown: int = 32
    state: str = "closed"        # "closed" | "open" | "half_open"
    failures: int = 0            # consecutive failures while closed
    trips: int = 0               # times the breaker opened
    denied: int = 0              # ops refused while open
    _cooldown_left: int = 0

    def allow(self) -> bool:
        if self.state == "open":
            self._cooldown_left -= 1
            if self._cooldown_left > 0:
                self.denied += 1
                return False
            self.state = "half_open"     # this op is the probe
        return True

    def record_success(self):
        if self.state == "half_open":
            self.state = "closed"
        self.failures = 0

    def record_failure(self):
        self.failures += 1
        if (self.state == "half_open"
                or (self.state == "closed"
                    and self.failures >= self.threshold)):
            self.state = "open"
            self.trips += 1
            self._cooldown_left = self.cooldown
            self.failures = 0

    def stats_export(self, prefix: str = "tier") -> dict:
        """Breaker observability (one breaker per cache tier — the host
        arena's exports under ``tier_*``, the disk tier's under
        ``disk_*``): current state plus trip/denial counters."""
        return {f"{prefix}_state": self.state,
                f"{prefix}_tripped": self.trips,
                f"{prefix}_denied_ops": self.denied}
