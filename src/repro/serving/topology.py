"""Serving topology: the mesh-placement layer of the serving runtime
(DESIGN.md §10).

``ServingTopology`` describes how one ``ServingEngine`` maps onto a device
mesh and owns every placement decision the engine makes:

* **Slot partition** — the ``batch`` slots are split into ``data_size``
  contiguous ranges; shard ``s`` owns slots ``[s*B_local, (s+1)*B_local)``.
* **Block sub-pools** — the physical block pool is per-data-shard: shard
  ``s`` owns global blocks ``[s*P_local, (s+1)*P_local)`` and its block
  tables store *shard-local* ids. Each sub-pool has its own reserved sink
  block (local id 0), so masked scatter lanes never cross shards.
* **Round wrapping** — the verify round / jitted step runs under
  ``shard_map`` manual over the ``data`` axis: every shard decodes its own
  rows against its own sub-pool with its own local tables. Block-table
  indirection is shard-local *by construction* — no gather ever sees a
  remote block id, so the round hot path lowers with zero cross-shard
  collectives (asserted via HLO inspection in
  tests/serving/test_mesh_engine.py). The device-resident round *loop*
  (DESIGN.md §11) preserves this: the whole ``lax.while_loop`` sits inside
  the per-shard body and each shard's stop condition reads only its OWN
  rows, so shards may run different trip counts and the stop test needs no
  cross-shard reduction — extra rounds on an early-finishing shard are
  token-exact no-ops. Other mesh axes (``model``, ``pod``) stay *auto*:
  GSPMD places tensor-sharded params there (only standard TP reductions
  can appear, never table-indexed traffic).
* **Exactness** — per-request noise streams (``Request.seq_id``) are
  placement-independent and the round body is row-local, so a mesh engine
  emits tokens bit-identical to the single-device engine and to solo
  ``PredictiveSampler.generate``.

``ServingTopology()`` (no mesh) is the single-device degenerate case: one
shard, plain ``jax.jit``, no placement — the engine always goes through
this layer, so there is exactly one code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.api import shard_map


class ServingTopology:
    """Mesh + axis naming + partition math for a mesh-sharded engine.

    ``mesh=None`` = single device (one shard). ``data_axis`` rows/pools are
    manually sharded; every other mesh axis is left to GSPMD (``auto``) so
    ``param_shardings``-style tensor parallelism over ``model`` composes.
    """

    def __init__(self, mesh=None, data_axis: str = "data"):
        if mesh is not None:
            assert data_axis in mesh.axis_names, (data_axis, mesh.axis_names)
        self.mesh = mesh
        self.data_axis = data_axis

    @property
    def data_size(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.shape[self.data_axis])

    @property
    def auto_axes(self) -> frozenset:
        """Mesh axes left to GSPMD (tensor-parallel params live there).
        Size-1 axes are excluded: they are trivially manual, and a jax whose
        shard_map lacks ``auto`` support can still serve data-parallel."""
        if self.mesh is None:
            return frozenset()
        return frozenset(a for a in self.mesh.axis_names
                         if a != self.data_axis and self.mesh.shape[a] > 1)

    def __repr__(self):
        if self.mesh is None:
            return "ServingTopology(single-device)"
        return (f"ServingTopology(mesh={dict(self.mesh.shape)}, "
                f"data_axis={self.data_axis!r})")

    # -- slot / block partition math (host-side bookkeeping) ---------------
    def slots_per_shard(self, batch: int) -> int:
        assert batch % self.data_size == 0, \
            f"batch {batch} not divisible by data shards {self.data_size}"
        return batch // self.data_size

    def shard_of_slot(self, b: int, batch: int) -> int:
        return b // self.slots_per_shard(batch)

    def slot_range(self, shard: int, batch: int) -> range:
        per = self.slots_per_shard(batch)
        return range(shard * per, (shard + 1) * per)

    def global_slot(self, shard: int, local_row: int, batch: int) -> int:
        """Inverse of the shard-local row numbering the round program sees:
        the global batch slot of ``local_row`` on ``shard``. The in-loop
        adoption scan (DESIGN.md §15) reports displaced episodes by local
        row; the harvest walk maps them back through here. Same contract
        for the shard-major staged-descriptor arrays: descriptor ``i`` of
        ``shard`` lives at flat index ``shard * S + i``, matching how
        ``put_batch`` splits a leading dimension across the data axis."""
        per = self.slots_per_shard(batch)
        assert 0 <= local_row < per, (local_row, per)
        return shard * per + local_row

    def block_offset(self, shard: int, blocks_per_shard: int) -> int:
        """Global pool id of a shard's local block 0 (its reserved sink)."""
        return shard * blocks_per_shard

    # -- host cache tier (DESIGN.md §13) ------------------------------------
    def host_tier(self, capacity_bytes: int, staging_depth: int = 2, *,
                  integrity: bool = True, faults=None, breaker=None,
                  disk=None):
        """Build the engine's host cache tier for this topology: one arena
        (a single shared byte budget for the whole process — a hot shard may
        use headroom an idle one is not) partitioned into per-data-shard key
        namespaces, mirroring the per-shard device prefix caches (block
        contents never cross shards, so neither do their host copies).
        ``integrity``/``faults``/``breaker`` configure the §14 fault layer
        (checksum verification, injection seams, circuit breaker); ``disk``
        is an optional §16 :class:`DiskTier` below the arena (one directory
        for the process — keys carry the shard, like the arena)."""
        from repro.serving.hostcache import HostTier
        return HostTier(capacity_bytes, num_shards=self.data_size,
                        staging_depth=staging_depth, integrity=integrity,
                        faults=faults, breaker=breaker, disk=disk)

    # -- device placement ---------------------------------------------------
    def batch_spec(self) -> P:
        return P(self.data_axis)

    def batch_sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.batch_spec())

    def put_batch(self, x):
        """Device array with the batch (slot) dim sharded over ``data``."""
        if self.mesh is None:
            return jnp.asarray(x)
        return jax.device_put(x, self.batch_sharding())

    def put_paged(self, cfg, paged):
        """Place a paged-cache pytree: pool/state leading dims over ``data``
        (see ``sharding.rules.paged_cache_shardings``)."""
        if self.mesh is None:
            return paged
        sh = self.paged_shardings(cfg, paged)
        return jax.tree.map(jax.device_put, paged, sh,
                            is_leaf=lambda x: isinstance(x, NamedSharding))

    def paged_shardings(self, cfg, paged):
        """NamedSharding pytree for the paged cache, or None without a mesh.
        Admission-path jits that write into sub-pools with GLOBAL pool ids —
        row-local prefill, the sequence-migration block copy — run as plain
        GSPMD programs and pin their output back to this placement, so the
        pool never silently decays to replicated; cross-shard traffic there
        is acceptable because none of it is on the round hot path."""
        if self.mesh is None:
            return None
        from repro.sharding.rules import paged_cache_shardings
        return paged_cache_shardings(cfg, paged, self.mesh,
                                     data_axis=self.data_axis)

    # -- program wrapping ---------------------------------------------------
    def wrap_round(self, fn, paged_specs, n_batch_in: int, n_batch_out: int):
        """Map the round step over the data axis: shards see local rows,
        local tables, and their local block sub-pool. ``fn`` signature is
        ``(params, paged, *batch_args) -> (paged, *batch_outs)``;
        ``paged_specs`` is the PartitionSpec pytree for the paged cache
        (``TransformerLM.paged_partition_specs``). Identity without a mesh.
        """
        if self.mesh is None:
            return fn
        d = self.batch_spec()
        return shard_map(
            fn, mesh=self.mesh,
            in_specs=(P(), paged_specs) + (d,) * n_batch_in,
            out_specs=(paged_specs,) + (d,) * n_batch_out,
            check_vma=False, auto=self.auto_axes)
