"""Paged KV-cache serving runtime with adaptive speculation and telemetry.

See DESIGN.md §6-9 and ``repro.serving.engine.ServingEngine`` for the
architecture; ``repro.engine.ContinuousBatcher`` remains as a thin
compatibility alias over this subsystem.
"""
from repro.serving.admission import AdmissionQueue, Request, prefill_chunks
from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.blocks import BlockManager, chain_hashes
from repro.serving.engine import ServingEngine
from repro.serving.metrics import EngineMetrics, percentile

__all__ = ["AdmissionQueue", "Request", "prefill_chunks",
           "AdaptiveWindowController", "BlockManager", "chain_hashes",
           "ServingEngine", "EngineMetrics", "percentile"]
