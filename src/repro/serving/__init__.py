"""Paged KV-cache serving runtime with adaptive speculation and telemetry.

See DESIGN.md §6-12 and ``repro.serving.engine.ServingEngine`` for the
architecture; ``repro.engine.ContinuousBatcher`` remains as a thin
compatibility alias over this subsystem. ``ServingTopology`` maps an engine
onto a device mesh (per-data-shard slot ranges + block sub-pools, shard_map
round step); ``ShardedBlockPool`` routes admissions by pool pressure and
carries the sequence-migration block accounting; under saturation the
engine schedules with admission lookahead, priority preemption (host-side
parking + bitwise-exact resume, ``ParkedSequence``), and shard rebalancing
(§12); fault isolation (§14) quarantines failures per request
(``RequestError``), integrity-checks the host cache tiers behind a
``CircuitBreaker``, and scripts every failure path deterministically
through a ``FaultPlan``; durability (§16) journals the request lifecycle
(``RequestJournal``), spills arena victims to a ``DiskTier``, and
checkpoints the scheduler so a SIGKILLed engine restarts bitwise-exact
(``REPRO_KILL_POINT`` crash harness).
"""
from repro.serving.admission import (AdmissionQueue, Request, pow2_at_most,
                                     prefill_chunks)
from repro.serving.adaptive import AdaptiveWindowController
from repro.serving.blocks import BlockManager, ShardedBlockPool, chain_hashes
from repro.serving.engine import ParkedSequence, ServingEngine
from repro.serving.faults import (KILL_POINTS, CircuitBreaker, FaultPlan,
                                  RequestError, StagingFault, kill_point)
from repro.serving.hostcache import (DiskTier, HostArena, HostTier,
                                     StagingRing)
from repro.serving.journal import RequestJournal
from repro.serving.metrics import EngineMetrics, percentile
from repro.serving.topology import ServingTopology

__all__ = ["AdmissionQueue", "Request", "prefill_chunks", "pow2_at_most",
           "AdaptiveWindowController", "BlockManager", "ShardedBlockPool",
           "chain_hashes", "ParkedSequence", "ServingEngine",
           "EngineMetrics", "percentile", "ServingTopology",
           "HostArena", "HostTier", "StagingRing", "DiskTier",
           "RequestJournal", "KILL_POINTS", "kill_point",
           "CircuitBreaker", "FaultPlan", "RequestError", "StagingFault"]
