"""Serving telemetry: per-request and engine-level counters as plain dicts.

No external metrics dependency — everything exports to ``dict`` so callers
can feed dashboards, benchmark tables, or test assertions directly. The
engine updates these from values it already syncs to host each round, so
telemetry adds no extra device round-trips.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def percentile(values, p: float) -> float:
    """p in [0, 100]; 0.0 on empty input (missing-data sentinel)."""
    vals = [v for v in values if v is not None]
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals, np.float64), p))


@dataclass
class EngineMetrics:
    rounds: int = 0                      # batch-level verify rounds (ARM calls)
    prefill_calls: int = 0               # row-local prefill chunk passes
    tokens_generated: int = 0
    tokens_accepted_hist: list = field(default_factory=list)  # per-round sums
    occupancy_hist: list = field(default_factory=list)        # active/B per round
    window_hist: list = field(default_factory=list)           # W per round
    requests_finished: int = 0
    request_latencies: list = field(default_factory=list)
    request_queue_waits: list = field(default_factory=list)
    request_calls: list = field(default_factory=list)         # rounds/request
    request_new_tokens: list = field(default_factory=list)
    deadline_miss_count: int = 0         # finished past their latency SLO
    deadline_requests: int = 0           # finished requests that carried one

    def observe_round(self, window: int, active: int, batch: int,
                      accepted: int):
        self.rounds += 1
        self.window_hist.append(int(window))
        self.occupancy_hist.append(active / batch if batch else 0.0)
        self.tokens_accepted_hist.append(int(accepted))
        self.tokens_generated += int(accepted)

    def observe_finish(self, req):
        self.requests_finished += 1
        self.request_latencies.append(req.latency)
        self.request_queue_waits.append(req.queue_wait)
        self.request_calls.append(req.calls_used)
        self.request_new_tokens.append(req.new_tokens)
        if getattr(req, "deadline", None) is not None:
            self.deadline_requests += 1
            if req.missed_deadline:
                self.deadline_miss_count += 1

    def export(self, block_stats: dict | None = None) -> dict:
        calls = np.asarray(self.request_calls, np.float64)
        new = np.asarray(self.request_new_tokens, np.float64)
        out = {
            "rounds": self.rounds,
            "prefill_calls": self.prefill_calls,
            "tokens_generated": self.tokens_generated,
            "requests_finished": self.requests_finished,
            "mean_accept_per_round": (
                float(np.mean(self.tokens_accepted_hist))
                if self.tokens_accepted_hist else 0.0),
            "mean_batch_occupancy": (
                float(np.mean(self.occupancy_hist))
                if self.occupancy_hist else 0.0),
            "mean_window": (float(np.mean(self.window_hist))
                            if self.window_hist else 0.0),
            "window_final": self.window_hist[-1] if self.window_hist else 0,
            "arm_calls_per_request_mean": (
                float(calls.mean()) if calls.size else 0.0),
            # < 1.0 means speculation beat ancestral decode
            "arm_calls_vs_ancestral": (
                float((calls / np.maximum(new, 1)).mean())
                if calls.size else 0.0),
            "latency_p50_s": percentile(self.request_latencies, 50),
            "latency_p95_s": percentile(self.request_latencies, 95),
            "queue_wait_p50_s": percentile(self.request_queue_waits, 50),
            "queue_wait_p95_s": percentile(self.request_queue_waits, 95),
            "deadline_miss_count": self.deadline_miss_count,
            "deadline_requests": self.deadline_requests,
        }
        if block_stats:
            out.update(block_stats)
        return out
