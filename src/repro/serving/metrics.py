"""Serving telemetry: per-request and engine-level counters as plain dicts.

No external metrics dependency — everything exports to ``dict`` so callers
can feed dashboards, benchmark tables, or test assertions directly. The
engine updates these from values it already syncs to host each round, so
telemetry adds no extra device round-trips.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def percentile(values, p: float) -> float:
    """p in [0, 100]; 0.0 on empty input (missing-data sentinel)."""
    vals = [v for v in values if v is not None]
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals, np.float64), p))


@dataclass
class EngineMetrics:
    rounds: int = 0                      # batch-level verify rounds (ARM calls)
    prefill_calls: int = 0               # row-local prefill chunk passes
    host_syncs: int = 0                  # stats-array pulls (one per loop)
    device_dispatches: int = 0           # round-loop program launches
    tokens_generated: int = 0
    tokens_accepted_hist: list = field(default_factory=list)  # per-loop sums
    occupancy_hist: list = field(default_factory=list)  # row-rounds/(rounds*B)
    active_row_rounds: int = 0           # (row, round) pairs active, total
    row_rounds: int = 0                  # rounds * batch, total — the
    #                                      duration-weighted occupancy
    #                                      denominator (the per-loop hist
    #                                      mean overweights short loops)
    window_hist: list = field(default_factory=list)           # W per loop
    requests_finished: int = 0
    request_latencies: list = field(default_factory=list)
    request_queue_waits: list = field(default_factory=list)
    request_calls: list = field(default_factory=list)         # rounds/request
    request_new_tokens: list = field(default_factory=list)
    deadline_miss_count: int = 0         # finished past their latency SLO
    deadline_requests: int = 0           # finished requests that carried one
    deadline_missed_in_queue: int = 0    # SLO expired while queued/parked
    #                                      (detected at admission poll time,
    #                                      once per request)
    preemptions: int = 0                 # slots parked for a higher priority
    resumes: int = 0                     # parked requests re-admitted
    migrations: int = 0                  # mid-flight slot/shard moves
    blocks_parked: int = 0               # block payloads spilled to host
    blocks_migrated: int = 0             # blocks device-copied across shards
    head_bypass_admissions: int = 0      # lookahead admissions past the head
    host_staged_blocks: int = 0          # KV blocks re-admitted from the host
    #                                      tier at admission (H2D staging)
    rec_snapshot_captures: int = 0       # recurrent-state rows checkpointed
    #                                      into the host tier at block bounds
    rec_snapshot_restores: int = 0       # admissions that resumed from a
    #                                      host-tier recurrent snapshot
    requests_failed: int = 0             # requests finished with a
    #                                      RequestError (quarantine/abort)
    requests_cancelled: int = 0          # requests cancelled via cancel(uid)
    requests_rejected: int = 0           # submit-time validation rejections
    retries: int = 0                     # failed requests re-admitted
    staging_errors: int = 0              # H2D staging runs aborted mid-ring
    resume_recomputes: int = 0           # parked resumes rebuilt by cold
    #                                      re-prefill (payload lost/corrupt)
    in_loop_adoptions: int = 0           # sequences adopted by a freed row
    #                                      inside the device loop (no sync)
    staged_sequences: int = 0            # requests ever staged for adoption
    staging_occupancy_hist: list = field(default_factory=list)  # staged/S
    #                                      per dispatch (drain-rate signal)
    prefetch_hits: int = 0               # queued requests whose host-tier
    #                                      prefix was restaged before admit
    idle_row_rounds: int = 0             # (row, round) pairs a freed row sat
    #                                      with the staging area drained
    recovered_requests: int = 0          # requests re-admitted from the
    #                                      journal by restore() (§16)
    recovered_parked: int = 0            # of those, resumed from a durable
    #                                      parked-sequence checkpoint (the
    #                                      rest re-prefill from scratch)
    checkpoints_written: int = 0         # scheduler snapshots fsynced at
    #                                      sync boundaries
    active_rr_backlog: int = 0           # the two counters above, restricted
    row_rr_backlog: int = 0              # to loops DISPATCHED with host
    #                                      backlog (queued or staged work
    #                                      waiting) — the §15 saturation
    #                                      claim is about these loops; the
    #                                      drain tail idles identically for
    #                                      every engine and only adds noise

    def _per_token(self, value: float) -> float:
        """All ``*_per_token`` exports divide here: 0.0 before the first
        generated token instead of ZeroDivisionError (a server exporting
        telemetry right after boot has tokens_generated == 0)."""
        return value / self.tokens_generated if self.tokens_generated else 0.0

    def observe_loop(self, window: int, rounds: int, active_row_rounds: int,
                     batch: int, accepted: int, backlog: int = 0):
        """One device-resident round loop (one dispatch, one host sync)
        covering ``rounds`` verify rounds; ``active_row_rounds`` counts
        (row, round) pairs in which the row was active. ``backlog`` is the
        host-side work (queued + staged) waiting when the loop was
        dispatched — loops with ``backlog > 0`` feed the under-backlog
        occupancy split."""
        self.rounds += int(rounds)
        self.host_syncs += 1
        self.device_dispatches += 1
        self.window_hist.append(int(window))
        self.active_row_rounds += int(active_row_rounds)
        self.row_rounds += max(1, int(rounds)) * batch
        if backlog > 0:
            self.active_rr_backlog += int(active_row_rounds)
            self.row_rr_backlog += max(1, int(rounds)) * batch
        denom = max(1, int(rounds)) * batch
        self.occupancy_hist.append(active_row_rounds / denom if batch
                                   else 0.0)
        self.tokens_accepted_hist.append(int(accepted))
        self.tokens_generated += int(accepted)

    def observe_round(self, window: int, active: int, batch: int,
                      accepted: int):
        """Host-driven compatibility shim: a single round = a loop of 1."""
        self.observe_loop(window, 1, active, batch, accepted)

    def observe_finish(self, req):
        self.requests_finished += 1
        self.request_latencies.append(req.latency)
        self.request_queue_waits.append(req.queue_wait)
        self.request_calls.append(req.calls_used)
        self.request_new_tokens.append(req.new_tokens)
        if getattr(req, "deadline", None) is not None:
            self.deadline_requests += 1
            if req.missed_deadline:
                self.deadline_miss_count += 1

    def export(self, block_stats: dict | None = None,
               host_stats: dict | None = None) -> dict:
        calls = np.asarray(self.request_calls, np.float64)
        new = np.asarray(self.request_new_tokens, np.float64)
        out = {
            "rounds": self.rounds,
            "prefill_calls": self.prefill_calls,
            "host_syncs": self.host_syncs,
            "device_dispatches": self.device_dispatches,
            # device residency: verify rounds amortized per program launch /
            # per host pull (1.0 = host-driven; rounds_per_sync at best)
            "rounds_per_sync": (self.rounds / self.host_syncs
                                if self.host_syncs else 0.0),
            "dispatches_per_token": self._per_token(self.device_dispatches),
            "host_syncs_per_token": self._per_token(self.host_syncs),
            "syncs_per_token": self._per_token(self.host_syncs),
            "rounds_per_token": self._per_token(self.rounds),
            "tokens_generated": self.tokens_generated,
            "requests_finished": self.requests_finished,
            # hist entries are per-LOOP sums since the device-resident
            # rounds; normalize by executed rounds so the value keeps its
            # per-round meaning across rounds_per_sync settings
            "mean_accept_per_round": (self.tokens_generated / self.rounds
                                      if self.rounds else 0.0),
            "mean_batch_occupancy": (
                float(np.mean(self.occupancy_hist))
                if self.occupancy_hist else 0.0),
            # duration-weighted occupancy: active row-rounds over ALL row-
            # rounds executed — the per-loop mean above weights a 1-round
            # loop equally with an 8-round one, which misranks engines that
            # run different loop lengths for the same work
            "occupancy_weighted": (self.active_row_rounds / self.row_rounds
                                   if self.row_rounds else 0.0),
            # saturation while work waits (§15): 1.0 means no (row, round)
            # pair was wasted while the host held adoptable work. The k=1
            # host-admission baseline is 1.0 here BY CONSTRUCTION (it syncs
            # every round, so refill is instant); a device-resident loop
            # can only approach it, paying <= 1 round of idle per freed row
            # before adoption or the starvation exit kicks in
            "occupancy_under_backlog": (
                self.active_rr_backlog / self.row_rr_backlog
                if self.row_rr_backlog else 0.0),
            "mean_window": (float(np.mean(self.window_hist))
                            if self.window_hist else 0.0),
            "window_final": self.window_hist[-1] if self.window_hist else 0,
            "arm_calls_per_request_mean": (
                float(calls.mean()) if calls.size else 0.0),
            # < 1.0 means speculation beat ancestral decode
            "arm_calls_vs_ancestral": (
                float((calls / np.maximum(new, 1)).mean())
                if calls.size else 0.0),
            "latency_p50_s": percentile(self.request_latencies, 50),
            "latency_p95_s": percentile(self.request_latencies, 95),
            "queue_wait_p50_s": percentile(self.request_queue_waits, 50),
            "queue_wait_p95_s": percentile(self.request_queue_waits, 95),
            "deadline_miss_count": self.deadline_miss_count,
            "deadline_requests": self.deadline_requests,
            "deadline_missed_in_queue": self.deadline_missed_in_queue,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "migrations": self.migrations,
            "blocks_parked": self.blocks_parked,
            "blocks_migrated": self.blocks_migrated,
            "head_bypass_admissions": self.head_bypass_admissions,
            "host_staged_blocks": self.host_staged_blocks,
            "rec_snapshot_captures": self.rec_snapshot_captures,
            "rec_snapshot_restores": self.rec_snapshot_restores,
            "requests_failed": self.requests_failed,
            "requests_cancelled": self.requests_cancelled,
            "requests_rejected": self.requests_rejected,
            "retries": self.retries,
            "staging_errors": self.staging_errors,
            "resume_recomputes": self.resume_recomputes,
            "in_loop_adoptions": self.in_loop_adoptions,
            "staged_sequences": self.staged_sequences,
            "staging_occupancy": (
                float(np.mean(self.staging_occupancy_hist))
                if self.staging_occupancy_hist else 0.0),
            "prefetch_hits": self.prefetch_hits,
            "idle_row_rounds": self.idle_row_rounds,
            "recovered_requests": self.recovered_requests,
            "recovered_parked": self.recovered_parked,
            "checkpoints_written": self.checkpoints_written,
        }
        if block_stats:
            out.update(block_stats)
        if host_stats:
            # arena + staging-ring counters (host_hits/host_evictions/
            # bytes_resident/h2d_staged/h2d_overlap_frac, ...)
            out.update(host_stats)
        return out
