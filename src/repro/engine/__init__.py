from repro.engine.spec_decode import (PredictiveSampler, GenState,
                                      make_eps_fn, verify_round)

__all__ = ["PredictiveSampler", "GenState", "make_eps_fn", "verify_round",
           "Request", "ContinuousBatcher"]


def __getattr__(name):
    # Lazy: scheduler pulls in repro.serving, whose engine imports
    # spec_decode from this package — importing it eagerly here would cycle.
    if name in ("Request", "ContinuousBatcher"):
        from repro.engine import scheduler
        return getattr(scheduler, name)
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
