from repro.engine.spec_decode import (PredictiveSampler, GenState,
                                      make_eps_fn)
from repro.engine.scheduler import Request, ContinuousBatcher

__all__ = ["PredictiveSampler", "GenState", "make_eps_fn", "Request",
           "ContinuousBatcher"]
