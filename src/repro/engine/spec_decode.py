"""Token-domain predictive sampling with a KV cache — the paper's Algorithm 1
as a serving step (windowed verify), DESIGN.md §3.

Round layout (per sequence): accepted tokens ``x_0..x_{n-1}``; the verify
window feeds ``[x_{n-1}, c_n, .., c_{n+W-2}]`` (W tokens; candidates c are
forecasts). Output slot t is the reparametrized sample for position ``n+t``:
``o_t = argmax(logits_t + eps_{n+t})``. Slot 0 is always valid (conditioned
only on accepted tokens); each further slot is valid while the candidate it
was conditioned on matched. Per round, ``a in [1, W]`` tokens are accepted —
identical tokens to ancestral sampling (W=1), by the paper's exactness
argument, just fewer model calls.

Forecasts: FPI reuses the previous round's outputs past the accept point
(paper §2.3 — zero extra compute); optional learned forecasting heads
(TokenForecast / DeepSeek-MTP correspondence) fill the tail (paper §2.4).

Reparametrization noise is *virtual*: ``eps[b, p] = Gumbel(fold_in(key, b, p))``
is recomputed on demand (never materialized at (L, V) scale) — positions keep
their noise across rounds, which is what makes forecasts exactly verifiable
(paper's key insight; Table 3 ablation).

Per-sequence accept lengths mean each sequence advances at its own rate —
the batched-sampling scheduler the paper left to future work (§4.1 "We leave
the implementation of a scheduling system to future work").
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.analysis.hotpath import hot_path
from repro.core.reparam import reparam_argmax
from repro.models.transformer import PagedView, TransformerLM


def make_eps_fn(key, vocab: int):
    """Deterministic per-(noise stream, position) Gumbel noise function.

    ``eps_fn(seq_ids, positions)`` — ``seq_ids (B,)`` names each row's noise
    stream (a serving engine pins it to the request, so a request keeps its
    stream across slots and batch shapes; a plain sampler uses the row index).
    """
    def eps_fn(seq_ids, positions):
        # seq_ids: (B,); positions: (B, W) absolute token positions
        def one(sid, row):
            kb = jax.random.fold_in(key, sid)
            return jax.vmap(
                lambda p: jax.random.gumbel(jax.random.fold_in(kb, p),
                                            (vocab,)))(row)
        return jax.vmap(one)(seq_ids, positions)
    return eps_fn


class GenState(NamedTuple):
    tokens: jnp.ndarray      # (B, L_max) accepted tokens (prompt + generated)
    n: jnp.ndarray           # (B,) accepted length per sequence
    cand: jnp.ndarray        # (B, W) next verify window (slot0 = last token)
    cache: dict
    rounds: jnp.ndarray      # () total verify rounds (batch-level ARM calls)
    per_seq_calls: jnp.ndarray  # (B,) rounds in which the sequence was active
    accept_hist: jnp.ndarray    # (B,) total accepted tokens while active
    seq_ids: jnp.ndarray        # (B,) noise-stream id per row (see make_eps_fn)


class PredictiveSampler:
    """Batched predictive-sampling text generation for any TransformerLM."""

    def __init__(self, cfg, params, window: int = 8, max_len: int = 256,
                 eps_key=None, use_forecast_heads: bool = False,
                 use_verify_kernel: bool = False):
        self.cfg = cfg
        self.params = params
        self.W = window
        self.max_len = max_len
        self.eps_fn = make_eps_fn(
            eps_key if eps_key is not None else jax.random.PRNGKey(0),
            cfg.vocab)
        self.use_forecast_heads = (use_forecast_heads
                                   and "forecast" in params
                                   and cfg.forecast_horizon > 0)
        # TPU fast path: the fused vocab-tiled Gumbel-argmax Pallas kernel
        # (kernels/spec_verify); interpret-mode on CPU, bit-identical.
        self.use_verify_kernel = use_verify_kernel
        self._round = jax.jit(self._round_impl)

    # ------------------------------------------------------------------
    def init_state(self, prompts, batch: int, seq_ids=None) -> GenState:
        """prompts: (B, L_p) int (uniform prompt length for the state init;
        ragged admission is handled by the serving engine). ``seq_ids``
        selects each row's noise stream (default: row index)."""
        cfg, W = self.cfg, self.W
        B, L_p = prompts.shape
        assert L_p >= 1
        cache = TransformerLM.init_cache(cfg, B, self.max_len + W,
                                         dtype=cfg.param_dtype)
        tokens = jnp.zeros((B, self.max_len), jnp.int32)
        tokens = tokens.at[:, :L_p].set(prompts)

        if L_p > 1:
            # prefill the first L_p - 1 tokens (their KV/state enter the cache)
            _, _, cache = TransformerLM.decode_window(
                self.params, cfg, prompts[:, :-1], cache,
                jnp.zeros((B,), jnp.int32))
            cache = TransformerLM.select_states(
                cfg, cache, jnp.full((B,), L_p - 1, jnp.int32))
        n = jnp.full((B,), L_p, jnp.int32)
        cand = jnp.zeros((B, W), jnp.int32)
        cand = cand.at[:, 0].set(prompts[:, -1])
        if seq_ids is None:
            seq_ids = jnp.arange(B, dtype=jnp.int32)
        return GenState(tokens, n, cand, cache,
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((B,), jnp.int32),
                        jnp.zeros((B,), jnp.int32),
                        jnp.asarray(seq_ids, jnp.int32))

    # ------------------------------------------------------------------
    def _round_impl(self, state: GenState, target_len) -> GenState:
        state, _stats = verify_round(
            self.params, self.cfg, self.eps_fn, state, target_len,
            use_forecast_heads=self.use_forecast_heads,
            use_verify_kernel=self.use_verify_kernel)
        return state

    # ------------------------------------------------------------------
    def generate(self, prompts, new_tokens: int, seq_ids=None):
        """Generate ``new_tokens`` per sequence. Returns (tokens, stats).

        ``seq_ids`` pins each row to a noise stream (default: row index) —
        a serving engine replays the same stream to reproduce a request
        bit-for-bit regardless of which batch slot served it."""
        B, L_p = prompts.shape
        target = jnp.full((B,), L_p + new_tokens, jnp.int32)
        assert L_p + new_tokens <= self.max_len
        state = self.init_state(jnp.asarray(prompts, jnp.int32), B,
                                seq_ids=seq_ids)
        while bool(jnp.any(state.n < target)):
            state = self._round(state, target)
        stats = {
            "rounds": int(state.rounds),
            "per_seq_calls": jax.device_get(state.per_seq_calls),
            "baseline_calls": new_tokens,
            "mean_accept": float(jnp.mean(
                state.accept_hist / jnp.maximum(state.per_seq_calls, 1))),
        }
        return state.tokens, stats


# ---------------------------------------------------------------------------
# The verify round as a pure function (shared by PredictiveSampler and the
# serving engine, which feeds it block-table cache views and variable W)
# ---------------------------------------------------------------------------

@hot_path
def verify_round(params, cfg, eps_fn, state: GenState, target_len,
                 use_forecast_heads: bool = False,
                 use_verify_kernel: bool = False,
                 paged: Optional[PagedView] = None,
                 poison=None,
                 prompt_len=None):
    """One verify round over ``state``. W is taken from
    ``state.cand.shape[1]`` so callers may vary the window round-to-round
    (adaptive speculation): candidates only gate acceptance, never token
    values, so any W yields the same accepted stream (DESIGN.md §3, §7).

    ``state.cache`` is a dense cache view, or — with ``paged`` — the paged
    block-pool pytree, decoded in place through the block tables (no dense
    attention K/V view is ever materialized; DESIGN.md §9).

    ``poison`` (B,) int32, optional, is the serving engine's fault-
    injection seam (DESIGN.md §14): rows with ``poison > 0`` have their
    logits NaN-replaced *post-model*, so K/V written to the cache stay
    finite and row-local — a poisoned row degrades only itself while the
    quarantine health flag (below) trips for it.

    Returns ``(new_state, row_stats)`` where ``row_stats`` is the packed
    (B, 4) int32 per-row stats vector ``[accepted, done, new_length,
    nonfinite]`` — everything a driving loop needs to decide continuation
    and everything a host needs per sync, without pulling
    ``n``/``cand``/``tokens`` (the device-resident round loop ABI,
    DESIGN.md §11). The ``nonfinite`` health column is always computed
    (one cheap ``isfinite`` reduce next to the vocab matmul): any NaN/inf
    in a row's logits — poisoned or genuinely numerically broken — reports
    1 there, the engine's quarantine signal (§14).

    ``prompt_len`` (B,) int32, optional, enables *forced-acceptance
    prefill* (DESIGN.md §15): rows whose accepted length ``n`` is still
    inside their prompt (``n < prompt_len``) carry true prompt tokens in
    their candidate window, so every window slot landing on a prompt
    position is force-matched (the prompt is ground truth — no sampling
    gate applies), token writes preserve the prompt region, and the next
    window is overlaid with prompt tokens wherever it still covers the
    prompt. A row with ``prompt_len <= n`` is bitwise unaffected (every
    forced-match / mask / overlay predicate is False), so resident
    sequences and the ``prompt_len=None`` solo path stay exact."""
    B, W = state.cand.shape
    max_len = state.tokens.shape[1]
    active = state.n < target_len

    cache_len = state.n - 1
    if paged is None:
        logits, h, new_cache = TransformerLM.decode_window(
            params, cfg, state.cand, state.cache, cache_len)
    else:
        logits, h, new_cache = TransformerLM.decode_window_paged(
            params, cfg, state.cand, state.cache, paged, cache_len)
    logits = logits.astype(jnp.float32)
    if poison is not None:
        logits = jnp.where((poison > 0)[:, None, None], jnp.nan, logits)
    nonfinite = 1 - jnp.all(jnp.isfinite(logits),
                            axis=(1, 2)).astype(jnp.int32)
    out_pos = state.n[:, None] + jnp.arange(W)[None, :]   # sampled positions
    eps = eps_fn(state.seq_ids, out_pos)
    if use_verify_kernel:
        from repro.kernels.spec_verify.ops import spec_verify
        out = spec_verify(logits, eps)                    # (B, W)
    else:
        out = reparam_argmax(logits, eps)

    # accept length: slot t+1 valid while candidate c_{n+t} matched o_t
    match = state.cand[:, 1:] == out[:, :-1]               # (B, W-1)
    if prompt_len is not None:
        # forced-acceptance prefill: candidate c_{n+t} at a prompt position
        # is the true prompt token — no gate applies
        forced = (state.n[:, None] + jnp.arange(W - 1)[None, :]) \
            <= (prompt_len[:, None] - 1)
        match = match | forced
    a = 1 + jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    a = jnp.minimum(a, jnp.maximum(target_len - state.n, 1))
    a = jnp.where(active, a, 0)

    # write accepted tokens
    pos = jnp.arange(max_len)[None, :]
    newly = (pos >= state.n[:, None]) & (pos < (state.n + a)[:, None])
    if prompt_len is not None:
        newly = newly & (pos >= prompt_len[:, None])   # preserve the prompt
    slot = jnp.clip(pos - state.n[:, None], 0, W - 1)
    tokens = jnp.where(newly, jnp.take_along_axis(out, slot, axis=1),
                       state.tokens)

    n_new = state.n + a
    # cache: adopt window writes; recurrent states at the accept point.
    # Inactive rows must keep their old recurrent snapshot (a=0 -> the
    # gather would fetch slot -1); clamp handles it because their cand
    # window re-ran from the same snapshot: slot 0 state == snapshot
    # after x_{n-1}... only true if cand[:,0] stayed x_{n-1} — it does.
    sel = TransformerLM.select_states(cfg, new_cache,
                                      jnp.maximum(a, 1))
    if paged is None:
        cache = sel
    else:
        cache = TransformerLM.adopt_states_paged(cfg, state.cache, sel,
                                                 paged.rows)

    # next window: slot0 = last accepted token; FPI forecasts = this
    # round's outputs past the accept point (paper §2.3)
    idx = (a - 1)[:, None] + jnp.arange(W)[None, :]        # (B, W)
    fpi = jnp.take_along_axis(out, jnp.minimum(idx, W - 1), axis=1)
    valid_fpi = idx <= (W - 1)
    cand = jnp.where(valid_fpi, fpi, 0)

    if use_forecast_heads:
        from repro.core.forecasting import (TokenForecast,
                                            TokenForecastConfig)
        fcfg = TokenForecastConfig(cfg.d_model, cfg.vocab,
                                   cfg.forecast_horizon,
                                   cfg.forecast_hidden)
        fc_logits = TokenForecast.apply(params["forecast"], h, fcfg)
        # anchor slot a (uses h[a-1], the last fully-valid slot); offset
        # j forecasts window slot a-1+j -> next-window slot j + ... we
        # fill tail slots where FPI ran out (valid_fpi == False).
        # anchor s=a reads h[a-1] (last fully-valid slot); its offset-t
        # logits forecast window slot a+t... = position n_new-1+t, i.e.
        # next-window slot s' uses offset t = s'.
        anchor = jnp.minimum(a, W - 1)
        fc_a = jnp.take_along_axis(
            fc_logits, anchor[:, None, None, None], axis=1)[:, 0]  # (B,T,V)
        T = cfg.forecast_horizon
        s_idx = jnp.arange(W)
        t_of_s = jnp.clip(s_idx, 0, T - 1)
        eps_next = eps_fn(state.seq_ids, n_new[:, None] - 1 + s_idx[None, :])
        fc_tok = reparam_argmax(
            jnp.take_along_axis(
                fc_a, jnp.broadcast_to(t_of_s[None, :, None],
                                       (B, W, 1)), axis=1),
            eps_next)
        use_fc = (~valid_fpi) & (s_idx[None, :] < T)
        cand = jnp.where(use_fc, fc_tok, cand)

    if prompt_len is not None:
        # next-window slots still inside the prompt must carry the true
        # prompt tokens (they source the K/V writes + the forced matches)
        p = (n_new - 1)[:, None] + jnp.arange(W)[None, :]
        prompt_tok = jnp.take_along_axis(
            tokens, jnp.clip(p, 0, max_len - 1), axis=1)
        cand = jnp.where(p <= prompt_len[:, None] - 1, prompt_tok, cand)

    # slot 0 must be the last accepted token
    last_tok = jnp.take_along_axis(tokens,
                                   jnp.maximum(n_new - 1, 0)[:, None],
                                   axis=1)[:, 0]
    cand = cand.at[:, 0].set(last_tok)
    cand = jnp.where(active[:, None], cand, state.cand)
    n_new = jnp.where(active, n_new, state.n)
    tokens = jnp.where(active[:, None], tokens, state.tokens)

    new_state = GenState(
        tokens, n_new, cand, cache,
        state.rounds + jnp.any(active).astype(jnp.int32),
        state.per_seq_calls + active.astype(jnp.int32),
        state.accept_hist + a,
        state.seq_ids,
    )
    row_stats = jnp.stack(
        [a, (n_new >= target_len).astype(jnp.int32), n_new, nonfinite],
        axis=1)
    return new_state, row_stats
