"""Continuous-batching request scheduler — thin alias over ``repro.serving``.

The paper (§4.1) defers a scheduling system to future work. The seed's dense
``ContinuousBatcher`` lived here; it is now a compatibility shim over the
paged ``ServingEngine`` (``repro.serving.engine``), which adds a paged
KV-cache block manager with a prefix cache, priority admission with
row-local chunked prefill, adaptive speculation windows, and telemetry.
Construction from a ``PredictiveSampler`` pins the window (no adaptation)
to preserve the original behaviour; ``Request`` is re-exported unchanged.
"""
from __future__ import annotations

from repro.serving.admission import Request
from repro.serving.engine import ServingEngine


class ContinuousBatcher(ServingEngine):
    """Seed-compatible facade: ``ContinuousBatcher(sampler, batch)`` with
    ``submit`` / ``run`` / ``done`` / ``state.rounds`` intact."""

    def __init__(self, sampler, batch: int):
        super().__init__(
            sampler.cfg, sampler.params, batch=batch,
            window_max=sampler.W, max_len=sampler.max_len,
            eps_fn=sampler.eps_fn, adaptive=False,
            use_forecast_heads=sampler.use_forecast_heads,
            use_verify_kernel=sampler.use_verify_kernel)


__all__ = ["Request", "ContinuousBatcher"]
