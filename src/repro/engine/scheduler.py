"""Continuous-batching request scheduler (beyond-paper).

The paper (§4.1) measures batched sampling where "the slowest image
determines the number of ARM inference passes" and defers a scheduling
system to future work. Here it is: requests are admitted into free slots of
a fixed-width batch; every verify round each sequence advances by its *own*
accept length; finished sequences free their slot immediately. Throughput
approaches the batch-size-1 ARM-call rate the paper reports.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.spec_decode import GenState, PredictiveSampler
from repro.models.transformer import TransformerLM


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L_p,) int
    new_tokens: int
    result: Optional[np.ndarray] = None
    calls_used: int = 0


class ContinuousBatcher:
    def __init__(self, sampler: PredictiveSampler, batch: int):
        self.s = sampler
        self.B = batch
        self.slots: list[Optional[Request]] = [None] * batch
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self.state: Optional[GenState] = None
        self.target = np.zeros(batch, np.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _reset_row(self, state: GenState, b: int, prompt: np.ndarray):
        """Admit a request into slot b: zero the row's recurrent snapshots,
        prefill its prompt, point its counters at the new sequence."""
        cfg, W = self.s.cfg, self.s.W
        L_p = len(prompt)

        def zero_row(x):
            return x.at[_row_index(x, b)].set(0) if False else x
        # recurrent snapshots: zero just row b
        cache = jax.tree.map(
            lambda x: x.at[_batch_axis_index(x, self.B, b)].set(0),
            state.cache)

        tokens = state.tokens.at[b].set(0)
        tokens = tokens.at[b, :L_p].set(jnp.asarray(prompt, jnp.int32))
        n = state.n.at[b].set(L_p)
        cand = state.cand.at[b].set(0)
        cand = cand.at[b, 0].set(int(prompt[-1]))
        state = state._replace(tokens=tokens, n=n, cand=cand, cache=cache,
                               per_seq_calls=state.per_seq_calls.at[b].set(0),
                               accept_hist=state.accept_hist.at[b].set(0))

        if L_p > 1:
            # row-local prefill: run the whole batch's decode_window but only
            # adopt row b (simple, correct; a production system would group
            # admissions). Prompt chunked through the W-wide window.
            for s0 in range(0, L_p - 1, W):
                chunk = prompt[s0:s0 + W]
                wlen = len(chunk)
                win = np.zeros((self.B, W), np.int32)
                win[b, :wlen] = chunk
                cache_len = jnp.maximum(state.n - 1, 0)
                cache_len = cache_len.at[b].set(s0)
                _, _, nc = TransformerLM.decode_window(
                    self.s.params, cfg, jnp.asarray(win), state.cache,
                    cache_len)
                accept = jnp.ones((self.B,), jnp.int32)
                accept = accept.at[b].set(wlen)
                sel = TransformerLM.select_states(cfg, nc, accept)
                # adopt ONLY row b of the new cache
                cache = jax.tree.map(
                    lambda old, new: _adopt_row(old, new, self.B, b),
                    state.cache, sel)
                state = state._replace(cache=cache)
        return state

    # ------------------------------------------------------------------
    def run(self, max_rounds: int = 10_000):
        """Drain the queue; returns completed Requests with stats."""
        B = self.B
        while self.queue or any(s is not None for s in self.slots):
            # admit
            for b in range(B):
                if self.slots[b] is None and self.queue:
                    req = self.queue.pop(0)
                    if self.state is None:
                        prompts = np.zeros((B, len(req.prompt)), np.int32)
                        prompts[b] = req.prompt
                        self.state = self.s.init_state(
                            jnp.asarray(prompts), B)
                        # other rows: inactive (target 0)
                        self.target[:] = 0
                    else:
                        self.state = self._reset_row(self.state, b,
                                                     req.prompt)
                    self.slots[b] = req
                    self.target[b] = len(req.prompt) + req.new_tokens
            # one verify round for the whole batch
            pre_calls = np.asarray(self.state.per_seq_calls).copy()
            self.state = self.s._round(self.state,
                                       jnp.asarray(self.target))
            # harvest
            n_host = np.asarray(self.state.n)
            for b in range(B):
                req = self.slots[b]
                if req is not None and n_host[b] >= self.target[b]:
                    toks = np.asarray(self.state.tokens[b, :n_host[b]])
                    req.result = toks
                    req.calls_used = int(
                        np.asarray(self.state.per_seq_calls)[b]
                        - pre_calls[b]) + int(pre_calls[b])
                    self.done.append(req)
                    self.slots[b] = None
                    self.target[b] = 0
            max_rounds -= 1
            if max_rounds <= 0:
                raise RuntimeError("scheduler did not converge")
        return self.done


def _batch_axis_index(x, B: int, b: int):
    """Index tuple selecting batch row b, for leaves shaped (B, ...) or
    (n_blocks, B, ...) (scanned segments)."""
    if x.ndim >= 1 and x.shape[0] == B:
        return (b,)
    return (slice(None), b)


def _adopt_row(old, new, B: int, b: int):
    idx = _batch_axis_index(old, B, b)
    return old.at[idx].set(new[idx])
