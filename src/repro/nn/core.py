"""Core layers: Dense, Embedding, norms, (masked) convolutions.

Layers are namespaced classes of static methods so call sites read
``Dense.init`` / ``Dense.apply``; parameters are plain dict pytrees.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def variance_scaling(key, shape, fan_in=None, scale=1.0, dtype=jnp.float32):
    """LeCun-style variance scaling (truncated-normal-free, plain normal)."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 1 else 1
    std = math.sqrt(scale / max(1, fan_in))
    return std * jax.random.normal(key, shape, dtype=dtype)


def truncated_normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype=dtype)


# ---------------------------------------------------------------------------
# Dense / Embedding
# ---------------------------------------------------------------------------

class Dense:
    @staticmethod
    def init(key, in_dim: int, out_dim: int, use_bias: bool = True,
             dtype=jnp.float32, scale: float = 1.0):
        kw, _ = jax.random.split(key)
        params = {"w": variance_scaling(kw, (in_dim, out_dim), fan_in=in_dim,
                                        scale=scale, dtype=dtype)}
        if use_bias:
            params["b"] = jnp.zeros((out_dim,), dtype=dtype)
        return params

    @staticmethod
    def apply(params, x):
        y = x @ params["w"]
        if "b" in params:
            y = y + params["b"]
        return y


class Embedding:
    @staticmethod
    def init(key, vocab: int, dim: int, dtype=jnp.float32, std: float = 0.02):
        return {"table": std * jax.random.normal(key, (vocab, dim), dtype=dtype)}

    @staticmethod
    def apply(params, ids):
        return jnp.take(params["table"], ids, axis=0)

    @staticmethod
    def attend(params, x):
        """Tied-readout logits: x @ table.T"""
        return x @ params["table"].T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

class RMSNorm:
    @staticmethod
    def init(dim: int, dtype=jnp.float32):
        return {"scale": jnp.ones((dim,), dtype=dtype)}

    @staticmethod
    def apply(params, x, eps: float = 1e-6):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(dtype)


class LayerNorm:
    @staticmethod
    def init(dim: int, dtype=jnp.float32):
        return {"scale": jnp.ones((dim,), dtype=dtype),
                "bias": jnp.zeros((dim,), dtype=dtype)}

    @staticmethod
    def apply(params, x, eps: float = 1e-5):
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Convolutions (NHWC)
# ---------------------------------------------------------------------------

class Conv2D:
    @staticmethod
    def init(key, in_ch: int, out_ch: int, kernel: Sequence[int] = (3, 3),
             use_bias: bool = True, dtype=jnp.float32, scale: float = 1.0):
        kh, kw_ = kernel
        fan_in = in_ch * kh * kw_
        params = {"w": variance_scaling(key, (kh, kw_, in_ch, out_ch),
                                        fan_in=fan_in, scale=scale, dtype=dtype)}
        if use_bias:
            params["b"] = jnp.zeros((out_ch,), dtype=dtype)
        return params

    @staticmethod
    def apply(params, x, stride: Sequence[int] = (1, 1), padding="SAME",
              transpose: bool = False):
        if transpose:
            y = jax.lax.conv_transpose(
                x, params["w"], strides=tuple(stride), padding=padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        else:
            y = jax.lax.conv_general_dilated(
                x, params["w"], window_strides=tuple(stride), padding=padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if "b" in params:
            y = y + params["b"]
        return y


def group_ids(n_ch: int, n_groups: int) -> np.ndarray:
    """Contiguous-block channel->group assignment (n_ch divisible preferred)."""
    return np.arange(n_ch) * n_groups // max(n_ch, 1)


def _pixelcnn_mask(kh: int, kw: int, gi: np.ndarray, go: np.ndarray,
                   mask_type: str) -> np.ndarray:
    """Raster-scan causal mask for PixelCNN convolutions.

    Channels carry explicit group ids ``gi``/``go`` (e.g. R,G,B sub-channel
    groups; concat_elu duplicates the id vector): at the centre pixel, output
    group ``go`` may see input group ``g`` iff ``g < go`` (mask 'A', strict)
    or ``g <= go`` (mask 'B'). ``mask_type='T'`` is the strictly-triangular
    *spatial* mask used by the forecasting module: centre pixel fully blocked.
    """
    in_ch, out_ch = len(gi), len(go)
    mask = np.ones((kh, kw, in_ch, out_ch), dtype=np.float32)
    ch, cw = kh // 2, kw // 2
    # rows strictly below centre
    mask[ch + 1:, :, :, :] = 0.0
    # same row, right of centre
    mask[ch, cw + 1:, :, :] = 0.0
    if mask_type == "T":
        mask[ch, cw, :, :] = 0.0
        return mask
    if mask_type == "A":
        centre = (gi[:, None] < go[None, :]).astype(np.float32)
    elif mask_type == "B":
        centre = (gi[:, None] <= go[None, :]).astype(np.float32)
    else:
        raise ValueError(f"unknown mask type {mask_type!r}")
    mask[ch, cw, :, :] = centre
    return mask


class MaskedConv2D:
    """PixelCNN masked convolution with channel-autoregressive centre masks."""

    @staticmethod
    def init(key, in_ch: int, out_ch: int, kernel=(3, 3), mask_type="B",
             groups_in=1, groups_out=1, use_bias: bool = True,
             dtype=jnp.float32):
        """``groups_in``/``groups_out`` may be ints (contiguous blocks) or
        explicit per-channel group-id vectors."""
        params = Conv2D.init(key, in_ch, out_ch, kernel, use_bias, dtype)
        gi = (group_ids(in_ch, groups_in) if np.isscalar(groups_in)
              else np.asarray(groups_in))
        go = (group_ids(out_ch, groups_out) if np.isscalar(groups_out)
              else np.asarray(groups_out))
        mask = _pixelcnn_mask(kernel[0], kernel[1], gi, go, mask_type)
        # mask is static (buffer, not trainable) — store as numpy-backed const
        params["_mask"] = jnp.asarray(mask, dtype=dtype)
        return params

    @staticmethod
    def apply(params, x):
        w = params["w"] * params["_mask"]
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if "b" in params:
            y = y + params["b"]
        return y


def concat_elu(x):
    """concat_elu nonlinearity from PixelCNN++."""
    return jax.nn.elu(jnp.concatenate([x, -x], axis=-1))
