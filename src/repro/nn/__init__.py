"""Minimal functional NN substrate on raw pytrees (no flax dependency).

Every layer is a pair of pure functions:
  ``init(key, ...) -> params``  (params is a dict pytree)
  ``apply(params, x, ...) -> y``
Composite models assemble these dicts; everything jit/pjit-compatible.
"""
from repro.nn.core import (
    Dense,
    Embedding,
    LayerNorm,
    RMSNorm,
    Conv2D,
    MaskedConv2D,
    concat_elu,
    variance_scaling,
    truncated_normal_init,
)
from repro.nn.rope import apply_rope, rope_frequencies

__all__ = [
    "Dense",
    "Embedding",
    "LayerNorm",
    "RMSNorm",
    "Conv2D",
    "MaskedConv2D",
    "concat_elu",
    "variance_scaling",
    "truncated_normal_init",
    "apply_rope",
    "rope_frequencies",
]
