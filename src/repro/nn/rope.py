"""Rotary position embeddings (RoPE), split-half convention."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for RoPE over ``head_dim`` (must be even)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """Apply RoPE to ``x`` of shape (..., seq, heads, head_dim).

    ``positions``: int array broadcastable to (..., seq).
    Uses the split-half (rotate_half) convention used by Llama/Gemma/Qwen.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., seq, half)
    # broadcast over head dim: (..., seq, 1, half)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
