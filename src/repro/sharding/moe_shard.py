"""Expert-parallel MoE under shard_map (the distributed execution path).

Layout: tokens sharded over ("pod","data"); experts sharded over "model"
(EP) and FSDP-sharded over "data" on a weight dim. Each (data, model) shard:

  1. routes its local tokens (router weights replicated),
  2. keeps only assignments targeting its local experts, dispatches them
     into an (E/m, C, D) capacity buffer,
  3. all-gathers its expert weights over "data" (FSDP gather; the transpose
     reduce-scatters the gradients),
  4. computes the expert MLPs, scatters back weighted,
  5. psum over "model" combines contributions from all expert owners.

Communication per MoE layer = one (b, T, D) all-reduce over "model" + the
FSDP weight gathers — the baseline the §Perf all-to-all hillclimb improves
on (an all-to-all moves only routed tokens, ~k/E of the psum bytes... see
EXPERIMENTS.md §Perf for the actual napkin math and measurement).

The token-choice semantics (top-k, capacity, sort order) EXACTLY match the
single-device ``MoE.apply`` dense path — verified by
tests/sharding/test_moe_shard.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.moe import MoE, _mlp_apply
from repro.sharding.api import shard_map


def _dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def moe_apply_sharded(p, x, cfg, mesh, capacity_factor, ep_only: bool = False):
    """Drop-in for MoE.apply under an active mesh. x: (B, T, D) sharded on
    batch; returns (y, aux).

    ``ep_only`` (§Perf C2, inference layout): experts sharded E-wise over
    ("model","data") jointly (full expert parallelism), weights NOT
    FSDP-sharded, tokens replicated (decode token sets are tiny) — removes
    the per-layer expert-weight all-gathers that dominate MoE decode."""
    dp = _dp_axes(mesh)
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]
    E = cfg.n_experts

    if ep_only:
        if E % (n_model * n_data) == 0:
            e_axes, n_eshards = ("model", "data"), n_model * n_data
        else:
            e_axes, n_eshards = ("model",), n_model
        assert E % n_eshards == 0, (E, n_eshards)
        return _moe_ep_only(p, x, cfg, mesh, capacity_factor, e_axes,
                            n_eshards, dp)

    assert E % n_model == 0, (E, n_model)

    # batch not divisible by the dp extent (e.g. long_500k B=1): replicate
    # tokens over dp; expert parallelism over "model" still applies.
    dp_size = 1
    for ax in dp:
        dp_size *= mesh.shape[ax]
    x_spec = P(dp, None, None) if x.shape[0] % dp_size == 0 else P(None, None, None)
    w_spec = P("model", "data", None)
    r_spec = P()

    has_gate = "gate" in p["experts"]

    def local_fn(router, up, gate, down, x_local):
        m = jax.lax.axis_index("model")
        E_loc = E // n_model
        b, T, D = x_local.shape
        N = b * T
        k = cfg.top_k
        xf = x_local.reshape(N, D)

        # --- routing (identical math to MoE.route) -----------------------
        logits = (xf @ router).astype(jnp.float32)
        if cfg.router_score == "sigmoid":
            scores = jax.nn.sigmoid(logits)
        else:
            scores = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(scores, k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        probs = jax.nn.softmax(logits, axis=-1)
        aux = MoE.load_balance_loss(probs, ids.astype(jnp.int32), cfg)
        aux = jax.lax.pmean(aux, dp)

        if capacity_factor is None:
            C = N * k
        else:
            C = max(1, int(N * k * capacity_factor) // E)

        ids_flat = ids.reshape(N * k).astype(jnp.int32)
        w_flat = w.reshape(N * k)
        tok_flat = jnp.repeat(jnp.arange(N), k)
        order = jnp.argsort(ids_flat)
        ids_s = ids_flat[order]
        tok_s = tok_flat[order]
        w_s = w_flat[order]
        first = jnp.searchsorted(ids_s, ids_s, side="left")
        pos = jnp.arange(N * k) - first
        keep = pos < C
        pos_c = jnp.where(keep, pos, C)

        # --- local-expert dispatch --------------------------------------
        local = (ids_s // E_loc) == m
        e_loc = jnp.where(local, ids_s - m * E_loc, E_loc)  # E_loc -> drop
        buf = jnp.zeros((E_loc, C, D), x_local.dtype)
        buf = buf.at[e_loc, pos_c].set(xf[tok_s], mode="drop")

        # --- FSDP weight gather + expert MLPs ----------------------------
        up_f = jax.lax.all_gather(up, "data", axis=1, tiled=True)
        down_f = jax.lax.all_gather(down, "data", axis=1, tiled=True)
        hidden = jnp.einsum("ecd,edf->ecf", buf, up_f)
        if has_gate:
            gate_f = jax.lax.all_gather(gate, "data", axis=1, tiled=True)
            g = jnp.einsum("ecd,edf->ecf", buf, gate_f)
            act = (jax.nn.silu(g) if cfg.mlp_kind == "swiglu"
                   else jax.nn.gelu(g))
            hidden = hidden * act
        else:
            hidden = jax.nn.gelu(hidden)
        out = jnp.einsum("ecf,efd->ecd", hidden, down_f)

        # --- combine + cross-expert-owner reduction ----------------------
        gathered = out.at[e_loc, pos_c].get(mode="fill", fill_value=0.0)
        contrib = gathered * jnp.where(keep & local, w_s, 0.0)[:, None]
        y = jnp.zeros((N, D), x_local.dtype).at[tok_s].add(contrib)
        y = jax.lax.psum(y, "model")
        return y.reshape(b, T, D), aux

    gate_arg = p["experts"]["gate"] if has_gate else p["experts"]["up"]
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(r_spec, w_spec, w_spec, w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p["router"]["w"], p["experts"]["up"], gate_arg,
      p["experts"]["down"], x)

    if "shared" in p:
        y = y + _mlp_apply(p["shared"], x.reshape(-1, x.shape[-1]),
                           cfg.mlp_kind).reshape(x.shape)
    return y, aux


def _moe_ep_only(p, x, cfg, mesh, capacity_factor, e_axes, n_eshards, dp):
    """Full expert parallelism for decode (§Perf C2). Tokens replicated;
    each shard owns E/n_eshards whole experts; one psum combines."""
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_eshards
    has_gate = "gate" in p["experts"]
    w_spec = P(e_axes if len(e_axes) > 1 else e_axes[0], None, None)
    x_spec = P(None, None, None)

    def local_fn(router, up, gate, down, x_rep):
        idx = jax.lax.axis_index(e_axes[0])
        if len(e_axes) > 1:
            idx = idx * mesh.shape[e_axes[1]] + jax.lax.axis_index(e_axes[1])
        b, T, D = x_rep.shape
        N = b * T
        xf = x_rep.reshape(N, D)

        logits = (xf @ router).astype(jnp.float32)
        if cfg.router_score == "sigmoid":
            scores = jax.nn.sigmoid(logits)
        else:
            scores = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(scores, k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        probs = jax.nn.softmax(logits, axis=-1)
        aux = MoE.load_balance_loss(probs, ids.astype(jnp.int32), cfg)

        C = N * k if capacity_factor is None else max(
            1, int(N * k * capacity_factor) // E)
        ids_flat = ids.reshape(N * k).astype(jnp.int32)
        w_flat = w.reshape(N * k)
        tok_flat = jnp.repeat(jnp.arange(N), k)
        order = jnp.argsort(ids_flat)
        ids_s, tok_s, w_s = ids_flat[order], tok_flat[order], w_flat[order]
        first = jnp.searchsorted(ids_s, ids_s, side="left")
        pos = jnp.arange(N * k) - first
        keep = pos < C
        pos_c = jnp.where(keep, pos, C)

        local = (ids_s // E_loc) == idx
        e_loc = jnp.where(local, ids_s - idx * E_loc, E_loc)
        buf = jnp.zeros((E_loc, C, D), x_rep.dtype)
        buf = buf.at[e_loc, pos_c].set(xf[tok_s], mode="drop")

        hidden = jnp.einsum("ecd,edf->ecf", buf, up)
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", buf, gate)
            act = (jax.nn.silu(g) if cfg.mlp_kind == "swiglu"
                   else jax.nn.gelu(g))
            hidden = hidden * act
        else:
            hidden = jax.nn.gelu(hidden)
        out = jnp.einsum("ecf,efd->ecd", hidden, down)

        gathered = out.at[e_loc, pos_c].get(mode="fill", fill_value=0.0)
        contrib = gathered * jnp.where(keep & local, w_s, 0.0)[:, None]
        y = jnp.zeros((N, D), x_rep.dtype).at[tok_s].add(contrib)
        y = jax.lax.psum(y, e_axes)
        return y.reshape(b, T, D), aux

    gate_arg = p["experts"]["gate"] if has_gate else p["experts"]["up"]
    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), w_spec, w_spec, w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p["router"]["w"], p["experts"]["up"], gate_arg,
      p["experts"]["down"], x)

    if "shared" in p:
        y = y + _mlp_apply(p["shared"], x.reshape(-1, x.shape[-1]),
                           cfg.mlp_kind).reshape(x.shape)
    return y, aux
