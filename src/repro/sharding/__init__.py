from repro.sharding.api import (constrain, use_rules, current_rules,
                                logical_sharding, Rules, shard_map)

__all__ = ["constrain", "use_rules", "current_rules", "logical_sharding",
           "Rules", "shard_map"]
