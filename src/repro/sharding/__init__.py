from repro.sharding.api import (constrain, use_rules, current_rules,
                                logical_sharding, Rules)

__all__ = ["constrain", "use_rules", "current_rules", "logical_sharding",
           "Rules"]
