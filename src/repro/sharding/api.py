"""Logical-axis sharding: named activation/parameter axes -> mesh axes.

Model code annotates tensors with *logical* axis names
(``constrain(h, ("batch", "seq", "embed"))``); the launcher activates a rule
set mapping logical names to physical mesh axes. Outside an active rule
context every annotation is a no-op, so tests and CPU smoke runs never touch
device placement.

Rule values may be ``None`` (replicated), a mesh-axis name, or a tuple of
mesh-axis names (e.g. batch -> ("pod", "data")).
"""
from __future__ import annotations

import contextlib
import inspect
import threading
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:                      # jax < 0.6 keeps it in experimental
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """Version-portable ``shard_map``: maps the renamed replication-check
    kwarg (``check_rep`` <-> ``check_vma``) onto whatever the installed jax
    accepts. Shared by the MoE expert-parallel path and the serving
    topology layer."""
    for old, new in (("check_vma", "check_rep"), ("check_rep", "check_vma")):
        if old in kwargs and old not in _SM_PARAMS:
            kwargs[new] = kwargs.pop(old)
    if "auto" in kwargs and "auto" not in _SM_PARAMS:
        if kwargs["auto"]:
            raise NotImplementedError(
                "this jax's shard_map has no `auto` axes; "
                "tensor-parallel serving needs it")
        del kwargs["auto"]
    return _shard_map(f, **kwargs)


_STATE = threading.local()


@dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axis mapping."""
    mapping: Mapping[str, object]

    def spec(self, names: Sequence[str | None]) -> P:
        axes, used = [], set()
        for n in names:
            ax = self.mapping.get(n) if n is not None else None
            comps = (() if ax is None
                     else ((ax,) if isinstance(ax, str) else tuple(ax)))
            # a mesh axis may be consumed at most once per spec
            if comps and not (set(comps) & used):
                used.update(comps)
                axes.append(ax if isinstance(ax, str) else tuple(ax))
            else:
                axes.append(None)
        return P(*axes)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_rules():
    return getattr(_STATE, "ctx", None)


def logical_sharding(names: Sequence[str | None]):
    """NamedSharding for the active context, or None."""
    ctx = current_rules()
    if ctx is None:
        return None
    mesh, rules = ctx
    return NamedSharding(mesh, rules.spec(names))


def constrain(x, names: Sequence[str | None]):
    """with_sharding_constraint under the active rules; identity otherwise."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec(names)))
