"""Parameter/optimizer/batch sharding for the production meshes.

Strategy (baseline; §Perf iterates on it):
* tensor parallel over "model": expert dim (EP) when present, else the
  largest divisible weight dim (heads / d_ff / vocab end up there naturally);
* ZeRO-3/FSDP over "data": next largest divisible dim;
* multi-pod: pure data parallelism over "pod" (batch only) — gradients
  all-reduce over ("pod", "data");
* scanned-block leading axes and small tensors (< 64k elems) replicated.

Assignment is size-heuristic rather than name-table: every leaf gets a
valid spec for ANY architecture in the zoo, and the dry-run verifies the
composite lowers + fits. Activation rules live in ``Rules`` (api.py).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.api import Rules

REPLICATE_BELOW = 64 * 1024

# module-level switch (set by launch/dryrun for decode lowering): experts
# use the inference EP-only layout (§Perf C2)
MOE_INFERENCE_LAYOUT = False


def default_activation_rules(mesh: Mesh, shard_embed: bool = False,
                             no_tp: bool = False) -> Rules:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if no_tp:
        # small-model scheme (§Perf B1): the "model" axis becomes extra data
        # parallelism; no tensor-parallel activation collectives at all.
        dpm = tuple(dp) + ("model",)
        return Rules({"batch": dpm, "seq": None, "embed": None,
                      "vocab": None, "experts": None, "heads": None})
    return Rules({
        "batch": dp,
        "seq": None,
        "embed": "model" if shard_embed else None,
        "vocab": "model",
        "experts": "model",
        "heads": "model",
    })


def _leaf_spec(path_names, leaf, mesh: Mesh) -> P:
    """Pick a PartitionSpec for one parameter leaf."""
    dims = list(leaf.shape)
    n = len(dims)
    model_n = mesh.shape["model"]
    data_n = mesh.shape["data"]

    in_blocks = path_names and path_names[0] == "blocks"
    start = 1 if in_blocks else 0          # never shard the scan axis

    # MoE: specs must match the shard_map contract (moe_shard.py):
    # router replicated, experts P('model' on E, 'data' on dim1).
    if "router" in path_names:
        return P()
    if "experts" in path_names:
        spec = [None] * n
        if MOE_INFERENCE_LAYOUT:
            # §Perf C2: full EP — experts E-wise over both axes, no FSDP
            if dims[start] % (model_n * data_n) == 0:
                spec[start] = ("model", "data")
            elif dims[start] % model_n == 0:
                spec[start] = "model"
            return P(*spec)
        if dims[start] % model_n == 0:
            spec[start] = "model"
        if n - start >= 2 and dims[start + 1] % data_n == 0:
            spec[start + 1] = "data"
        return P(*spec)

    if np.prod(dims, initial=1) < REPLICATE_BELOW:
        return P()

    spec = [None] * n
    used_dims = set()

    # 1) "model" axis (tensor parallel): largest divisible dim
    for i in sorted(range(start, n), key=lambda i: -dims[i]):
        if dims[i] % model_n == 0:
            spec[i] = "model"
            used_dims.add(i)
            break

    # 2) "data" axis (FSDP): largest remaining divisible dim
    for i in sorted(range(start, n), key=lambda i: -dims[i]):
        if i not in used_dims and dims[i] % data_n == 0:
            spec[i] = "data"
            break

    return P(*spec)


def param_shardings(params_shape, mesh: Mesh, no_tp: bool = False):
    """Pytree of NamedShardings mirroring a params (or opt-state) pytree of
    ShapeDtypeStructs/arrays. ``no_tp``: FSDP over all mesh axes instead of
    TP over "model" (small-model scheme, §Perf B1)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)

    def names_of(path):
        out = []
        for k in path:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
        return out

    leaf_fn = _leaf_spec_no_tp if no_tp else _leaf_spec
    specs = [NamedSharding(mesh, leaf_fn(names_of(p), l, mesh))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _leaf_spec_no_tp(path_names, leaf, mesh: Mesh) -> P:
    """FSDP-only: shard the largest divisible dim over ALL mesh axes
    (("pod",)"data","model" flattened); small leaves replicated."""
    dims = list(leaf.shape)
    n = len(dims)
    if np.prod(dims, initial=1) < REPLICATE_BELOW:
        return P()
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    in_blocks = path_names and path_names[0] == "blocks"
    start = 1 if in_blocks else 0
    spec = [None] * n
    for i in sorted(range(start, n), key=lambda i: -dims[i]):
        if dims[i] % total == 0:
            spec[i] = axes
            return P(*spec)
    for i in sorted(range(start, n), key=lambda i: -dims[i]):
        if dims[i] % mesh.shape["data"] == 0:
            spec[i] = "data"
            return P(*spec)
    return P(*spec)


def _cache_leaf_spec(path_names, leaf, mesh: Mesh, batch: int) -> P:
    """KV caches / recurrent states: batch over dp when divisible, "model"
    over the largest remaining divisible dim (head_dim / kv_lora / state)."""
    dims = list(leaf.shape)
    n = len(dims)
    model_n = mesh.shape["model"]
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp_size = 1
    for ax in dp:
        dp_size *= mesh.shape[ax]
    in_blocks = path_names and path_names[0] == "blocks"
    start = 1 if in_blocks else 0
    spec = [None] * n
    # batch axis: first dim of size `batch` after the optional scan axis
    b_dim = None
    for i in range(start, n):
        if dims[i] == batch:
            b_dim = i
            break
    if b_dim is not None and batch % dp_size == 0:
        spec[b_dim] = dp if len(dp) > 1 else dp[0]
    for i in sorted(range(start, n), key=lambda i: -dims[i]):
        if i != b_dim and spec[i] is None and dims[i] % model_n == 0:
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(cache_shape, mesh: Mesh, batch: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)

    def names_of(path):
        return [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]

    specs = [NamedSharding(mesh, _cache_leaf_spec(names_of(p), l, mesh, batch))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def paged_cache_shardings(cfg, paged, mesh: Mesh, data_axis: str = "data"):
    """NamedShardings for the serving engine's paged cache pytree: each
    leaf's pool dim (attention blocks) or slot dim (recurrent states) over
    ``data_axis`` — the placement that matches the mesh round's shard_map
    specs (``TransformerLM.paged_partition_specs``), so the jitted round
    never reshards the pool. The ``model`` axis is deliberately left off the
    pool: KV heads stay shard-local and tensor parallelism enters only via
    the (auto-sharded) params."""
    from repro.models.transformer import TransformerLM

    specs = TransformerLM.paged_partition_specs(cfg, paged,
                                                data_axis=data_axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def decode_activation_rules(mesh: Mesh) -> Rules:
    """Activation rules for the serving/decode path: verify-window rows over
    data parallelism, heads/vocab over "model" (the GSPMD lowering used by
    ``make_serve_step`` dry-runs; the mesh ``ServingEngine`` is manual over
    "data" instead and never consults activation rules on its hot path)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return Rules({
        "batch": dp,
        "seq": None,
        "embed": None,
        "vocab": "model",
        "experts": "model",
        "heads": "model",
    })


def _strip_axes(spec: P, drop: tuple) -> P:
    """Remove the given mesh axes from every component of a PartitionSpec."""
    out = []
    for comp in spec:
        if comp is None:
            out.append(None)
            continue
        axes = (comp,) if isinstance(comp, str) else tuple(comp)
        kept = tuple(a for a in axes if a not in drop)
        out.append(None if not kept else
                   (kept[0] if len(kept) == 1 else kept))
    return P(*out)


def serving_param_shardings(params_shape, mesh: Mesh):
    """``param_shardings`` minus the FSDP/data axes: the mesh serving round
    is *manual* over "data" (every data shard needs the full params — an
    FSDP-sharded leaf would force an all-gather into the round hot path), so
    only tensor parallelism over "model" survives; everything else is
    replicated."""
    drop = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)

    def names_of(path):
        out = []
        for k in path:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
        return out

    specs = [NamedSharding(mesh, _strip_axes(_leaf_spec(names_of(p), l, mesh),
                                             drop))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_sharding(mesh: Mesh, no_tp: bool = False):
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if no_tp:
        dp = tuple(dp) + ("model",)
    return NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
