"""Pallas TPU kernels: paged flash-decode with a fused window-writeback
epilogue — attend through block tables AND commit the window K/V, in one
dispatch.

The serving runtime stores attention K/V in fixed-size blocks of a shared
physical pool (``TransformerLM.init_paged_cache``); each sequence owns a
block table mapping logical block ``j`` to a physical pool id. PR 2 made the
verify round attend *through* the tables; it still paid a standalone O(B*W)
``write_window_paged`` scatter before each pallas_call to land the W fresh
window keys/values in their blocks. This kernel fuses that write into the
kernel itself, so one pallas_call per layer both reads the pool and commits
the window (DESIGN.md §11):

grid = (B, KV, nb): per (sequence, kv-head), logical KV blocks stream
sequentially. The per-sequence block table and valid lengths ride in SMEM via
scalar prefetch, so the K/V BlockSpec index_map resolves ``table[b, j]``
before each tile's DMA — the pool is read once, block-granular, and no dense
view ever exists. Online-softmax state for all G*W rows (G grouped query
heads x W window queries) lives in VMEM scratch, exactly like the dense
``decode_attention`` kernel.

Fused writeback (the epilogue):

* The W fresh K/V rows arrive as small ``(B, W, ...)`` inputs instead of
  being pre-scattered into the pool. Each tile is **merged** on the fly:
  slot ``t`` of block ``j`` takes ``new[j*bs + t - length]`` when its
  logical position falls in ``[length, length + W)`` and the pool value
  otherwise (a W-way unrolled select — bitwise equal to the gather the
  scatter used to do). Attention runs over the merged tile.
* The pools are **outputs input/output-aliased with the pool inputs**: the
  out BlockSpec index_map routes window-straddling tiles to their physical
  block (``table[b, j]``) and every other tile to the reserved sink block 0,
  so per-round pool *writes* stay O(B*W) — only the straddle blocks (and
  cheap sink dumps) are flushed, and every unvisited block keeps its
  contents through the aliasing. Interpret mode initializes aliased outputs
  from the input arrays, so CPU CI sees identical semantics.
* Each (b, h) visits each logical block once, window blocks are
  sequence-private (shared prefix blocks always sit strictly below the
  window span) and different kv heads touch disjoint tile slices, so the
  only physical block written by more than one grid step is the sink —
  whose contents are garbage by design. That makes the in-place aliasing
  race-free on TPU.

Masking handles the two paged-specific hazards:

* **Tail blocks** — table entries past a sequence's allocation point at the
  reserved sink block 0; their *logical* positions ``j*bs + t`` exceed
  ``length + W - 1`` so the causal mask ``k_pos <= q_pos`` zeroes them (the
  pool is always initialized/written memory — no NaN risk, unlike the dense
  kernel's out-of-bounds tail tiles).
* **Window keys** — merged from the ``new`` operands as above; query w sees
  keys ``<= length + w`` through the same table indirection as the prefix.

``latent=True`` is the MLA variant: scores are the sum of two inner products
(absorbed-latent query vs the c_kv pool, rope query vs the shared rope-key
pool) and the value *is* the merged c_kv tile — one pool read serves both
matmuls; both latent pools get the fused writeback.

``paged_write_kernel`` is the writeback epilogue alone — grid (B, T) over
just the blocks a W-wide span can straddle — used by the CPU-exact gather
fallback and the legacy dense round's ``scatter_paged`` so every pool write
path shares the same aliased, in-place commit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _merge_window(tile, new_rows, off, valid, W: int):
    """Select window rows into a pool tile: slot t takes ``new_rows[off[t]]``
    where ``0 <= off[t] < W`` (and ``valid``), else keeps ``tile[t]``.
    Unrolled W-way select — bitwise equal to the reference scatter, and
    lowers to plain vector selects on TPU (no dynamic gather)."""
    shaped = off.reshape((off.shape[0],) + (1,) * (tile.ndim - 1))
    merged = tile
    for w in range(W):
        take = (shaped == w) & valid
        merged = jnp.where(take, new_rows[w][None], merged)
    return merged


def _paged_kernel(tbl_ref, len_ref, *refs, bs: int, scale: float,
                  window: int, W: int, latent: bool):
    if latent:
        (q1_ref, q2_ref, k1_ref, k2_ref, n1_ref, n2_ref,
         o_ref, ok1_ref, ok2_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (q1_ref, k1_ref, v_ref, n1_ref, n2_ref,
         o_ref, ok1_ref, ok2_ref, m_ref, l_ref, acc_ref) = refs
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    base = len_ref[b]                                     # valid cache length

    # ---- fused window-writeback epilogue -------------------------------
    # Merge the W fresh rows into this tile at their in-block offsets and
    # write the merged tile to the aliased pool outputs. The out index_map
    # routes non-straddling tiles to the sink, so only the O(W) window
    # blocks are really committed; writing unconditionally keeps the out
    # VMEM buffer coherent with whatever block the emission targets.
    off = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0] \
        - base                                            # (bs,)
    k_tile = k1_ref[0, :, 0, :]                           # (bs, dk) raw dtype
    kn = n1_ref[0, :, 0, :]                               # (W, dk)
    k_merged = _merge_window(k_tile, kn, off, True, W)
    ok1_ref[0, :, 0, :] = k_merged
    if latent:
        k2_tile = k2_ref[0, :, 0, :]
        k2n = n2_ref[0, :, 0, :]
        k2_merged = _merge_window(k2_tile, k2n, off, True, W)
        ok2_ref[0, :, 0, :] = k2_merged
        v_merged = k_merged                               # c_kv doubles as V
    else:
        v_tile = v_ref[0, :, 0, :]
        vn = n2_ref[0, :, 0, :]
        v_merged = _merge_window(v_tile, vn, off, True, W)
        ok2_ref[0, :, 0, :] = v_merged
        k2_merged = None

    # skip fully-masked tiles outright: tail tiles past the last query
    # position (sink-aliased table entries) and, under a sliding window,
    # tiles wholly below the earliest visible key. A skipped tile's update
    # is the identity (p = 0, alpha = 1), so skipping is bitwise-neutral —
    # per-round compute tracks the *used* blocks, not the table width.
    visible = j * bs <= base + W - 1
    if window > 0:
        visible &= (j + 1) * bs > base - window + 1

    @pl.when(visible)
    def _tile():
        q = q1_ref[0, 0].astype(jnp.float32)              # (R, dk) R = G*W
        k = k_merged.astype(jnp.float32)                  # (bs, dk)
        R = q.shape[0]
        s = (q @ k.T) * scale                             # (R, bs)
        if latent:
            q2 = q2_ref[0, 0].astype(jnp.float32)         # (R, dr)
            k2 = k2_merged.astype(jnp.float32)            # (bs, dr)
            s += (q2 @ k2.T) * scale

        # row r serves window query w = r % W (G heads share a kv head)
        q_pos = base + jax.lax.broadcasted_iota(jnp.int32, (R, bs), 0) % W
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (R, bs), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        v = v_merged.astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _pool_out_map(bs: int, W: int):
    """Out index_map for an aliased pool output: window-straddling tiles go
    to their physical block, everything else to the reserved sink 0 (whose
    contents are garbage by design) — pool writes stay O(B*W) per round."""
    def index_map(b, h, j, tbl, ln):
        base = ln[b]
        straddle = (j * bs <= base + W - 1) & ((j + 1) * bs > base)
        return (jnp.where(straddle, tbl[b, j], 0), 0, h, 0)
    return index_map


@functools.partial(jax.jit, static_argnames=("W", "window", "scale",
                                             "interpret"))
def paged_decode_kernel(q, k_pool, v_pool, k_new, v_new, tables, lengths, *,
                        W: int, window: int = 0, scale: float | None = None,
                        interpret: bool = True):
    """q: (B, KV, G*W, d) grouped window queries (row = g*W + w); k_pool,
    v_pool: (P, bs, KV, d) physical block pools (window positions stale —
    the kernel commits them); k_new, v_new: (B, W, KV, d) fresh window rows;
    tables: (B, nb) physical block ids; lengths: (B,) valid prefix lengths.
    Query w attends keys < lengths + w + 1. Returns (out (B, KV, G*W, dv),
    k_pool, v_pool) with the pools updated in place (aliased)."""
    B, KV, R, dk = q.shape
    P, bs = k_pool.shape[:2]
    nb = tables.shape[1]
    dv = v_pool.shape[-1]
    if scale is None:
        scale = 1.0 / dk ** 0.5

    pool_map = _pool_out_map(bs, W)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, R, dk), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dv),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, W, 1, dk), lambda b, h, j, tbl, ln: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, dv), lambda b, h, j, tbl, ln: (b, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, dv),
                         lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk), pool_map),
            pl.BlockSpec((1, bs, 1, dv), pool_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, window=window,
                          W=W, latent=False),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, R, dv), q.dtype),
                   jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)],
        # flat operands: (tables, lengths, q, k_pool, v_pool, k_new, v_new)
        input_output_aliases={3: 1, 4: 2},
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool,
      k_new, v_new)


@functools.partial(jax.jit, static_argnames=("W", "scale", "interpret"))
def paged_latent_kernel(q_lat, q_rope, c_pool, kr_pool, c_new, kr_new,
                        tables, lengths, *, W: int, scale: float,
                        interpret: bool = True):
    """MLA absorbed-latent variant: q_lat: (B, 1, H*W, r); q_rope:
    (B, 1, H*W, dr); c_pool: (P, bs, 1, r); kr_pool: (P, bs, 1, dr); c_new,
    kr_new: (B, W, 1, r/dr) fresh window latents. Scores sum both inner
    products; the output is the attention-weighted *latent* (B, 1, H*W, r) —
    the merged c_kv tile doubles as the value. Returns (out, c_pool,
    kr_pool) with both latent pools committed in place (aliased)."""
    B, _, R, r = q_lat.shape
    P, bs = c_pool.shape[:2]
    dr = q_rope.shape[-1]
    nb = tables.shape[1]

    pool_map = _pool_out_map(bs, W)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, 1, nb),
        in_specs=[
            pl.BlockSpec((1, 1, R, r), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, R, dr), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, r),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dr),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, W, 1, r), lambda b, h, j, tbl, ln: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, dr), lambda b, h, j, tbl, ln: (b, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, r), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, r), pool_map),
            pl.BlockSpec((1, bs, 1, dr), pool_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, window=0,
                          W=W, latent=True),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, 1, R, r), q_lat.dtype),
                   jax.ShapeDtypeStruct(c_pool.shape, c_pool.dtype),
                   jax.ShapeDtypeStruct(kr_pool.shape, kr_pool.dtype)],
        # flat operands: (tbl, len, q_lat, q_rope, c_pool, kr_pool, c_new,
        #                 kr_new)
        input_output_aliases={4: 1, 5: 2},
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q_lat, q_rope, c_pool, kr_pool, c_new, kr_new)


# ---------------------------------------------------------------------------
# Standalone aliased writeback: the epilogue without the attention
# ---------------------------------------------------------------------------

def _write_kernel_body(tbl_ref, st_ref, act_ref, pool_ref, new_ref, out_ref,
                       *, bs: int, W: int, nb: int):
    b = pl.program_id(0)
    t = pl.program_id(1)
    start = st_ref[b]
    blk = start // bs + t
    last = (start + W - 1) // bs
    valid = (blk < nb) & (blk <= last) & (act_ref[b] > 0)
    off = blk * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0] \
        - start
    out_ref[0] = _merge_window(pool_ref[0], new_ref[0], off, valid, W)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_write_kernel(pool, new, tables, start, active, *,
                       interpret: bool = True):
    """Aliased window writeback: commit ``new (B, W, ...)`` into the pool
    ``(P, bs, ...)`` at per-sequence offsets ``start (B,)`` resolved through
    ``tables (B, nb)``. grid = (B, T) visits only the T blocks a W-wide span
    can straddle; the pool is input/output-aliased so unvisited blocks keep
    their contents and the commit happens in place (no full-pool temp on the
    donated buffer). Rows with ``active == 0`` (and out-of-table slots) are
    routed to the reserved sink block 0 where the write degenerates to a
    value-preserving self-copy."""
    P, bs = pool.shape[:2]
    B, W = new.shape[:2]
    nb = tables.shape[1]
    T = (W + bs - 2) // bs + 1          # max blocks a W-wide span straddles
    trail = pool.shape[2:]
    nd = len(trail)

    def pool_map(b, t, tbl, st, act):
        blk = st[b] // bs + t
        last = (st[b] + W - 1) // bs
        valid = (blk < nb) & (blk <= last) & (act[b] > 0)
        phys = jnp.where(valid, tbl[b, jnp.clip(blk, 0, nb - 1)], 0)
        return (phys,) + (0,) * (nd + 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, bs) + trail, pool_map),
            pl.BlockSpec((1, W) + trail,
                         lambda b, t, tbl, st, act: (b,) + (0,) * (nd + 1)),
        ],
        out_specs=pl.BlockSpec((1, bs) + trail, pool_map),
    )
    return pl.pallas_call(
        functools.partial(_write_kernel_body, bs=bs, W=W, nb=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # flat operands: (tables, start, active, pool, new)
        input_output_aliases={3: 0},
        interpret=interpret,
    )(tables.astype(jnp.int32), start.astype(jnp.int32),
      active.astype(jnp.int32), pool, new)
