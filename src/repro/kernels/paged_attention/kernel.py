"""Pallas TPU kernel: paged flash-decode — attend through block tables.

The serving runtime stores attention K/V in fixed-size blocks of a shared
physical pool (``TransformerLM.init_paged_cache``); each sequence owns a
block table mapping logical block ``j`` to a physical pool id. The dense
engine round used to materialize a contiguous per-sequence K/V view
(``gather_paged``), attend, and scatter the window back — an O(B*S*d) HBM
round-trip wrapping a bandwidth-bound op. This kernel attends *in place*:

grid = (B, KV, nb): per (sequence, kv-head), logical KV blocks stream
sequentially. The per-sequence block table and valid lengths ride in SMEM via
scalar prefetch, so the K/V BlockSpec index_map resolves ``table[b, j]``
before each tile's DMA — the pool is read once, block-granular, and no dense
view ever exists. Online-softmax state for all G*W rows (G grouped query
heads x W window queries) lives in VMEM scratch, exactly like the dense
``decode_attention`` kernel.

Masking handles the two paged-specific hazards:

* **Tail blocks** — table entries past a sequence's allocation point at the
  reserved sink block 0; their *logical* positions ``j*bs + t`` exceed
  ``length + W - 1`` so the causal mask ``k_pos <= q_pos`` zeroes them (the
  pool is always initialized/written memory — no NaN risk, unlike the dense
  kernel's out-of-bounds tail tiles).
* **Window keys** — the W fresh keys are written into their physical blocks
  *before* the kernel runs (``write_window_paged``), so query w sees keys
  ``<= length + w`` through the same table indirection as the prefix.

``latent=True`` is the MLA variant: scores are the sum of two inner products
(absorbed-latent query vs the c_kv pool, rope query vs the shared rope-key
pool) and the value *is* the c_kv tile — one pool read serves both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _paged_kernel(tbl_ref, len_ref, *refs, bs: int, scale: float,
                  window: int, W: int, latent: bool):
    if latent:
        q1_ref, q2_ref, k1_ref, k2_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        q1_ref, k1_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    base = len_ref[b]                                     # valid cache length
    # skip fully-masked tiles outright: tail tiles past the last query
    # position (sink-aliased table entries) and, under a sliding window,
    # tiles wholly below the earliest visible key. A skipped tile's update
    # is the identity (p = 0, alpha = 1), so skipping is bitwise-neutral —
    # per-round compute tracks the *used* blocks, not the table width.
    visible = j * bs <= base + W - 1
    if window > 0:
        visible &= (j + 1) * bs > base - window + 1

    @pl.when(visible)
    def _tile():
        q = q1_ref[0, 0].astype(jnp.float32)              # (R, dk) R = G*W
        k = k1_ref[0, :, 0, :].astype(jnp.float32)        # (bs, dk)
        R = q.shape[0]
        s = (q @ k.T) * scale                             # (R, bs)
        if latent:
            q2 = q2_ref[0, 0].astype(jnp.float32)         # (R, dr)
            k2 = k2_ref[0, :, 0, :].astype(jnp.float32)   # (bs, dr)
            s += (q2 @ k2.T) * scale

        # row r serves window query w = r % W (G heads share a kv head)
        q_pos = base + jax.lax.broadcasted_iota(jnp.int32, (R, bs), 0) % W
        k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (R, bs), 1)
        mask = k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        v = k if latent else v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("W", "window", "scale",
                                             "interpret"))
def paged_decode_kernel(q, k_pool, v_pool, tables, lengths, *, W: int,
                        window: int = 0, scale: float | None = None,
                        interpret: bool = True):
    """q: (B, KV, G*W, d) grouped window queries (row = g*W + w); k_pool,
    v_pool: (P, bs, KV, d) physical block pools (window keys already written
    at positions lengths..lengths+W-1 through the tables); tables: (B, nb)
    physical block ids; lengths: (B,) valid prefix lengths. Query w attends
    keys < lengths + w + 1. Returns (B, KV, G*W, dv)."""
    B, KV, R, dk = q.shape
    P, bs = k_pool.shape[:2]
    nb = tables.shape[1]
    dv = v_pool.shape[-1]
    if scale is None:
        scale = 1.0 / dk ** 0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, R, dk), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dk),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dv),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, dv),
                               lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, window=window,
                          W=W, latent=False),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, R, dv), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=("W", "scale", "interpret"))
def paged_latent_kernel(q_lat, q_rope, c_pool, kr_pool, tables, lengths, *,
                        W: int, scale: float, interpret: bool = True):
    """MLA absorbed-latent variant: q_lat: (B, 1, H*W, r); q_rope:
    (B, 1, H*W, dr); c_pool: (P, bs, 1, r); kr_pool: (P, bs, 1, dr). Scores
    sum both inner products; the output is the attention-weighted *latent*
    (B, 1, H*W, r) — the shared c_kv tile doubles as the value."""
    B, _, R, r = q_lat.shape
    P, bs = c_pool.shape[:2]
    dr = q_rope.shape[-1]
    nb = tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, 1, nb),
        in_specs=[
            pl.BlockSpec((1, 1, R, r), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, R, dr), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, r),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dr),
                         lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, r),
                               lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R,), jnp.float32),
            pltpu.VMEM((R, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=scale, window=0,
                          W=W, latent=True),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, R, r), q_lat.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q_lat, q_rope, c_pool, kr_pool)
