"""Jit'd public paged-attention ops (GQA row grouping, MLA latent variant).

Unlike the dense ``decode_attention`` wrapper, GQA is handled by *grouping*
query heads onto their kv head (row = g*W + w) instead of ``jnp.repeat`` on
the cache — the pool is never expanded or copied. The kernel streams physical
blocks through the per-sequence table; the ref gathers the dense view (the
CPU oracle / fallback shape).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.paged_attention.kernel import (paged_decode_kernel,
                                                 paged_latent_kernel)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                              paged_latent_ref)


def paged_attention(q, k_pool, v_pool, tables, lengths, window: int = 0,
                    use_kernel: bool = True, interpret: bool | None = None):
    """q: (B, W, H, d) window queries; k_pool/v_pool: (P, bs, KV, d) physical
    block pools with the window keys already written through ``tables``;
    tables: (B, nb); lengths: (B,). Returns (B, W, H, d)."""
    B, W, H, d = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    if not use_kernel:
        return paged_attention_ref(q, k_pool, v_pool, tables, lengths,
                                   window=window)
    qg = (q.reshape(B, W, KV, G, d)
          .transpose(0, 2, 3, 1, 4)          # (B, KV, G, W, d): row = g*W + w
          .reshape(B, KV, G * W, d))
    out = paged_decode_kernel(qg, k_pool, v_pool, tables, lengths, W=W,
                              window=window,
                              interpret=resolve_interpret(interpret))
    return (out.reshape(B, KV, G, W, d)
            .transpose(0, 3, 1, 2, 4)
            .reshape(B, W, H, d))


def paged_latent_attention(q_lat, q_rope, c_pool, kr_pool, tables, lengths,
                           scale: float, use_kernel: bool = True,
                           interpret: bool | None = None):
    """MLA absorbed-matrix decode over the latent pools. q_lat: (B, W, H, r);
    q_rope: (B, W, H, dr); c_pool: (P, bs, r); kr_pool: (P, bs, dr). Returns
    the attention-weighted latent (B, W, H, r) — the caller applies W_uv/W_o.
    """
    B, W, H, r = q_lat.shape
    dr = q_rope.shape[-1]
    if not use_kernel:
        return paged_latent_ref(q_lat, q_rope, c_pool, kr_pool, tables,
                                lengths, scale=scale)
    # all H heads share the single latent "kv head": rows = h*W + w
    ql = q_lat.transpose(0, 2, 1, 3).reshape(B, 1, H * W, r)
    qr = q_rope.transpose(0, 2, 1, 3).reshape(B, 1, H * W, dr)
    out = paged_latent_kernel(ql, qr, c_pool[:, :, None, :],
                              kr_pool[:, :, None, :], tables, lengths,
                              W=W, scale=scale,
                              interpret=resolve_interpret(interpret))
    return out.reshape(B, H, W, r).transpose(0, 2, 1, 3)
