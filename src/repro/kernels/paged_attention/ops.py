"""Jit'd public paged-attention ops (GQA row grouping, MLA latent variant)
with the fused window-writeback epilogue.

Unlike the dense ``decode_attention`` wrapper, GQA is handled by *grouping*
query heads onto their kv head (row = g*W + w) instead of ``jnp.repeat`` on
the cache — the pool is never expanded or copied. Every op takes the W
fresh window rows as separate small operands and returns the updated pools
next to the attention output: the kernel streams physical blocks through
the per-sequence table and commits the window rows into their destination
blocks as aliased outputs (one dispatch — no standalone scatter before the
pallas_call); the ref composes the reference scatter with the gathered
dense view (the CPU oracle shape).

``paged_window_write`` is the writeback alone — the same aliased, in-place
commit used by the CPU-exact gather fallback and the legacy dense round's
``scatter_paged``, so *every* pool write path shares one implementation and
one donation story.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.paged_attention.kernel import (paged_decode_kernel,
                                                 paged_latent_kernel,
                                                 paged_write_kernel)
from repro.kernels.paged_attention.ref import (paged_attention_fused_ref,
                                              paged_latent_fused_ref)


def paged_attention(q, k_pool, v_pool, k_new, v_new, tables, lengths,
                    window: int = 0, use_kernel: bool = True,
                    interpret: bool | None = None):
    """q: (B, W, H, d) window queries; k_pool/v_pool: (P, bs, KV, d) physical
    block pools (window positions stale — committed here); k_new/v_new:
    (B, W, KV, d) fresh window rows; tables: (B, nb); lengths: (B,).
    Returns (out (B, W, H, d), k_pool, v_pool) with the window rows written
    through the tables (fused kernel epilogue, or the reference scatter on
    the ref path)."""
    B, W, H, d = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    if not use_kernel:
        return paged_attention_fused_ref(q, k_pool, v_pool, k_new, v_new,
                                         tables, lengths, window=window)
    qg = (q.reshape(B, W, KV, G, d)
          .transpose(0, 2, 3, 1, 4)          # (B, KV, G, W, d): row = g*W + w
          .reshape(B, KV, G * W, d))
    out, k_pool, v_pool = paged_decode_kernel(
        qg, k_pool, v_pool, k_new, v_new, tables, lengths, W=W,
        window=window, interpret=resolve_interpret(interpret))
    out = (out.reshape(B, KV, G, W, d)
           .transpose(0, 3, 1, 2, 4)
           .reshape(B, W, H, d))
    return out, k_pool, v_pool


def paged_latent_attention(q_lat, q_rope, c_pool, kr_pool, c_new, kr_new,
                           tables, lengths, scale: float,
                           use_kernel: bool = True,
                           interpret: bool | None = None):
    """MLA absorbed-matrix decode over the latent pools. q_lat: (B, W, H, r);
    q_rope: (B, W, H, dr); c_pool: (P, bs, r); kr_pool: (P, bs, dr); c_new:
    (B, W, r); kr_new: (B, W, dr) fresh window latents. Returns (ctx
    (B, W, H, r), c_pool, kr_pool) — the attention-weighted latent (the
    caller applies W_uv/W_o) plus both pools with the window committed."""
    B, W, H, r = q_lat.shape
    dr = q_rope.shape[-1]
    if not use_kernel:
        return paged_latent_fused_ref(q_lat, q_rope, c_pool, kr_pool,
                                      c_new, kr_new, tables, lengths,
                                      scale=scale)
    # all H heads share the single latent "kv head": rows = h*W + w
    ql = q_lat.transpose(0, 2, 1, 3).reshape(B, 1, H * W, r)
    qr = q_rope.transpose(0, 2, 1, 3).reshape(B, 1, H * W, dr)
    out, c4, kr4 = paged_latent_kernel(
        ql, qr, c_pool[:, :, None, :], kr_pool[:, :, None, :],
        c_new[:, :, None, :], kr_new[:, :, None, :], tables, lengths,
        W=W, scale=scale, interpret=resolve_interpret(interpret))
    out = out.reshape(B, H, W, r).transpose(0, 2, 1, 3)
    return out, c4[:, :, 0, :], kr4[:, :, 0, :]


def paged_window_write(pool, new, tables, start, active=None,
                       interpret: bool | None = None):
    """Standalone aliased window writeback (the fused epilogue without the
    attention): commit ``new (B, W, ...)`` into ``pool (P, bs, ...)`` at
    offsets ``start (B,)`` through ``tables (B, nb)``, in place. Rows with
    ``active == False`` are routed to the reserved sink block 0. Used by the
    CPU-exact gather fallback and the legacy dense round's scatter so
    donation semantics are uniform across every pool write path."""
    if active is None:
        active = jnp.ones(new.shape[:1], jnp.int32)
    return paged_write_kernel(pool, new, tables, start, active,
                              interpret=resolve_interpret(interpret))
