"""Pure-jnp oracles for the paged flash-decode kernel.

Each ref gathers the dense per-sequence view through the block table (the
very copy the kernel exists to avoid) and runs the plain-softmax decode
math — the correctness anchor for the property sweeps, shared with
``decode_attention_ref`` semantics: query w attends keys <= lengths + w.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_view(pool, tables):
    """pool: (P, bs, ...) physical blocks; tables: (B, nb). Returns the dense
    (B, nb*bs, ...) per-sequence view (exactly what ``gather_paged`` builds
    per attention leaf)."""
    g = pool[tables]                                     # (B, nb, bs, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_attention_ref(q, k_pool, v_pool, tables, lengths, window: int = 0):
    """q: (B, W, H, d); k_pool/v_pool: (P, bs, KV, d); tables: (B, nb);
    lengths: (B,). Returns (B, W, H, d)."""
    B, W, H, d = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    k = gather_view(k_pool, tables)                      # (B, S, KV, d)
    v = gather_view(v_pool, tables)
    S = k.shape[1]
    qg = q.reshape(B, W, KV, G, d)
    s = jnp.einsum("bwkgd,bskd->bkgws", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qp = lengths[:, None, None, None, None] + jnp.arange(W)[None, None, None,
                                                            :, None]
    kp = jnp.arange(S)[None, None, None, None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > (qp - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgws,bskd->bwkgd", p, v.astype(jnp.float32))
    return out.reshape(B, W, H, d).astype(q.dtype)


def paged_latent_ref(q_lat, q_rope, c_pool, kr_pool, tables, lengths, *,
                     scale: float):
    """q_lat: (B, W, H, r); q_rope: (B, W, H, dr); c_pool: (P, bs, r);
    kr_pool: (P, bs, dr). Returns the latent context (B, W, H, r)."""
    B, W, H, r = q_lat.shape
    c = gather_view(c_pool, tables)                      # (B, S, r)
    kr = gather_view(kr_pool, tables)                    # (B, S, dr)
    S = c.shape[1]
    s = (jnp.einsum("bwhr,bsr->bhws", q_lat.astype(jnp.float32),
                    c.astype(jnp.float32))
         + jnp.einsum("bwhd,bsd->bhws", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    qp = lengths[:, None, None, None] + jnp.arange(W)[None, None, :, None]
    kp = jnp.arange(S)[None, None, None, :]
    s = jnp.where(kp <= qp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhws,bsr->bwhr", p, c.astype(jnp.float32))
    return out.astype(q_lat.dtype)
