"""Pure-jnp oracles for the paged flash-decode kernel and its fused
window-writeback epilogue.

Each attention ref gathers the dense per-sequence view through the block
table (the very copy the kernel exists to avoid) and runs the plain-softmax
decode math — the correctness anchor for the property sweeps, shared with
``decode_attention_ref`` semantics: query w attends keys <= lengths + w.

``write_window_paged`` is the *separate scatter* the fused epilogue
replaces: the standalone O(B*W) ``.at[].set`` at table-resolved offsets.
It survives here as the bitwise reference the fused kernel (and the aliased
``paged_write_kernel``) must reproduce exactly — asserted by the hypothesis
sweeps in tests/kernels and tests/models. The ``*_fused_ref`` helpers
compose it with the attention refs to oracle the full fused op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_view(pool, tables):
    """pool: (P, bs, ...) physical blocks; tables: (B, nb). Returns the dense
    (B, nb*bs, ...) per-sequence view (exactly what ``gather_paged`` builds
    per attention leaf)."""
    g = pool[tables]                                     # (B, nb, bs, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def write_window_paged(pool, new, tables, cache_len, active=None):
    """Reference window writeback: W new entries into the *physical block
    pool* at per-sequence offsets resolved through the block table — the
    standalone scatter the fused kernel epilogue replaces, touching O(B*W)
    rows instead of a dense cache.

    pool: (P, bs, ...); new: (B, W, ...); tables: (B, nb); cache_len: (B,).
    Positions past a row's table (cleared slots: table all-zero), and every
    position of rows with ``active == False``, land in the reserved sink
    block 0, whose contents are garbage by design.
    """
    P, bs = pool.shape[:2]
    B, W = new.shape[:2]
    nb = tables.shape[1]
    pos = cache_len[:, None] + jnp.arange(W)[None, :]        # (B, W)
    blk = pos // bs
    phys = jnp.take_along_axis(tables, jnp.clip(blk, 0, nb - 1), axis=1)
    ok = (blk >= 0) & (blk < nb)
    if active is not None:
        ok &= active[:, None]
    phys = jnp.where(ok, phys, 0)
    flat_idx = (phys * bs + pos % bs).reshape(-1)            # (B*W,)
    flat = pool.reshape((P * bs,) + pool.shape[2:])
    flat = flat.at[flat_idx].set(new.reshape((B * W,) + new.shape[2:]))
    return flat.reshape(pool.shape)


def paged_attention_ref(q, k_pool, v_pool, tables, lengths, window: int = 0):
    """Attend-only oracle over pools whose window keys are already written.
    q: (B, W, H, d); k_pool/v_pool: (P, bs, KV, d); tables: (B, nb);
    lengths: (B,). Returns (B, W, H, d)."""
    B, W, H, d = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    k = gather_view(k_pool, tables)                      # (B, S, KV, d)
    v = gather_view(v_pool, tables)
    S = k.shape[1]
    qg = q.reshape(B, W, KV, G, d)
    s = jnp.einsum("bwkgd,bskd->bkgws", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qp = lengths[:, None, None, None, None] + jnp.arange(W)[None, None, None,
                                                            :, None]
    kp = jnp.arange(S)[None, None, None, None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > (qp - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgws,bskd->bwkgd", p, v.astype(jnp.float32))
    return out.reshape(B, W, H, d).astype(q.dtype)


def paged_attention_fused_ref(q, k_pool, v_pool, k_new, v_new, tables,
                              lengths, window: int = 0):
    """Fused-op oracle: commit the window rows with the reference scatter,
    then attend — returns (out, k_pool, v_pool) like the fused kernel."""
    k_pool = write_window_paged(k_pool, k_new, tables, lengths)
    v_pool = write_window_paged(v_pool, v_new, tables, lengths)
    out = paged_attention_ref(q, k_pool, v_pool, tables, lengths,
                              window=window)
    return out, k_pool, v_pool


def paged_latent_ref(q_lat, q_rope, c_pool, kr_pool, tables, lengths, *,
                     scale: float):
    """Attend-only MLA oracle. q_lat: (B, W, H, r); q_rope: (B, W, H, dr);
    c_pool: (P, bs, r); kr_pool: (P, bs, dr). Returns the latent context
    (B, W, H, r)."""
    B, W, H, r = q_lat.shape
    c = gather_view(c_pool, tables)                      # (B, S, r)
    kr = gather_view(kr_pool, tables)                    # (B, S, dr)
    S = c.shape[1]
    s = (jnp.einsum("bwhr,bsr->bhws", q_lat.astype(jnp.float32),
                    c.astype(jnp.float32))
         + jnp.einsum("bwhd,bsd->bhws", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    qp = lengths[:, None, None, None] + jnp.arange(W)[None, None, :, None]
    kp = jnp.arange(S)[None, None, None, :]
    s = jnp.where(kp <= qp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhws,bsr->bwhr", p, c.astype(jnp.float32))
    return out.astype(q_lat.dtype)


def paged_latent_fused_ref(q_lat, q_rope, c_pool, kr_pool, c_new, kr_new,
                           tables, lengths, *, scale: float):
    """Fused MLA oracle: reference scatter on both latent pools, then
    attend — returns (out, c_pool, kr_pool) like the fused kernel."""
    c_pool = write_window_paged(c_pool, c_new, tables, lengths)
    kr_pool = write_window_paged(kr_pool, kr_new, tables, lengths)
    out = paged_latent_ref(q_lat, q_rope, c_pool, kr_pool, tables, lengths,
                           scale=scale)
    return out, c_pool, kr_pool
