"""Pallas TPU kernel: fused Gumbel-max verify over vocab tiles.

The hot loop of predictive sampling's verify step is
``argmax_v(logits[w, v] + eps[w, v])`` over a 32k-262k vocab for each of the
W window slots. On GPU the paper computed a log-softmax first; on TPU we
exploit LSE-shift invariance and never normalize (DESIGN.md §3) — the kernel
is a pure bandwidth-bound tiled reduction:

  grid = (R / br, V / bv); for each row tile, vocab tiles stream through
  VMEM while a running (max, argmax) pair lives in VMEM scratch (persists
  across the sequential TPU grid). bv is lane-aligned (multiple of 128);
  ties resolve to the lowest index (strict-greater update), matching
  jnp.argmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -3.0e38  # python float: pallas kernels must not capture array consts


def _verify_kernel(logits_ref, eps_ref, out_ref, m_ref, a_ref, *, bv: int):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        a_ref[...] = jnp.zeros_like(a_ref[...])

    vals = (logits_ref[...].astype(jnp.float32)
            + eps_ref[...].astype(jnp.float32))          # (br, bv)
    blk_max = jnp.max(vals, axis=1)                      # (br,)
    blk_arg = jnp.argmax(vals, axis=1).astype(jnp.int32) + j * bv

    run_max = m_ref[...]
    take = blk_max > run_max                             # strict: first wins
    m_ref[...] = jnp.where(take, blk_max, run_max)
    a_ref[...] = jnp.where(take, blk_arg, a_ref[...])

    @pl.when(j == nv - 1)
    def _emit():
        out_ref[...] = a_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_vocab",
                                             "interpret"))
def spec_verify_kernel(logits, eps, block_rows: int = 8,
                       block_vocab: int = 1024, interpret: bool = True):
    """argmax(logits + eps, axis=-1) for logits, eps: (R, V) -> (R,) int32."""
    R, V = logits.shape
    br = min(block_rows, R)
    bv = min(block_vocab, V)
    Rp = -(-R // br) * br
    Vp = -(-V // bv) * bv
    if (Rp, Vp) != (R, V):
        # NEG padding never wins the argmax
        logits = jnp.pad(logits, ((0, Rp - R), (0, Vp - V)),
                         constant_values=NEG)
        eps = jnp.pad(eps, ((0, Rp - R), (0, Vp - V)), constant_values=0.0)

    out = pl.pallas_call(
        functools.partial(_verify_kernel, bv=bv),
        grid=(Rp // br, Vp // bv),
        in_specs=[
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),   # running max
            pltpu.VMEM((br,), jnp.int32),     # running argmax
        ],
        interpret=interpret,
    )(logits, eps)
    return out[:R]
