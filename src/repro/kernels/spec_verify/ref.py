"""Pure-jnp oracle for the spec_verify kernel."""
import jax.numpy as jnp


def spec_verify_ref(logits, eps):
    """argmax(logits + eps, axis=-1): (R, V) -> (R,) int32."""
    return jnp.argmax(logits.astype(jnp.float32)
                      + eps.astype(jnp.float32), axis=-1).astype(jnp.int32)
