"""Jit'd public op: batched Gumbel-max verify.

Dispatches to the Pallas kernel (interpret=True on CPU, compiled on TPU) or
the jnp reference; shapes beyond 2D are flattened to rows.
"""
from __future__ import annotations

from repro.kernels import resolve_interpret
from repro.kernels.spec_verify.kernel import spec_verify_kernel
from repro.kernels.spec_verify.ref import spec_verify_ref


def spec_verify(logits, eps, use_kernel: bool = True,
                block_rows: int = 8, block_vocab: int = 1024,
                interpret: bool | None = None):
    """argmax(logits + eps) over the last axis; any leading shape."""
    shape = logits.shape[:-1]
    V = logits.shape[-1]
    lg = logits.reshape(-1, V)
    ep = eps.reshape(-1, V)
    if not use_kernel:
        out = spec_verify_ref(lg, ep)
    else:
        out = spec_verify_kernel(lg, ep, block_rows=block_rows,
                                 block_vocab=block_vocab,
                                 interpret=resolve_interpret(interpret))
    return out.reshape(shape)
