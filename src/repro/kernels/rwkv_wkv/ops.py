"""Jit'd public WKV op: (B, T, H, hd) layout adapter."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.rwkv_wkv.kernel import rwkv_wkv_kernel
from repro.kernels.rwkv_wkv.ref import rwkv_wkv_ref


def rwkv_wkv(r, k, v, w, u, use_kernel: bool = True, chunk: int = 64,
             interpret: bool | None = None):
    """r, k, v, w: (B, T, H, hd); u: (H, hd). Returns y (B, T, H, hd)."""
    B, T, H, hd = r.shape
    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    rf, kf, vf, wf = map(flat, (r, k, v, w))
    uf = jnp.tile(u, (B, 1))
    if use_kernel:
        yf = rwkv_wkv_kernel(rf, kf, vf, wf, uf, chunk=chunk,
                             interpret=resolve_interpret(interpret))
    else:
        yf = rwkv_wkv_ref(rf, kf, vf, wf, uf)
    return yf.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
