"""Pure-jnp oracle for the WKV recurrence (lax.scan form)."""
import jax
import jax.numpy as jnp


def rwkv_wkv_ref(r, k, v, w, u):
    """r, k, v, w: (BH, T, hd); u: (BH, hd) -> y (BH, T, hd)."""
    BH, T, hd = r.shape

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # (BH, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (BH, hd, hd)
        y = jnp.einsum("bk,bkv->bv", r_t, S + u[..., :, None] * kv)
        return w_t[..., :, None] * S + kv, y

    S0 = jnp.zeros((BH, hd, hd), jnp.float32)
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (r, k, v, w))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
