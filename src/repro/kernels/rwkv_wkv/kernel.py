"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

  S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

grid = (B*H, T/ct): time chunks stream sequentially per (batch, head) while
the (hd, hd) state matrix persists in VMEM scratch — the TPU-native shape of
the recurrence (the CUDA kernel the paper's successors use keeps state in
registers per thread; on TPU the whole state tile lives in VMEM and the
inner loop is a (1, hd) x (hd, hd) row-rank update, hd = 64 lanes).

The sequential inner fori_loop is the honest dependency structure — chunking
amortizes HBM traffic of r/k/v/w to one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, ct: int):
    jt = pl.program_id(1)

    @pl.when(jt == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref[...])

    u = u_ref[0].astype(jnp.float32)                     # (hd,)

    def step(t, _):
        r_t = r_ref[0, t].astype(jnp.float32)            # (hd,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        S = s_ref[...]                                   # (hd, hd)
        kv = k_t[:, None] * v_t[None, :]
        y = r_t @ (S + u[:, None] * kv)                  # (hd,)
        y_ref[0, t] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[:, None] * S + kv
        return ()

    jax.lax.fori_loop(0, ct, step, ())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_wkv_kernel(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """r, k, v, w: (BH, T, hd); u: (BH, hd). Returns y: (BH, T, hd)."""
    BH, T, hd = r.shape
    ct = min(chunk, T)
    Tp = -(-T // ct) * ct
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0))
        r, k, v = (jnp.pad(a, pad) for a in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, ct=ct),
        grid=(BH, Tp // ct),
        in_specs=[
            pl.BlockSpec((1, ct, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, ct, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, ct, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, ct, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, hd), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, ct, hd), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out[:, :T]
