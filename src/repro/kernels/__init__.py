# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel plumbing.

Every ``ops.py`` wrapper dispatches its Pallas kernel through
``resolve_interpret``: interpret-mode (bit-exact, slow) everywhere except a
real TPU backend, where the kernel compiles. Callers can still force either
mode explicitly (tests pin ``interpret=True``; TPU benchmarks pin ``False``).
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Backend dispatch for Pallas kernels: ``None`` means "compiled on TPU,
    interpreted elsewhere" — the CPU CI path and the TPU serving path run the
    same kernel code without every call site re-deriving the flag."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
