"""Pure-jnp oracle for flash-decode."""
import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, window: int = 0):
    """q: (BH, W, d); k, v: (BH, S, d); lengths: (BH,).
    Query w attends key positions j <= lengths + w (within sliding window)."""
    BH, W, d = q.shape
    S = k.shape[1]
    s = jnp.einsum("bwd,bsd->bws", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qp = lengths[:, None, None] + jnp.arange(W)[None, :, None]
    kp = jnp.arange(S)[None, None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > (qp - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bws,bsd->bwd", p, v.astype(jnp.float32)).astype(q.dtype)
