"""Jit'd public decode-attention op (GQA expansion + head flattening)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, lengths, window: int = 0,
                     use_kernel: bool = True, block_k: int = 512,
                     interpret: bool | None = None):
    """q: (B, W, H, d); k, v: (B, S, KV, d) caches; lengths: (B,).
    Returns (B, W, H, d)."""
    B, W, H, d = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    kx = jnp.repeat(k, G, axis=2) if G > 1 else k
    vx = jnp.repeat(v, G, axis=2) if G > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, W, d)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    lf = jnp.repeat(lengths, H)
    if use_kernel:
        of = decode_attention_kernel(qf, kf, vf, lf, window=window,
                                     block_k=block_k,
                                     interpret=resolve_interpret(interpret))
    else:
        of = decode_attention_ref(qf, kf, vf, lf, window=window)
    return of.reshape(B, H, W, d).transpose(0, 2, 1, 3)
