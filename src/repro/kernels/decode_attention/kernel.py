"""Pallas TPU kernel: flash-decode — W window queries vs a long KV cache.

The predictive-sampling verify step attends W (<=16) fresh queries against a
cache of up to 524,288 keys. Compute is dominated by streaming the cache
through VMEM once (bandwidth-bound, the long_500k roofline term); queries
ride along whole.

grid = (BH, ceil(S/bk)): per (batch*head), KV tiles stream sequentially with
the online-softmax state for all W queries in scratch. Per-sequence valid
length masks tail tiles (cache slots beyond ``length + W`` are never
counted). A ragged final tile is masked *in-kernel* against the true S —
no host-side ``jnp.pad`` copy of the whole cache on the hot path; its
out-of-bounds K/V rows are zeroed before the matmuls so garbage (possibly
non-finite) memory can never poison the accumulator through ``0 * v``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

NEG = -1.0e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bk: int, s_len: int, scale: float,
                   window: int):
    jk = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    q = q_ref[0].astype(jnp.float32)                     # (W, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    W = q.shape[0]
    # ragged tail tile: rows at k_pos >= S are out-of-bounds reads
    in_bounds = (jk * bk
                 + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)) < s_len
    k = jnp.where(in_bounds, k, 0.0)
    v = jnp.where(in_bounds, v, 0.0)
    s = (q @ k.T) * scale                                # (W, bk)

    base = len_ref[0]                                    # valid cache length
    q_pos = base + jax.lax.broadcasted_iota(jnp.int32, (W, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (W, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos < s_len)
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention_kernel(q, k, v, lengths, window: int = 0,
                            block_k: int = 512,
                            interpret: bool | None = None):
    """q: (BH, W, d) window queries; k, v: (BH, S, d) caches (window keys
    already written at positions lengths..lengths+W-1); lengths: (BH,) valid
    prefix lengths. Query w attends keys < lengths + w + 1."""
    BH, W, d = q.shape
    S = k.shape[1]
    bk = min(block_k, S)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, s_len=S,
                          scale=1.0 / d ** 0.5, window=window),
        grid=(BH, -(-S // bk)),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, W, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, W, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, W, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((W,), jnp.float32),
            pltpu.VMEM((W,), jnp.float32),
            pltpu.VMEM((W, d), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(lengths.astype(jnp.int32), q, k, v)
    return out
