"""Pallas TPU kernel: causal flash attention (prefill path).

Online-softmax tiling: grid = (batch*heads, Sq/bq, Skv/bk); the KV axis is
innermost so the running (m, l, acc) state persists in VMEM scratch across
KV tiles. Causal (and optional sliding-window) masking is applied per tile;
fully-masked tiles are skipped via the index map (block-level early exit is
structural: we simply don't schedule tiles above the diagonal).

Block sizes default to (bq, bk) = (128, 128) — MXU-aligned; head_dim rides
along unblocked (<= 256 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, scale: float, window: int):
    iq, jk = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale                                # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep exp(NEG - NEG)=1 rows from polluting l
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jk == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel(q, k, v, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q, k, v: (BH, S, d) (heads pre-flattened / GQA pre-expanded).
    Returns (BH, S, d)."""
    BH, S, d = q.shape
    assert causal, "non-causal path unused in this framework"
    bq = min(block_q, S)
    bk = min(block_k, S)
    Sp = -(-S // max(bq, bk)) * max(bq, bk)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        q, k, v = (jnp.pad(a, pad) for a in (q, k, v))
    scale = 1.0 / (d ** 0.5)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          window=window),
        grid=(BH, Sp // bq, Sp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
