"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, d) -> (BH, S, d), causal softmax attention."""
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = kp <= qp
    if window > 0:
        mask &= kp > (qp - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
