"""Jit'd public flash-attention op with GQA head expansion."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, window: int = 0, use_kernel: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, S, H, d); k, v: (B, S, KV, d). Returns (B, S, H, d)."""
    B, S, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    kx = jnp.repeat(k, G, axis=2) if G > 1 else k
    vx = jnp.repeat(v, G, axis=2) if G > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    if use_kernel:
        of = flash_attention_kernel(qf, kf, vf, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=resolve_interpret(interpret))
    else:
        of = flash_attention_ref(qf, kf, vf, window=window)
    return of.reshape(B, H, S, d).transpose(0, 2, 1, 3)
