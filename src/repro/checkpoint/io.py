"""Checkpointing: path-keyed npz + json manifest (no orbax dependency).

Arrays are gathered to host (works for sharded arrays via device_get) and
stored under flattened path keys; restore rebuilds nested dict/list pytrees
and re-places onto the caller's shardings if given.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}" if prefix else f"#{i}"))
        if len(tree) == 0:
            out[prefix + "/#empty"] = np.zeros(0)
    else:
        out[prefix] = tree
    return out


def save_pytree(tree, directory: str, step: int):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    arrays = {}
    manifest = {"step": step, "keys": [], "dtypes": {}}
    for i, (k, v) in enumerate(sorted(flat.items())):
        arrays[f"a{i}"] = np.asarray(v)
        manifest["keys"].append(k)
        manifest["dtypes"][k] = str(np.asarray(v).dtype)
    np.savez(os.path.join(directory, f"ckpt_{step:08d}.npz"), **arrays)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.json", f))]
    return max(steps) if steps else None


def restore_pytree(directory: str, step: int, shardings=None):
    with open(os.path.join(directory, f"ckpt_{step:08d}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    return tree


def _unflatten(flat):
    root = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        if parts[-1] != "#empty":
            node[parts[-1]] = val
    return _listify(root)


def _listify(node):
    if not isinstance(node, dict):
        return node
    if node == {}:
        return []
    if all(k.startswith("#") for k in node):
        idx = sorted((int(k[1:]) for k in node if k != "#empty"))
        return [_listify(node[f"#{i}"]) for i in idx]
    return {k: _listify(v) for k, v in node.items()}
