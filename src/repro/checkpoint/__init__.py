from repro.checkpoint.io import save_pytree, restore_pytree, latest_step

__all__ = ["save_pytree", "restore_pytree", "latest_step"]
