"""Declarative invariant rules over traced jaxprs and post-SPMD HLO
(DESIGN.md §17).

Each rule is a small object with a ``name`` and a ``check(program) ->
list[Violation]`` method; :class:`~repro.analysis.contracts.Contract`
bundles rules and :func:`~repro.analysis.contracts.check_program` runs
them against one compiled program, returning a structured ``Report``
instead of a bare assert. A :class:`Program` lazily exposes the three
views rules read — the traced jaxpr, the compiled post-SPMD HLO text,
and XLA's memory analysis — so a jaxpr-only contract never pays a
compile and an HLO rule compiles exactly once.

The rule catalog encodes the invariants predictive sampling's speedup
lives or dies by (PRs 2-9 asserted them ad hoc; this is the one place
they are written down):

* ``NoCollectives`` — the verify-round hot path is shard-local by
  construction; any collective (sync OR async-``start`` lowering) means
  a placement bug that scales round latency with the mesh.
* ``NoPoolRankedScatters`` — every physical-pool write happens inside a
  pallas_call as an input/output-aliased epilogue (DESIGN.md §11); a
  pool-ranked scatter eqn is the dense round-trip sneaking back.
* ``DonationAliasCovers`` — the donated pool must actually alias in
  place (XLA established >= pool-size input/output aliasing), or every
  round holds two live copies of the cache.
* ``NoHostCallbacks`` — io_callback / pure_callback / debug prints in a
  round program serialize the device stream on the host.
* ``NoF64Leaks`` — a stray f64 (x64 leak) doubles hot-path bandwidth
  and breaks the bf16/f32 exactness story.
* ``MaxLiveBytes`` — bound on live bytes (args + outputs + temps -
  aliasing) of the compiled program.
* ``RecompileHazard`` — the same program traced at more than N distinct
  static shapes per process is a recompile storm (the W-grid and
  prefill-chunk pow2 bounds exist precisely to prevent this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.analysis.hlo import (count_jaxpr_primitives, find_collectives,
                                find_dtype_leaks, find_jaxpr_primitives)

HOST_CALLBACK_PRIMITIVES = ("io_callback", "pure_callback",
                            "debug_callback", "callback")


@dataclass
class Violation:
    """One structured contract violation: which rule, where (eqn path or
    HLO line), and the numeric evidence (rank / bytes / counts)."""
    rule: str
    summary: str
    site: str = ""                 # eqn path or "HLO line N"
    evidence: dict = field(default_factory=dict)

    def __str__(self):
        loc = f" [{self.site}]" if self.site else ""
        ev = (" " + ", ".join(f"{k}={v}" for k, v in self.evidence.items())
              if self.evidence else "")
        return f"{self.rule}: {self.summary}{loc}{ev}"


class Program:
    """Lazy views of one traced/compiled program for rules to read.

    Built from a jit-wrapped callable plus example args (the normal
    path), or directly from a jaxpr and/or HLO text (unit fixtures, and
    the synthetic async-HLO regression tests). ``label`` keys the
    per-process trace registry :class:`RecompileHazard` reads.
    """

    def __init__(self, fn=None, args=None, *, jaxpr=None, hlo_text=None,
                 label: str = ""):
        if fn is not None and not hasattr(fn, "trace"):
            import jax
            fn = jax.jit(fn)
        self.fn = fn
        self.args = args
        self.label = label or (getattr(fn, "__name__", "") or "<program>")
        self._jaxpr = jaxpr
        self._hlo = hlo_text
        self._compiled = False
        self._mem = None

    # -- views ---------------------------------------------------------
    @property
    def jaxpr(self):
        if self._jaxpr is None:
            if self.fn is None:
                raise ValueError(
                    f"{self.label}: rule needs a jaxpr but the Program was "
                    "built from HLO text only")
            self._jaxpr = self.fn.trace(*self.args).jaxpr
        return self._jaxpr

    def _compile(self):
        if not self._compiled:
            if self.fn is None:
                raise ValueError(
                    f"{self.label}: rule needs compiled HLO but the Program "
                    "was built from a jaxpr only")
            compiled = self.fn.lower(*self.args).compile()
            if self._hlo is None:
                self._hlo = compiled.as_text()
            try:
                self._mem = compiled.memory_analysis()
            except Exception:          # backend without memory analysis
                self._mem = None
            self._compiled = True

    @property
    def hlo_text(self) -> str:
        if self._hlo is None:
            self._compile()
        return self._hlo

    @property
    def memory(self):
        """XLA memory analysis of the compiled program (or None)."""
        if not self._compiled and self._mem is None and self._hlo is None:
            self._compile()
        elif self.fn is not None and not self._compiled:
            self._compile()
        return self._mem

    def arg_bytes(self, argnums) -> int:
        """PER-DEVICE byte size of the (flattened) positional args
        ``argnums`` — e.g. the physical pool pytree a donation must
        cover. Per-device because XLA's ``memory_analysis`` (what
        DonationAliasCovers compares against) reports one device's
        program: a data-sharded pool contributes one shard's bytes, a
        replicated arg its full size."""
        import jax

        total = 0
        for i in argnums:
            for leaf in jax.tree_util.tree_leaves(self.args[i]):
                shards = getattr(leaf, "addressable_shards", None)
                if shards:
                    nbytes = shards[0].data.nbytes
                else:
                    nbytes = getattr(leaf, "nbytes", None)
                    if nbytes is None:
                        import numpy as np
                        nbytes = np.asarray(leaf).nbytes
                total += int(nbytes)
        return total


class Rule:
    """Base: subclasses set ``name`` and implement ``check``."""
    name = "rule"

    def check(self, program: Program) -> list[Violation]:
        raise NotImplementedError


class NoCollectives(Rule):
    """Zero collective ops in the compiled (post-SPMD) HLO — counting
    the async ``-start`` lowered forms too (the PR 10 regression fix:
    async-lowered HLO used to slip past the gate)."""
    name = "NoCollectives"

    def check(self, program):
        return [Violation(
            self.name, f"collective `{rec['op']}` on the hot path",
            site=f"HLO line {rec['line_no']}: {rec['line'][:120]}",
            evidence={"bytes": rec["bytes"], "op": rec["op"]})
            for rec in find_collectives(program.hlo_text)]


class NoPoolRankedScatters(Rule):
    """Zero scatter eqns of rank >= ``min_rank`` in the jaxpr
    (recursive). Rank 3 is pool-shaped: the standalone window writeback
    the fused pallas epilogue eliminated (DESIGN.md §11); rank <= 2
    row-bookkeeping updates (adoption stats, descriptor outputs) pass.

    ``pool_shapes`` (optional) narrows the rule from a rank proxy to the
    real invariant — only scatters whose OUTPUT SHAPE matches one of the
    given KV-pool leaf shapes count as pool writes. The engine passes
    its exact pool leaf shapes (global and per-data-shard), so the
    legitimate high-rank scatters other archs run per round — MoE
    expert-dispatch buffers, ssm/rwkv per-slot recurrent-state rows —
    pass, while a dense writeback into the pool is still caught.
    ``pool_shapes=None`` keeps the plain rank filter (fixtures, and
    callers with no pool pytree in hand).
    """
    name = "NoPoolRankedScatters"

    def __init__(self, min_rank: int = 3, pool_shapes=None):
        self.min_rank = min_rank
        self.pool_shapes = (None if pool_shapes is None else
                            frozenset(tuple(s) for s in pool_shapes))

    def check(self, program):
        return [Violation(
            self.name,
            f"pool-ranked `{s.primitive}` (rank {s.rank} >= "
            f"{self.min_rank}) outside a pallas epilogue",
            site=s.path or "<top>",
            evidence={"rank": s.rank, "shape": list(s.shape),
                      "eqn": s.eqn})
            for s in find_jaxpr_primitives(
                program.jaxpr, ("scatter", "scatter-add"), self.min_rank)
            if self.pool_shapes is None or s.shape in self.pool_shapes]


class DonationAliasCovers(Rule):
    """The compiled program's input/output aliasing must cover at least
    the byte size of the args in ``pool_argnums`` (the donated physical
    pool): donation that XLA silently declined means two live pool
    copies per round. Skipped (no violation) when the backend exposes no
    memory analysis."""
    name = "DonationAliasCovers"

    def __init__(self, pool_argnums=(1,)):
        self.pool_argnums = tuple(pool_argnums)

    def check(self, program):
        mem = program.memory
        if mem is None or program.args is None:
            return []
        pool_bytes = program.arg_bytes(self.pool_argnums)
        alias = int(getattr(mem, "alias_size_in_bytes", 0))
        if alias >= pool_bytes:
            return []
        return [Violation(
            self.name,
            f"aliased {alias} bytes < {pool_bytes}-byte pool "
            f"(args {list(self.pool_argnums)}): donation not established",
            evidence={"alias_bytes": alias, "pool_bytes": pool_bytes,
                      "pool_argnums": list(self.pool_argnums)})]


class NoHostCallbacks(Rule):
    """Zero host callback eqns (io_callback / pure_callback /
    debug_callback, incl. jax.debug.print) anywhere in the jaxpr."""
    name = "NoHostCallbacks"

    def check(self, program):
        return [Violation(
            self.name, f"host callback `{s.primitive}` on the hot path",
            site=s.path or "<top>", evidence={"eqn": s.eqn})
            for s in find_jaxpr_primitives(
                program.jaxpr, HOST_CALLBACK_PRIMITIVES)]


class NoF64Leaks(Rule):
    """Zero float64/complex128-producing eqns in the jaxpr."""
    name = "NoF64Leaks"

    def check(self, program):
        return [Violation(
            self.name, f"`{s.primitive}` produces a 64-bit float output",
            site=s.path or "<top>",
            evidence={"rank": s.rank, "eqn": s.eqn})
            for s in find_dtype_leaks(program.jaxpr)]


class MaxLiveBytes(Rule):
    """Live bytes of the compiled program (arguments + outputs + temps -
    established aliasing) must not exceed ``budget`` bytes. Workload-
    parameterized, so the named contracts don't carry it by default —
    extend a contract with it where a budget is known
    (``ROUND_CONTRACT.extend(MaxLiveBytes(b))``)."""
    name = "MaxLiveBytes"

    def __init__(self, budget: int):
        self.budget = int(budget)

    def check(self, program):
        mem = program.memory
        if mem is None:
            return []
        live = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        if live <= self.budget:
            return []
        return [Violation(
            self.name, f"live {live} bytes > budget {self.budget}",
            evidence={"live_bytes": live, "budget": self.budget})]


class RecompileHazard(Rule):
    """The same program label traced at more than ``max_shapes`` distinct
    static arg-shape signatures in this process. The engine's W grid and
    pow2 prefill chunks exist to bound compiled variants; a caller that
    re-traces per request (ragged shapes reaching jit) trips this."""
    name = "RecompileHazard"

    # label -> set of shape signatures, process-global by design
    _registry: dict = {}

    def __init__(self, max_shapes: int = 8):
        self.max_shapes = int(max_shapes)

    @staticmethod
    def signature(args) -> tuple:
        import jax

        def one(leaf):
            shape = getattr(leaf, "shape", ())
            dtype = str(getattr(leaf, "dtype", type(leaf).__name__))
            return (tuple(shape), dtype)
        return tuple(one(leaf) for leaf in jax.tree_util.tree_leaves(args))

    def check(self, program):
        if program.args is None:
            return []
        seen = self._registry.setdefault(program.label, set())
        seen.add(self.signature(program.args))
        if len(seen) <= self.max_shapes:
            return []
        return [Violation(
            self.name,
            f"`{program.label}` traced at {len(seen)} distinct static "
            f"shapes (> {self.max_shapes}) this process",
            evidence={"distinct_shapes": len(seen),
                      "max_shapes": self.max_shapes})]


def census(program: Program) -> dict:
    """The summary numbers every gate used to compute by hand, attached
    to each Report: pool-ranked scatters, pallas calls, host callbacks,
    per-op collective counts (async forms folded in)."""
    jx = program.jaxpr
    counts = count_jaxpr_primitives(
        jx, ("pallas_call",) + HOST_CALLBACK_PRIMITIVES)
    scatters = count_jaxpr_primitives(
        jx, ("scatter", "scatter-add"), min_rank=3)
    return {
        "pool_scatters": sum(scatters.values()),
        "pallas_calls": counts["pallas_call"],
        "host_callbacks": sum(counts[p] for p in HOST_CALLBACK_PRIMITIVES),
    }
