"""Named contracts + the check entrypoints (DESIGN.md §17).

A :class:`Contract` is an ordered bundle of rules with a name; the four
shipped contracts cover the engine's compiled programs:

* ``ROUND_CONTRACT`` — the legacy 9-arg verify-round loop.
* ``STAGED_ROUND_CONTRACT`` — the §15 19-arg staged round (in-loop slot
  adoption); same invariants, separate name so violations and the
  recompile registry attribute to the right program.
* ``PREFILL_CONTRACT`` — chunked prompt admission. Collectives are
  allowed (GSPMD may move activations on the admission path) and so are
  pool-ranked scatters (prefill's whole job is writing pool rows), but
  host callbacks and f64 leaks are not, and donation must still hold.
* ``MIGRATION_COPY_CONTRACT`` — block migration copy. The copy *is* a
  pool write, so no scatter rule; cross-tier copies stay shard-local,
  callback-free, and donate the pool (arg 0).

``check_program(fn, args, contract)`` runs one program through one
contract and returns a :class:`Report`. ``maybe_check(kind, fn, args)``
is the engine seam: no-op unless ``REPRO_CHECK_CONTRACTS=1`` (set by
tests/conftest.py and the mesh/chaos/recovery CI jobs), checked once per
(kind, fn) per process, raising :class:`ContractViolationError` with the
full structured report on failure.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

from repro.analysis.rules import (DonationAliasCovers, NoCollectives,
                                  NoF64Leaks, NoHostCallbacks,
                                  NoPoolRankedScatters, Program,
                                  RecompileHazard, Rule, Violation, census)


@dataclass
class Report:
    """Outcome of checking one program against one contract."""
    contract: str
    label: str
    violations: list = field(default_factory=list)
    metrics: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def __str__(self):
        head = (f"contract {self.contract} on `{self.label}`: "
                f"{'OK' if self.ok else f'{len(self.violations)} violation(s)'}")
        lines = [head] + [f"  - {v}" for v in self.violations]
        if self.metrics:
            lines.append("  metrics: " + ", ".join(
                f"{k}={v}" for k, v in sorted(self.metrics.items())))
        return "\n".join(lines)


class ContractViolationError(AssertionError):
    """Raised by ``maybe_check``/``Report.require`` on a failed contract.

    Subclasses AssertionError so pre-existing ``assert``-era harnesses
    (pytest, the bench runner) treat it as the same class of failure.
    """

    def __init__(self, report: Report):
        self.report = report
        super().__init__(str(report))


class Contract:
    """A named, ordered rule bundle."""

    def __init__(self, name: str, rules: list[Rule]):
        self.name = name
        self.rules = list(rules)

    def extend(self, *extra: Rule) -> "Contract":
        """A derived contract with ``extra`` rules appended (e.g. a
        workload-specific ``MaxLiveBytes`` budget)."""
        return Contract(self.name, self.rules + list(extra))

    def rule_names(self) -> list[str]:
        return [r.name for r in self.rules]


def _hot_rules(pool_argnums):
    return [
        NoCollectives(),
        NoPoolRankedScatters(min_rank=3),
        NoHostCallbacks(),
        NoF64Leaks(),
        DonationAliasCovers(pool_argnums),
        RecompileHazard(max_shapes=8),
    ]


ROUND_CONTRACT = Contract("ROUND_CONTRACT", _hot_rules(pool_argnums=(1,)))
STAGED_ROUND_CONTRACT = Contract("STAGED_ROUND_CONTRACT",
                                 _hot_rules(pool_argnums=(1,)))
PREFILL_CONTRACT = Contract("PREFILL_CONTRACT", [
    NoHostCallbacks(),
    NoF64Leaks(),
    DonationAliasCovers(pool_argnums=(1,)),
    RecompileHazard(max_shapes=16),    # one variant per pow2 chunk size
])
MIGRATION_COPY_CONTRACT = Contract("MIGRATION_COPY_CONTRACT", [
    NoHostCallbacks(),
    NoF64Leaks(),
    DonationAliasCovers(pool_argnums=(0,)),
    RecompileHazard(max_shapes=8),
])

CONTRACTS = {c.name: c for c in (ROUND_CONTRACT, STAGED_ROUND_CONTRACT,
                                 PREFILL_CONTRACT, MIGRATION_COPY_CONTRACT)}


def _strip_rules(contract: Contract, names) -> Contract:
    names = set(names)
    return Contract(contract.name,
                    [r for r in contract.rules if r.name not in names])


# Rules that do not apply to tensor-parallel round programs: the model
# axis is left to GSPMD (ServingTopology.auto_axes), whose lowering
# all-reduces partial products every layer BY DESIGN, and whose compiled
# program does not preserve the manual pool-donation aliasing. The
# zero-collective / donation invariants are a property of the *data*
# axis only (PR 3), which the non-TP mesh tests pin.
_TP_EXEMPT_RULES = ("NoCollectives", "DonationAliasCovers")


def select_contract(kind: str, *, donate: bool = True,
                    tensor_parallel: bool = False,
                    pool_scatter_shapes=None) -> Contract:
    """The contract actually enforced for an engine program variant.

    ``kind`` names a registered contract ("round" / "staged_round" /
    "prefill" / "migration_copy"). ``donate=False`` drops
    DonationAliasCovers (undonated pools establish no aliasing);
    ``tensor_parallel=True`` additionally drops the data-axis-only rules
    in :data:`_TP_EXEMPT_RULES` — model-axis collectives are the TP
    contraction itself, not a hot-path regression.
    ``pool_scatter_shapes`` (the engine's exact KV-pool leaf shapes,
    global and per-shard) narrows NoPoolRankedScatters from the rank
    proxy to real pool writes, so MoE dispatch buffers and recurrent
    state rows — high-rank scatters other archs run per round by
    design — pass while a dense pool writeback is still caught.
    """
    contract = CONTRACTS[_KIND_TO_CONTRACT[kind]]
    strip = set()
    if not donate:
        strip.add("DonationAliasCovers")
    if tensor_parallel:
        strip.update(_TP_EXEMPT_RULES)
    if strip:
        contract = _strip_rules(contract, strip)
    if pool_scatter_shapes is not None:
        contract = Contract(contract.name, [
            NoPoolRankedScatters(min_rank=r.min_rank,
                                 pool_shapes=pool_scatter_shapes)
            if r.name == "NoPoolRankedScatters" else r
            for r in contract.rules])
    return contract


def check_program(fn, args, contract: Contract, label: str = None,
                  *, jaxpr=None, hlo_text=None) -> Report:
    """Check one program against ``contract``; returns a :class:`Report`
    with structured violations and the census metrics (pool_scatters,
    pallas_calls, host_callbacks, collectives). ``fn`` may be any
    callable (jit-wrapped automatically) — or pass ``jaxpr``/``hlo_text``
    directly for pre-traced fixtures."""
    program = Program(fn, args, jaxpr=jaxpr, hlo_text=hlo_text,
                      label=label or "")
    report = Report(contract=contract.name, label=program.label)
    for rule in contract.rules:
        report.violations.extend(rule.check(program))
    try:
        report.metrics.update(census(program))
    except ValueError:
        pass                              # HLO-text-only fixture: no jaxpr
    if program._hlo is not None:
        from repro.analysis.hlo import parse_collective_bytes
        report.metrics["collectives"] = {
            k: v["count"] for k, v in
            parse_collective_bytes(program.hlo_text).items()}
    return report


def require(report: Report) -> Report:
    """Raise :class:`ContractViolationError` unless ``report.ok``."""
    if not report.ok:
        raise ContractViolationError(report)
    return report


def contracts_enabled() -> bool:
    return os.environ.get("REPRO_CHECK_CONTRACTS", "0") == "1"


# (kind, id(fn)) pairs already checked this process: contracts are a
# per-program property, so one check per compiled variant is enough.
_CHECKED: set = set()


def maybe_check(kind: str, fn, args, *, label: str = None,
                donate: bool = True, tensor_parallel: bool = False,
                pool_scatter_shapes=None) -> None:
    """Engine seam: contract-check ``fn`` once per process when
    ``REPRO_CHECK_CONTRACTS=1``. ``kind`` names a registered contract
    ("round" / "staged_round" / "prefill" / "migration_copy").

    ``donate=False`` (engines built without donation, e.g. the memory
    A/B benchmark) drops the DonationAliasCovers rule — undonated pools
    legitimately establish no aliasing; ``tensor_parallel`` /
    ``pool_scatter_shapes`` are the :func:`select_contract`
    refinements for model-parallel engines and pool-shape-targeted
    scatter checking. Raises
    :class:`ContractViolationError` on violation so a broken program
    fails loudly at first trace, not as a perf mystery later.
    """
    if not contracts_enabled():
        return
    key = (kind, id(fn))
    if key in _CHECKED:
        return
    _CHECKED.add(key)
    contract = select_contract(kind, donate=donate,
                               tensor_parallel=tensor_parallel,
                               pool_scatter_shapes=pool_scatter_shapes)
    require(check_program(fn, args, contract, label=label or kind))


def check_engine_round(eng, *, extra_rules=()) -> Report:
    """Contract-check an engine's CURRENT round program (the exact fn +
    args its next ``step()`` dispatches) and return the Report — the one
    gate block tests and benches share. ``Report.metrics`` carries the
    numbers the old inline gates computed by hand (per-op collective
    counts, pool_scatters, pallas_calls) plus ``n_args`` (9 legacy /
    19 staged §15 ABI). Duck-typed on the engine so the analysis layer
    never imports serving."""
    fn = eng._round_loop_fn(eng.controller.window, eng.rounds_per_sync)
    args = eng._round_args()
    staged = getattr(eng, "staging_slots", 0) > 0
    kind = "staged_round" if staged else "round"
    exemptions = getattr(eng, "_contract_exemptions", None)
    exemptions = exemptions() if callable(exemptions) else {}
    contract = select_contract(kind, donate=getattr(eng, "donate", True),
                               **exemptions)
    if extra_rules:
        contract = contract.extend(*extra_rules)
    report = check_program(fn, args, contract,
                           label=f"{kind}@{hex(id(eng))}")
    report.metrics["n_args"] = len(args)
    return report


_KIND_TO_CONTRACT = {
    "round": "ROUND_CONTRACT",
    "staged_round": "STAGED_ROUND_CONTRACT",
    "prefill": "PREFILL_CONTRACT",
    "migration_copy": "MIGRATION_COPY_CONTRACT",
}
