"""AST host-sync / determinism linter over ``src/repro`` (DESIGN.md §17).

Three rules, all static (no imports of the linted code):

* ``host-sync`` — device->host synchronization calls (``np.asarray``,
  ``.item()``, ``float()``, ``bool()`` on traced values) inside *hot*
  functions. Hot = decorated ``@hot_path``, nested under a round-loop
  builder (``_round_loop_fn`` / ``_build_staged_round``), or reachable
  from either via same-module calls. Each sync forces the dispatch
  stream to drain — the exact stall the device-resident round loop
  exists to avoid.
* ``nondet`` — Python ``random.*`` or ``time.time()`` in the seeded /
  deterministic modules (journal, faults, adaptive policy, noise-stream
  and verify code). Replay (journal), fault injection, and the
  reparameterized noise stream are deterministic *by contract*; wall
  clocks and the global RNG silently break replay equivalence.
  (``jax.random`` is fine — it is the seeded stream.)
* ``bare-except`` — ``except:`` with no exception type anywhere in
  ``src/repro``: it swallows ``RequestError`` (and KeyboardInterrupt),
  defeating the per-request quarantine path.

Suppress a finding with ``# repro: allow(<rule>)`` on the flagged line
or on the enclosing ``def`` line. CLI::

    python -m repro.analysis.lint [paths...]   # default: src/repro

exits nonzero on any finding.
"""
from __future__ import annotations

import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path

# Modules whose behaviour is deterministic by contract (replay journal,
# fault plans, adaptive policy, seeded noise / verify round).
DETERMINISTIC_MODULES = (
    "serving/journal.py",
    "serving/faults.py",
    "serving/adaptive.py",
    "core/reparam.py",
    "engine/spec_decode.py",
)

# Builders whose nested functions are traced into the round loop.
HOT_BUILDERS = ("_round_loop_fn", "_build_staged_round")

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([\w*-]+)\)")


@dataclass
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _allows(source_lines, lineno: int) -> set:
    """Rules suppressed on source line ``lineno`` (1-based)."""
    if 1 <= lineno <= len(source_lines):
        return set(_ALLOW_RE.findall(source_lines[lineno - 1]))
    return set()


def _dotted(node) -> str:
    """Best-effort dotted name of a call target ('np.asarray', 'x.item')."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_hot_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target)
        if name == "hot_path" or name.endswith(".hot_path"):
            return True
    return False


class _ModuleLint:
    def __init__(self, path: Path, rel: str, tree: ast.Module, lines):
        self.path = path
        self.rel = rel
        self.tree = tree
        self.lines = lines
        self.findings: list[Finding] = []

    def _emit(self, rule, node, message, def_line: int = 0):
        allowed = _allows(self.lines, node.lineno)
        if def_line:
            allowed |= _allows(self.lines, def_line)
        if rule in allowed or "*" in allowed:
            return
        self.findings.append(Finding(self.rel, node.lineno, rule, message))

    # -- hot-function discovery ---------------------------------------
    def _hot_functions(self) -> list[ast.AST]:
        """@hot_path defs, defs nested under HOT_BUILDERS, plus the
        same-module transitive call closure of both."""
        fndefs = (ast.FunctionDef, ast.AsyncFunctionDef)
        by_name: dict[str, list] = {}
        hot: list[ast.AST] = []
        seen: set[int] = set()

        def add(fn):
            if id(fn) not in seen:
                seen.add(id(fn))
                hot.append(fn)

        def walk(node, inside_builder):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, fndefs):
                    by_name.setdefault(child.name, []).append(child)
                    if _is_hot_decorated(child) or inside_builder:
                        add(child)
                    walk(child, inside_builder
                         or child.name in HOT_BUILDERS)
                else:
                    walk(child, inside_builder)

        walk(self.tree, False)

        # transitive closure over same-module calls by simple name
        frontier = list(hot)
        while frontier:
            fn = frontier.pop()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name):
                    for callee in by_name.get(node.func.id, []):
                        if id(callee) not in seen:
                            add(callee)
                            frontier.append(callee)
        return hot

    # -- rules ---------------------------------------------------------
    def check_host_sync(self):
        for fn in self._hot_functions():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = _dotted(node.func)
                if name in ("np.asarray", "numpy.asarray", "onp.asarray",
                            "np.array", "numpy.array"):
                    self._emit("host-sync", node,
                               f"`{name}` in hot function `{fn.name}` "
                               "syncs the device stream", fn.lineno)
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    self._emit("host-sync", node,
                               f"`.item()` in hot function `{fn.name}` "
                               "syncs the device stream", fn.lineno)
                elif isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "bool") and node.args:
                    self._emit("host-sync", node,
                               f"`{node.func.id}()` on a traced value in "
                               f"hot function `{fn.name}` syncs the device "
                               "stream", fn.lineno)

    def check_nondet(self):
        if not self.rel.replace("\\", "/").endswith(DETERMINISTIC_MODULES):
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name == "time.time":
                self._emit("nondet", node,
                           "`time.time()` in a deterministic module "
                           "breaks replay equivalence")
            elif name.startswith("random.") and name.count(".") == 1:
                self._emit("nondet", node,
                           f"global-RNG `{name}` in a deterministic "
                           "module breaks replay equivalence")

    def check_bare_except(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                self._emit("bare-except", node,
                           "bare `except:` can swallow RequestError "
                           "(and KeyboardInterrupt); name the exception")

    def run(self) -> list[Finding]:
        self.check_host_sync()
        self.check_nondet()
        self.check_bare_except()
        return self.findings


def lint_file(path, root=None) -> list[Finding]:
    path = Path(path)
    rel = str(path.relative_to(root)) if root else str(path)
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    return _ModuleLint(path, rel, tree, source.splitlines()).run()


def lint_paths(paths) -> list[Finding]:
    findings: list[Finding] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                findings.extend(lint_file(f, root=p.parent))
        else:
            findings.extend(lint_file(p))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        here = Path(__file__).resolve()
        argv = [str(here.parents[1])]          # src/repro
    findings = lint_paths(argv)
    for f in findings:
        print(f)
    print(f"repro-lint: {len(findings)} finding(s) in "
          f"{', '.join(argv)}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
