"""The ``@hot_path`` marker (DESIGN.md §17).

A zero-cost decorator naming the functions that run inside (or are traced
into) a compiled serving hot path — the verify-round loop and everything
it inlines. The marker carries no runtime behaviour; it exists so the
AST linter (:mod:`repro.analysis.lint`) knows where device->host syncs
(``np.asarray`` / ``.item()`` / ``float()`` / ``bool()`` on traced
values) are forbidden, without the linter having to solve whole-program
reachability: decorate the roots, and the linter closes over same-module
callees and functions nested under ``_round_loop_fn`` /
``_build_staged_round`` by itself.

Kept import-light on purpose (no jax): core modules decorate their round
functions without pulling the analysis engine into their import graph.
"""
from __future__ import annotations


def hot_path(fn):
    """Mark ``fn`` as serving-hot-path code for the static linter."""
    fn.__repro_hot_path__ = True
    return fn


def is_hot_path(fn) -> bool:
    return bool(getattr(fn, "__repro_hot_path__", False))
