"""Static-analysis layer: declarative jaxpr/HLO contracts + host-sync
linter (DESIGN.md §17).

Public surface::

    from repro import analysis
    report = analysis.check_program(fn, args, analysis.ROUND_CONTRACT)
    assert report.ok, report
    report.metrics["pool_scatters"]     # the numbers gates assert on

    analysis.maybe_check("round", fn, args)   # engine seam, env-gated

    @analysis.hot_path                   # mark for the AST linter
    def verify_round(...): ...
"""
from repro.analysis.contracts import (CONTRACTS, MIGRATION_COPY_CONTRACT,
                                      PREFILL_CONTRACT, ROUND_CONTRACT,
                                      STAGED_ROUND_CONTRACT, Contract,
                                      ContractViolationError, Report,
                                      check_engine_round, check_program,
                                      contracts_enabled, maybe_check, require,
                                      select_contract)
from repro.analysis.hlo import (EqnSite, count_jaxpr_primitives,
                                find_collectives, find_dtype_leaks,
                                find_jaxpr_primitives, parse_collective_bytes,
                                parse_shape_bytes)
from repro.analysis.hotpath import hot_path, is_hot_path
from repro.analysis.rules import (DonationAliasCovers, MaxLiveBytes,
                                  NoCollectives, NoF64Leaks, NoHostCallbacks,
                                  NoPoolRankedScatters, Program,
                                  RecompileHazard, Rule, Violation, census)

__all__ = [
    "CONTRACTS", "Contract", "ContractViolationError", "Report",
    "ROUND_CONTRACT", "STAGED_ROUND_CONTRACT", "PREFILL_CONTRACT",
    "MIGRATION_COPY_CONTRACT", "check_engine_round", "check_program",
    "contracts_enabled", "maybe_check", "require", "select_contract",
    "EqnSite", "count_jaxpr_primitives", "find_collectives",
    "find_dtype_leaks", "find_jaxpr_primitives", "parse_collective_bytes",
    "parse_shape_bytes",
    "hot_path", "is_hot_path",
    "DonationAliasCovers", "MaxLiveBytes", "NoCollectives", "NoF64Leaks",
    "NoHostCallbacks", "NoPoolRankedScatters", "Program", "RecompileHazard",
    "Rule", "Violation", "census",
]
