"""Jaxpr / post-SPMD HLO parsing backend of the contract engine
(DESIGN.md §17; no jax side effects on import).

This is the measurement layer the declarative rules in
:mod:`repro.analysis.rules` are built on: text parsing of compiled HLO
(collective ops — including their *async* lowered forms — and dtype-sized
result shapes) and structural walks of ClosedJaxprs (primitive census with
recursion into ``while``/``scan``/``pjit``/pallas sub-jaxprs, with rank
filtering and per-equation evidence). It subsumes the former
``repro.launch.hlo_analysis`` module, which survives as a thin re-export
shim for external callers; everything in-repo goes through
``repro.analysis``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# Collective op spellings in post-SPMD HLO. The sync forms are how a
# single-stream lowering spells them; the ``-start`` forms are the async
# lowering (``--xla_..._enable_async_collectives`` and TPU/GPU defaults)
# where the op is split into start/done pairs — an async-lowered program
# used to slip past the zero-collective gate entirely (the PR 10 fix).
# Only the ``-start`` half is counted (the ``-done`` op consumes the
# handle and moves no new bytes); longer names must sort before their
# prefixes so ``all-reduce-start(`` is never misread as ``all-reduce(``.
_SYNC_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")
_ASYNC_COLLECTIVES = ("all-reduce-start", "all-gather-start",
                      "collective-permute-start")
_COLLECTIVES = tuple(sorted(_SYNC_COLLECTIVES + _ASYNC_COLLECTIVES,
                            key=len, reverse=True))

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_shape_bytes(type_text: str) -> int:
    """Sum the byte sizes of every ``dtype[dims]`` shape in ``type_text``
    (tuple result types contribute each element)."""
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


def find_collectives(hlo_text: str) -> list[dict]:
    """Every collective op in (post-SPMD) HLO text, with evidence: one
    record ``{op, line_no, line, bytes}`` per occurrence. Async-lowered
    start ops count like their sync forms (the regression the
    zero-collective gate needs); ``-done`` ops are skipped."""
    found = []
    for i, line in enumerate(hlo_text.splitlines(), start=1):
        stripped = line.strip()
        for coll in _COLLECTIVES:
            marker = f" {coll}("
            if marker not in stripped:
                continue
            # result type(s) appear between '=' and the op name
            lhs = stripped.split(marker)[0]
            if "=" not in lhs:
                continue
            type_part = lhs.split("=", 1)[1]
            found.append({"op": coll, "line_no": i,
                          "line": stripped[:200],
                          "bytes": parse_shape_bytes(type_part)})
            break
    return found


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO,
    keyed by *base* op name: async start forms fold into their sync
    spelling (``all-reduce-start`` counts as ``all-reduce``), so the
    zero-collective gate ``all(count == 0)`` covers both lowerings."""
    totals = {c: {"bytes": 0, "count": 0} for c in _SYNC_COLLECTIVES}
    for rec in find_collectives(hlo_text):
        base = rec["op"]
        if base.endswith("-start"):
            base = base[:-len("-start")]
        totals[base]["bytes"] += rec["bytes"]
        totals[base]["count"] += 1
    return totals


@dataclass
class EqnSite:
    """One matched equation inside a (possibly nested) jaxpr."""
    primitive: str
    rank: int                      # max output rank
    path: str                      # e.g. "while/body/pjit"
    eqn: str = field(repr=False, default="")   # pretty-printed, truncated
    shape: tuple = ()              # shape of the max-rank output

    def __str__(self):
        where = self.path or "<top>"
        return f"{self.primitive} (rank {self.rank}) at {where}: {self.eqn}"


def find_jaxpr_primitives(closed_jaxpr, names, min_rank: int = 0
                          ) -> list[EqnSite]:
    """Every equation matching ``names`` (and the rank filter) in a
    ClosedJaxpr, recursing into sub-jaxprs (scan/while/pjit/pallas
    bodies). Returns :class:`EqnSite` evidence records — the structured
    counterpart of :func:`count_jaxpr_primitives`, used by contract
    Reports to *name* the offending equation instead of just counting."""
    names = frozenset(names)
    sites: list[EqnSite] = []

    def visit(jaxpr, path):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim in names:
                shapes = [tuple(getattr(v.aval, "shape", ()))
                          for v in eqn.outvars]
                shape = max(shapes, key=len, default=())
                if len(shape) >= min_rank:
                    txt = str(eqn)
                    if len(txt) > 160:
                        txt = txt[:157] + "..."
                    sites.append(EqnSite(prim, len(shape), path, txt,
                                         shape))
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    sub_path = f"{path}/{eqn.primitive.name}" if path \
                        else eqn.primitive.name
                    visit(sub, sub_path)
    visit(getattr(closed_jaxpr, "jaxpr", closed_jaxpr), "")
    return sites


def count_jaxpr_primitives(closed_jaxpr, names, min_rank: int = 0):
    """Count primitive occurrences (by name) in a ClosedJaxpr, recursing
    into sub-jaxprs (scan/while/pjit/pallas bodies). ``min_rank`` filters to
    equations whose first output has at least that many dims — e.g.
    ``count_jaxpr_primitives(jaxpr, ("scatter",), min_rank=3)`` counts
    pool-shaped scatters (the standalone window-writeback the fused kernel
    epilogue eliminates) while ignoring small per-row bookkeeping updates.

    The fused-round acceptance gate (DESIGN.md §11): a verify round's jaxpr
    must contain ZERO pool-ranked scatter eqns — every physical-pool write
    happens inside a pallas_call as an aliased epilogue."""
    counts = {n: 0 for n in names}
    for site in find_jaxpr_primitives(closed_jaxpr, names, min_rank):
        counts[site.primitive] += 1
    return counts


def find_dtype_leaks(closed_jaxpr, dtypes=("float64", "complex128")
                     ) -> list[EqnSite]:
    """Equations producing outputs of any of ``dtypes`` (recursive) —
    the :class:`~repro.analysis.rules.NoF64Leaks` evidence walk. A stray
    f64 on the hot path silently doubles bandwidth (and diverges from the
    bf16/f32 bit-exactness story), so it is a contract violation, not a
    style nit."""
    wanted = frozenset(dtypes)
    sites: list[EqnSite] = []

    def visit(jaxpr, path):
        for eqn in jaxpr.eqns:
            hits = [v for v in eqn.outvars
                    if str(getattr(v.aval, "dtype", "")) in wanted]
            if hits:
                rank = max(len(getattr(v.aval, "shape", ()))
                           for v in hits)
                txt = str(eqn)
                if len(txt) > 160:
                    txt = txt[:157] + "..."
                sites.append(EqnSite(eqn.primitive.name, rank, path, txt))
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    sub_path = f"{path}/{eqn.primitive.name}" if path \
                        else eqn.primitive.name
                    visit(sub, sub_path)
    visit(getattr(closed_jaxpr, "jaxpr", closed_jaxpr), "")
    return sites


def _sub_jaxprs(value):
    """Yield any jaxprs nested inside an eqn param value."""
    import jax.extend.core as jex_core  # deferred: no import side effects

    vals = value if isinstance(value, (list, tuple)) else [value]
    for v in vals:
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jex_core.Jaxpr):
            yield v
