"""RWKV-6 "Finch" 7B [arXiv:2404.05892] — attention-free, data-dependent
decay. Assigned: 32L d_model=4096 d_ff=14336 vocab=65536.

long_500k decode is O(1)-state (the arch's raison d'etre); predictive
sampling verifies windows via the parallel ("GPT-mode") scan from the state
snapshot at the accept boundary (DESIGN.md §5)."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        arch_type="ssm",
        n_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab=65536,
        layer_block=(("rwkv", "rwkv_cmix"),),
        rwkv_head_dim=64,
        tie_embeddings=False,
        dtype="bfloat16",
        source="arXiv:2404.05892",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-reduced",
        arch_type="ssm",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        layer_block=(("rwkv", "rwkv_cmix"),),
        rwkv_head_dim=32,
        tie_embeddings=False,
        dtype="float32",
        source="arXiv:2404.05892",
    )
