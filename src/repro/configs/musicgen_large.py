"""MusicGen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.
Assigned: 48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.

Backbone only: the EnCodec conv codec is a stub frontend providing
conditioning-frame embeddings (n_prefix_tokens). The original uses learned
sinusoidal positions + GELU; we use RoPE (TPU-idiomatic substrate shared with
the rest of the zoo — noted in DESIGN.md §7). vocab=2048 is the per-codebook
EnCodec cardinality; the delay-pattern codebook interleave is represented as
a single flattened token stream."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        arch_type="audio",
        n_layers=48,
        d_model=2048,
        d_ff=8192,
        vocab=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        mlp_kind="gelu",
        tie_embeddings=False,
        modality="audio",
        n_prefix_tokens=256,      # conditioning frames (stub frontend)
        dtype="bfloat16",
        source="arXiv:2306.05284",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-reduced",
        arch_type="audio",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=256,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        mlp_kind="gelu",
        tie_embeddings=False,
        modality="audio",
        n_prefix_tokens=8,
        dtype="float32",
        source="arXiv:2306.05284",
    )
