"""Jamba-1.5-Large 398B [arXiv:2403.19887] — Mamba+attention 1:7 interleave,
MoE every other layer (16e top-2). Assigned: 72L d_model=8192 64H (kv=8)
d_ff=24576 vocab=65536. 72 layers = 9 x (8-layer Jamba block: attention at
index 3, MoE on odd layers)."""
from repro.models.transformer import ModelConfig

_BLOCK = (
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("attn", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
    ("mamba", "dense"), ("mamba", "moe"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type="hybrid",
        n_layers=72,
        d_model=8192,
        d_ff=24576,
        moe_d_ff=24576,
        vocab=65536,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        layer_block=_BLOCK,
        n_experts=16,
        top_k=2,
        mlp_kind="swiglu",
        ssm_state=16,
        tie_embeddings=False,
        dtype="bfloat16",
        source="arXiv:2403.19887",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-reduced",
        arch_type="hybrid",
        n_layers=8,
        d_model=256,
        d_ff=512,
        moe_d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        layer_block=_BLOCK,
        n_experts=4,
        top_k=2,
        mlp_kind="swiglu",
        ssm_state=8,
        tie_embeddings=False,
        dtype="float32",
        source="arXiv:2403.19887",
    )
