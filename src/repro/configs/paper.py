"""The paper's own experimental configs (PixelCNN image/latent ARMs and the
discrete autoencoder), full-size + CPU-reduced variants.

Full-size values follow Appendix A (Table 4); reduced variants preserve the
architecture family at a scale a single CPU core can train in minutes."""
from repro.core.forecasting import PixelForecastConfig
from repro.models.autoencoder import AutoencoderConfig
from repro.models.pixelcnn import PixelCNNConfig

# ---- explicit likelihood modelling (paper §4.1) ---------------------------

PIXELCNN_FULL = {
    "binary_mnist": PixelCNNConfig(height=28, width=28, channels=1,
                                   categories=2, filters=60, n_res=2),
    "svhn_8bit": PixelCNNConfig(height=32, width=32, channels=3,
                                categories=256, filters=162, n_res=5),
    "cifar10_5bit": PixelCNNConfig(height=32, width=32, channels=3,
                                   categories=32, filters=162, n_res=5),
    "cifar10_8bit": PixelCNNConfig(height=32, width=32, channels=3,
                                   categories=256, filters=162, n_res=5),
}

PIXELCNN_REDUCED = {
    "binary_mnist": PixelCNNConfig(height=12, width=12, channels=1,
                                   categories=2, filters=24, n_res=2,
                                   first_kernel=5),
    "svhn_8bit": PixelCNNConfig(height=8, width=8, channels=3,
                                categories=256, filters=24, n_res=2,
                                first_kernel=5),
    "cifar10_5bit": PixelCNNConfig(height=8, width=8, channels=3,
                                   categories=32, filters=24, n_res=2,
                                   first_kernel=5),
    "cifar10_8bit": PixelCNNConfig(height=8, width=8, channels=3,
                                   categories=256, filters=24, n_res=2,
                                   first_kernel=5),
}


def forecast_cfg(pix: PixelCNNConfig, horizon: int) -> PixelForecastConfig:
    """Paper: forecasting filters == ARM filters; T=20 (MNIST) / 1 or 5."""
    return PixelForecastConfig(channels=pix.channels,
                               categories=pix.categories,
                               horizon=horizon,
                               filters=pix.filters,
                               in_filters=pix.filters)


# ---- latent-space modelling (paper §4.2) ----------------------------------

AE_FULL = AutoencoderConfig(height=32, width=32, channels=3,
                            width_filters=512, latent_channels=4,
                            latent_categories=128)
LATENT_ARM_FULL = PixelCNNConfig(height=8, width=8, channels=4,
                                 categories=128, filters=160, n_res=5)

AE_REDUCED = AutoencoderConfig(height=16, width=16, channels=3,
                               width_filters=32, latent_channels=2,
                               latent_categories=16)
LATENT_ARM_REDUCED = PixelCNNConfig(height=4, width=4, channels=2,
                                    categories=16, filters=16, n_res=2,
                                    first_kernel=3)
