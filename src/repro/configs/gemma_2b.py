"""Gemma-2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1).
Assigned: 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        arch_type="dense",
        n_layers=18,
        d_model=2048,
        d_ff=16384,
        vocab=256000,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        layer_block=(("attn", "dense"),),
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
        dtype="bfloat16",
        source="arXiv:2403.08295",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=1,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
        dtype="float32",
        source="arXiv:2403.08295",
    )
