"""Mistral-Large-Instruct-2407 123B [hf:mistralai/Mistral-Large-Instruct-2407]
— dense GQA. Assigned: 88L d_model=12288 96H (kv=8) d_ff=28672 vocab=32768."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        arch_type="dense",
        n_layers=88,
        d_model=12288,
        d_ff=28672,
        vocab=32768,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        layer_block=(("attn", "dense"),),
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=False,
        dtype="bfloat16",
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=False,
        dtype="float32",
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
