"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family card] — dense GQA with qk_norm.
Assigned: 28L d_model=2048 16H (kv=8) d_ff=6144 vocab=151936."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        arch_type="dense",
        n_layers=28,
        d_model=2048,
        d_ff=6144,
        vocab=151936,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        layer_block=(("attn", "dense"),),
        qk_norm=True,
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=True,
        dtype="bfloat16",
        source="hf:Qwen/Qwen3-8B",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-reduced",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        qk_norm=True,
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=True,
        dtype="float32",
        source="hf:Qwen/Qwen3-8B",
    )
