"""Architecture registry: ``get_config(arch_id)`` returns the exact assigned
config; ``get_config(arch_id, reduced=True)`` returns the CPU-smoke variant
(<=8 layers, d_model<=512, <=4 experts) of the same family."""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, shape_applicable, InputShape
from repro.models.transformer import ModelConfig

ARCHS = (
    "deepseek-v3-671b",
    "qwen3-1.7b",
    "musicgen-large",
    "gemma-2b",
    "gemma3-1b",
    "rwkv6-7b",
    "jamba-1.5-large-398b",
    "internvl2-1b",
    "mistral-large-123b",
    "dbrx-132b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCHS}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.reduced_config() if reduced else mod.config()


def list_archs():
    return list(ARCHS)


__all__ = ["ARCHS", "get_config", "list_archs", "SHAPES",
           "shape_applicable", "InputShape", "ModelConfig"]
