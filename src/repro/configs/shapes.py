"""Assigned input shapes and (arch x shape) applicability."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic context handling: runnable for SSM/hybrid and
# the 5:1 sliding-window gemma3; skipped (and documented in DESIGN.md §5) for
# pure full-attention archs.
_LONG_OK = {"rwkv6-7b", "jamba-1.5-large-398b", "gemma3-1b"}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, ("pure full-attention architecture: 500k dense KV "
                       "decode skipped per brief (see DESIGN.md §5)")
    return True, ""
