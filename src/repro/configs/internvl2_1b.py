"""InternVL2-1B [arXiv:2404.16821] — InternViT vision encoder + LM decoder.
Assigned: 24L d_model=896 14H (kv=2) d_ff=4864 vocab=151655.

Backbone only: the InternViT encoder + MLP projector are a stub frontend
providing 256 patch embeddings as a prefix (the sanctioned carve-out)."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        arch_type="vlm",
        n_layers=24,
        d_model=896,
        d_ff=4864,
        vocab=151655,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=True,
        modality="vision",
        n_prefix_tokens=256,
        dtype="bfloat16",
        source="arXiv:2404.16821",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-reduced",
        arch_type="vlm",
        n_layers=2,
        d_model=256,
        d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        layer_block=(("attn", "dense"),),
        rope_theta=1e6,
        mlp_kind="swiglu",
        tie_embeddings=True,
        modality="vision",
        n_prefix_tokens=8,
        dtype="float32",
        source="arXiv:2404.16821",
    )
