"""Gemma3-1B [hf:google/gemma-3-1b-pt] — 5:1 local(sliding-window):global
attention, 128k-capable. Assigned: 26L d_model=1152 4H (kv=1) d_ff=6912
vocab=262144. 26 layers = 4 full (5 local + 1 global) blocks + 2 trailing
local layers. Sliding window 512 makes long_500k decode runnable."""
from repro.models.transformer import ModelConfig

_BLOCK = (("local", "dense"),) * 5 + (("attn", "dense"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        arch_type="dense",
        n_layers=26,
        d_model=1152,
        d_ff=6912,
        vocab=262144,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        layer_block=_BLOCK,
        layer_suffix=(("local", "dense"),) * 2,
        sliding_window=512,
        qk_norm=True,
        rope_theta=1e6,
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
        dtype="bfloat16",
        source="hf:google/gemma-3-1b-pt",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-reduced",
        arch_type="dense",
        n_layers=4,
        d_model=256,
        d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=1,
        head_dim=64,
        layer_block=(("local", "dense"),) * 3 + (("attn", "dense"),),
        sliding_window=16,
        qk_norm=True,
        rope_theta=1e6,
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
        dtype="float32",
        source="hf:google/gemma-3-1b-pt",
    )
