"""DBRX 132B [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4
on every layer. Assigned: 40L d_model=6144 48H (kv=8) d_ff=10752(expert)
vocab=100352."""
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        arch_type="moe",
        n_layers=40,
        d_model=6144,
        d_ff=10752,
        moe_d_ff=10752,
        vocab=100352,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        layer_block=(("attn", "moe"),),
        n_experts=16,
        top_k=4,
        rope_theta=5e5,
        mlp_kind="swiglu",
        tie_embeddings=False,
        dtype="bfloat16",
        source="hf:databricks/dbrx-base",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-reduced",
        arch_type="moe",
        n_layers=2,
        d_model=256,
        d_ff=512,
        moe_d_ff=512,
        vocab=512,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        layer_block=(("attn", "moe"),),
        n_experts=4,
        top_k=2,
        rope_theta=5e5,
        mlp_kind="swiglu",
        tie_embeddings=False,
        dtype="float32",
        source="hf:databricks/dbrx-base",
    )
