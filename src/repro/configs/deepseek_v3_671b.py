"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA + 1 shared/256 routed top-8 MoE
+ MTP. Assigned: 61L d_model=7168 128H d_ff=2048(expert) vocab=129280.

The MTP head is implemented as the paper's learned forecasting module
(forecast_horizon=2): DESIGN.md §5 — predictive sampling verifies MTP drafts
with Gumbel-max reparametrized acceptance, giving exact samples."""
from repro.models.transformer import ModelConfig

_MLA = dict(q_lora_rank=1536, kv_lora_rank=512, qk_rope_dim=64,
            qk_nope_dim=128, v_head_dim=128)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        d_ff=18432,                 # dense-prefix FFN width [paper §4]
        moe_d_ff=2048,              # assigned expert width
        vocab=129280,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        layer_prefix=(("mla", "dense"),) * 3,   # first-3-dense [paper]
        layer_block=(("mla", "moe"),),
        n_experts=256,
        n_shared_experts=1,
        top_k=8,
        router_score="sigmoid",     # DeepSeek-V3 scoring
        mlp_kind="swiglu",
        tie_embeddings=False,
        forecast_horizon=2,         # MTP depth 1 == forecast offsets {0,1}
        forecast_hidden=0,
        dtype="bfloat16",
        source="arXiv:2412.19437",
        **_MLA,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced",
        arch_type="moe",
        n_layers=2,
        d_model=256,
        d_ff=512,
        moe_d_ff=128,
        vocab=512,
        n_heads=4,
        n_kv_heads=4,
        head_dim=64,
        layer_prefix=(("mla", "dense"),),
        layer_block=(("mla", "moe"),),
        n_experts=4,
        n_shared_experts=1,
        top_k=2,
        router_score="sigmoid",
        mlp_kind="swiglu",
        tie_embeddings=False,
        forecast_horizon=2,
        q_lora_rank=64, kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=48,
        v_head_dim=64,
        dtype="float32",
        source="arXiv:2412.19437",
    )
