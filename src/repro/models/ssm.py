"""Attention-free mixers: RWKV-6 ("Finch") time/channel mix and Mamba-1
selective SSM (as interleaved in Jamba).

Both support:
* ``full``   — scan from zero state over the whole sequence (train/prefill).
* ``window`` — scan a W-token verify window starting from a carried state
  snapshot, returning per-position states so the predictive-sampling engine
  can adopt the state at its accept point (see DESIGN.md §5: recurrent state
  is cumulative, so the engine snapshots at the last accepted position).

Recurrences use ``jax.lax.scan`` over time — the Pallas `rwkv_wkv` kernel
(kernels/rwkv_wkv/) provides the chunked TPU implementation of the WKV loop;
ops.py dispatches to it when enabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import Dense, LayerNorm

# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------


def _lora_init(key, dim, rank, out_dim, dtype):
    k1, k2 = jax.random.split(key)
    return {"a": 0.02 * jax.random.normal(k1, (dim, rank), dtype=dtype),
            "b": 0.02 * jax.random.normal(k2, (rank, out_dim), dtype=dtype)}


def _lora_apply(p, x, base=None):
    y = jnp.tanh(x @ p["a"]) @ p["b"]
    return y if base is None else base + y


class RWKV6TimeMix:
    """Data-dependent-decay time mixing (the Finch contribution)."""

    MIX_KEYS = ("r", "k", "v", "w", "g")

    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        D = cfg.d_model
        hd = cfg.rwkv_head_dim
        H = D // hd
        ks = jax.random.split(key, 12)
        p = {
            # token-shift interpolation factors (static part)
            "mu": {m: 0.5 * jnp.ones((D,), dtype) for m in RWKV6TimeMix.MIX_KEYS},
            "mu_x": 0.5 * jnp.ones((D,), dtype),
            # data-dependent lerp LoRAs
            "lora": {m: _lora_init(ks[i], D, 32, D, dtype)
                     for i, m in enumerate(RWKV6TimeMix.MIX_KEYS)},
            "wr": Dense.init(ks[5], D, D, use_bias=False, dtype=dtype),
            "wk": Dense.init(ks[6], D, D, use_bias=False, dtype=dtype),
            "wv": Dense.init(ks[7], D, D, use_bias=False, dtype=dtype),
            "wg": Dense.init(ks[8], D, D, use_bias=False, dtype=dtype),
            "wo": Dense.init(ks[9], D, D, use_bias=False, dtype=dtype),
            # decay: w_t = exp(-exp(w0 + lora_w(x_mixed)))  (data-dependent!)
            "w0": -6.0 + 0.5 * jax.random.normal(ks[10], (D,), dtype),
            "w_lora": _lora_init(ks[11], D, 64, D, dtype),
            "u": 0.5 * jnp.ones((H, hd), dtype),          # bonus
            "ln_out": LayerNorm.init(D, dtype=dtype),     # group-norm stand-in
        }
        return p

    @staticmethod
    def _mix(p, x, x_prev):
        """Token-shift ddlerp (v6): per-stream data-dependent interpolation.

        x: (B, T, D); x_prev: (B, T, D) shifted-by-one inputs."""
        dx = x_prev - x
        xx = x + dx * p["mu_x"]
        mixed = {}
        for m in RWKV6TimeMix.MIX_KEYS:
            mixed[m] = x + dx * (p["mu"][m] + _lora_apply(p["lora"][m], xx))
        return mixed

    @staticmethod
    def _wkv_scan(r, k, v, w, u, state0):
        """WKV recurrence. r,k,v,w: (B, T, H, hd); state0: (B, H, hd, hd).

        S_t = diag(w_t) S_{t-1} + k_t^T v_t
        y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
        Returns y (B, T, H, hd) and per-step states (B, T, H, hd, hd).
        """
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp  # (B, H, hd)
            kv = k_t[..., :, None] * v_t[..., None, :]       # (B, H, hd, hd)
            y = jnp.einsum("bhk,bhkv->bhv", r_t,
                           S + u[None, :, :, None] * kv)
            S_new = w_t[..., :, None] * S + kv
            return S_new, (y, S_new)

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
        S_fin, (ys, Ss) = jax.lax.scan(step, state0, xs)
        return jnp.moveaxis(ys, 0, 1), jnp.moveaxis(Ss, 0, 1)

    @staticmethod
    def _project(p, x, x_prev, cfg):
        B, T, D = x.shape
        hd = cfg.rwkv_head_dim
        H = D // hd
        m = RWKV6TimeMix._mix(p, x, x_prev)
        r = Dense.apply(p["wr"], m["r"]).reshape(B, T, H, hd)
        k = Dense.apply(p["wk"], m["k"]).reshape(B, T, H, hd)
        v = Dense.apply(p["wv"], m["v"]).reshape(B, T, H, hd)
        g = jax.nn.silu(Dense.apply(p["wg"], m["g"]))
        w = jnp.exp(-jnp.exp(
            (p["w0"] + _lora_apply(p["w_lora"], m["w"])).astype(jnp.float32)))
        w = w.reshape(B, T, H, hd).astype(x.dtype)
        return r, k, v, w, g

    @staticmethod
    def _finish(p, y, g, B, T, D):
        y = LayerNorm.apply(p["ln_out"], y.reshape(B, T, D))
        return Dense.apply(p["wo"], y * g)

    SCAN_CHUNK = 64

    @staticmethod
    def _wkv_scan_chunked(r, k, v, w, u, state0):
        """Chunk-checkpointed WKV (§Perf A1 treatment): backward stores only
        chunk-boundary states; the Pallas rwkv_wkv kernel is the TPU fast
        path with the same chunking."""
        B, T, H, hd = r.shape
        ck = RWKV6TimeMix.SCAN_CHUNK
        while T % ck:
            ck //= 2
        n_chunks = T // ck

        def step(S, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[..., :, None] * v_t[..., None, :]
            y = jnp.einsum("bhk,bhkv->bhv", r_t,
                           S + u[None, :, :, None] * kv)
            return w_t[..., :, None] * S + kv, y

        @jax.checkpoint
        def chunk_fn(S, xs_c):
            return jax.lax.scan(step, S, xs_c)

        xs = tuple(jnp.reshape(jnp.moveaxis(a, 1, 0),
                               (n_chunks, ck) + a.shape[0:1] + a.shape[2:])
                   for a in (r, k, v, w))
        _, ys = jax.lax.scan(chunk_fn, state0, xs)
        return jnp.moveaxis(ys.reshape(T, B, H, hd), 0, 1)

    @staticmethod
    def full(p, x, cfg):
        B, T, D = x.shape
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        r, k, v, w, g = RWKV6TimeMix._project(p, x, x_prev, cfg)
        hd = cfg.rwkv_head_dim
        H = D // hd
        S0 = jnp.zeros((B, H, hd, hd), x.dtype)
        if T >= 256:
            y = RWKV6TimeMix._wkv_scan_chunked(r, k, v, w, p["u"], S0)
        else:
            y, _ = RWKV6TimeMix._wkv_scan(r, k, v, w, p["u"], S0)
        return RWKV6TimeMix._finish(p, y, g, B, T, D)

    @staticmethod
    def init_state(cfg, batch: int, dtype=jnp.float32):
        D, hd = cfg.d_model, cfg.rwkv_head_dim
        return {"x_last": jnp.zeros((batch, D), dtype),
                "S": jnp.zeros((batch, D // hd, hd, hd), dtype)}

    @staticmethod
    def window(p, x, cfg, state):
        """x: (B, W, D); state carries (x_last, S) from the accepted prefix.
        Returns (y, per-position states dict with leading (B, W) axes)."""
        B, W, D = x.shape
        x_prev = jnp.concatenate([state["x_last"][:, None], x[:, :-1]], axis=1)
        r, k, v, w, g = RWKV6TimeMix._project(p, x, x_prev, cfg)
        y, Ss = RWKV6TimeMix._wkv_scan(r, k, v, w, p["u"], state["S"])
        states = {"x_last": x, "S": Ss}  # per-position snapshots
        return RWKV6TimeMix._finish(p, y, g, B, W, D), states

    @staticmethod
    def advance_state(p, x, cfg, state, accept):
        """Two-pass memory mode (§Perf C4): state after ``accept`` tokens
        only, no per-position (B, W, H, hd, hd) stack."""
        B, W, D = x.shape
        hd = cfg.rwkv_head_dim
        x_prev = jnp.concatenate([state["x_last"][:, None], x[:, :-1]],
                                 axis=1)
        r, k, v, w, g = RWKV6TimeMix._project(p, x, x_prev, cfg)

        def step(carry, inp):
            S, t = carry
            _, k_t, v_t, w_t = inp
            kv = k_t[..., :, None] * v_t[..., None, :]
            S_new = w_t[..., :, None] * S + kv
            live = (t < accept)[:, None, None, None]
            return (jnp.where(live, S_new, S), t + 1), None

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
        (S_fin, _), _ = jax.lax.scan(
            step, (state["S"], jnp.zeros((), jnp.int32)), xs)
        x_last = jnp.take_along_axis(
            x, jnp.maximum(accept - 1, 0)[:, None, None], axis=1)[:, 0]
        return {"x_last": x_last, "S": S_fin}


class RWKV6ChannelMix:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        D, F = cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 3)
        return {
            "mu_k": 0.5 * jnp.ones((D,), dtype),
            "mu_r": 0.5 * jnp.ones((D,), dtype),
            "wk": Dense.init(ks[0], D, F, use_bias=False, dtype=dtype),
            "wv": Dense.init(ks[1], F, D, use_bias=False, dtype=dtype),
            "wr": Dense.init(ks[2], D, D, use_bias=False, dtype=dtype),
        }

    @staticmethod
    def _apply(p, x, x_prev):
        dx = x_prev - x
        xk = x + dx * p["mu_k"]
        xr = x + dx * p["mu_r"]
        k = jnp.square(jax.nn.relu(Dense.apply(p["wk"], xk)))
        return jax.nn.sigmoid(Dense.apply(p["wr"], xr)) * Dense.apply(p["wv"], k)

    @staticmethod
    def full(p, x, cfg):
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        return RWKV6ChannelMix._apply(p, x, x_prev)

    @staticmethod
    def init_state(cfg, batch: int, dtype=jnp.float32):
        return {"x_last": jnp.zeros((batch, cfg.d_model), dtype)}

    @staticmethod
    def window(p, x, cfg, state):
        x_prev = jnp.concatenate([state["x_last"][:, None], x[:, :-1]], axis=1)
        y = RWKV6ChannelMix._apply(p, x, x_prev)
        return y, {"x_last": x}

    @staticmethod
    def advance_state(p, x, cfg, state, accept):
        x_last = jnp.take_along_axis(
            x, jnp.maximum(accept - 1, 0)[:, None, None], axis=1)[:, 0]
        return {"x_last": x_last}


# ---------------------------------------------------------------------------
# Mamba-1 (Jamba's SSM layer)
# ---------------------------------------------------------------------------

class Mamba:
    D_CONV = 4

    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        D = cfg.d_model
        DI = 2 * D                       # d_inner (expand=2)
        N = cfg.ssm_state
        dt_rank = max(1, D // 16)
        ks = jax.random.split(key, 6)
        A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (DI, 1))
        return {
            "in_proj": Dense.init(ks[0], D, 2 * DI, use_bias=False,
                                  dtype=dtype),
            "conv_w": 0.1 * jax.random.normal(ks[1], (Mamba.D_CONV, DI),
                                              dtype=dtype),
            "conv_b": jnp.zeros((DI,), dtype),
            "x_proj": Dense.init(ks[2], DI, dt_rank + 2 * N, use_bias=False,
                                 dtype=dtype),
            "dt_proj": Dense.init(ks[3], dt_rank, DI, dtype=dtype),
            "A_log": jnp.log(A).astype(dtype),
            "D": jnp.ones((DI,), dtype),
            "out_proj": Dense.init(ks[4], DI, D, use_bias=False, dtype=dtype),
        }

    @staticmethod
    def _conv(p, u, conv_state):
        """Causal depthwise conv. u: (B, T, DI); conv_state: (B, D_CONV-1, DI)
        holds the last inputs of the accepted prefix."""
        ext = jnp.concatenate([conv_state, u], axis=1)
        T = u.shape[1]
        taps = [ext[:, t:t + T] * p["conv_w"][t] for t in range(Mamba.D_CONV)]
        y = sum(taps) + p["conv_b"]
        new_state = ext[:, -(Mamba.D_CONV - 1):] if Mamba.D_CONV > 1 else ext[:, :0]
        return jax.nn.silu(y), new_state, ext

    @staticmethod
    def _dt_b_c(p, u, cfg):
        N = cfg.ssm_state
        dt_rank = p["dt_proj"]["w"].shape[0]
        xdbc = Dense.apply(p["x_proj"], u)
        dt = jax.nn.softplus(
            Dense.apply(p["dt_proj"], xdbc[..., :dt_rank]).astype(jnp.float32))
        Bm = xdbc[..., dt_rank:dt_rank + N].astype(jnp.float32)   # (B, T, N)
        Cm = xdbc[..., dt_rank + N:].astype(jnp.float32)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (DI, N)
        return dt, Bm, Cm, A

    @staticmethod
    def _ssm_scan(p, u, cfg, h0):
        """Selective scan, per-step states retained (decode-window mode).
        u: (B, T, DI); h0: (B, DI, N). Returns y, states (B, T, DI, N)."""
        dt, Bm, Cm, A = Mamba._dt_b_c(p, u, cfg)

        def step(h, inp):
            dt_t, B_t, C_t, u_t = inp                  # time-major slices
            dA = jnp.exp(dt_t[..., None] * A[None])    # (B, DI, N)
            h_new = dA * h + (dt_t[..., None] * B_t[:, None, :]
                              * u_t[..., None])
            y_t = jnp.einsum("bdn,bn->bd", h_new, C_t)
            return h_new, (y_t, h_new)

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (dt, Bm, Cm, u.astype(jnp.float32)))
        _, (ys, hs) = jax.lax.scan(step, h0.astype(jnp.float32), xs)
        y = jnp.moveaxis(ys, 0, 1)
        y = y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)
        return y.astype(u.dtype), jnp.moveaxis(hs, 0, 1).astype(u.dtype)

    # chunked-checkpointed scan for long sequences (§Perf iteration A1):
    # never materializes (B, T, DI, N) discretized tensors; backward stores
    # only chunk-boundary states and recomputes within a chunk.
    SCAN_CHUNK = 64

    @staticmethod
    def _ssm_scan_chunked(p, u, cfg, h0):
        B, T, DI = u.shape
        dt, Bm, Cm, A = Mamba._dt_b_c(p, u, cfg)
        ck = Mamba.SCAN_CHUNK
        while T % ck:
            ck //= 2
        n_chunks = T // ck
        io_dtype = u.dtype   # §Perf A2: scan inputs/outputs in model dtype
        #                     (bf16); the recurrence carry stays f32 — same
        #                     layout real Mamba kernels use.

        def step(h, inp):
            dt_t, B_t, C_t, u_t = (a.astype(jnp.float32) for a in inp)
            dA = jnp.exp(dt_t[..., None] * A[None])
            h_new = dA * h + (dt_t[..., None] * B_t[:, None, :]
                              * u_t[..., None])
            y_t = jnp.einsum("bdn,bn->bd", h_new, C_t)
            return h_new, y_t.astype(io_dtype)

        @jax.checkpoint
        def chunk_fn(h, xs_c):
            return jax.lax.scan(step, h, xs_c)

        xs = tuple(jnp.reshape(jnp.moveaxis(a.astype(io_dtype), 1, 0),
                               (n_chunks, ck) + a.shape[0:1] + a.shape[2:])
                   for a in (dt, Bm, Cm, u))
        _, ys = jax.lax.scan(chunk_fn, h0.astype(jnp.float32), xs)
        y = jnp.moveaxis(ys.reshape(T, B, DI), 0, 1).astype(jnp.float32)
        y = y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)
        return y.astype(u.dtype)

    @staticmethod
    def _run(p, x, cfg, conv_state, h0):
        B, T, D = x.shape
        xz = Dense.apply(p["in_proj"], x)
        u, z = jnp.split(xz, 2, axis=-1)
        u, new_conv, ext = Mamba._conv(p, u, conv_state)
        y, hs = Mamba._ssm_scan(p, u, cfg, h0)
        y = y * jax.nn.silu(z)
        return Dense.apply(p["out_proj"], y), new_conv, hs, ext

    @staticmethod
    def full(p, x, cfg):
        B, T, D = x.shape
        DI = 2 * D
        conv0 = jnp.zeros((B, Mamba.D_CONV - 1, DI), x.dtype)
        h0 = jnp.zeros((B, DI, cfg.ssm_state), x.dtype)
        if T >= 256:   # chunk-checkpointed long-sequence path (§Perf A1)
            xz = Dense.apply(p["in_proj"], x)
            u, z = jnp.split(xz, 2, axis=-1)
            u, _, _ = Mamba._conv(p, u, conv0)[0:3]
            y = Mamba._ssm_scan_chunked(p, u, cfg, h0)
            y = y * jax.nn.silu(z)
            return Dense.apply(p["out_proj"], y)
        y, _, _, _ = Mamba._run(p, x, cfg, conv0, h0)
        return y

    @staticmethod
    def init_state(cfg, batch: int, dtype=jnp.float32):
        DI = 2 * cfg.d_model
        return {"conv": jnp.zeros((batch, Mamba.D_CONV - 1, DI), dtype),
                "h": jnp.zeros((batch, DI, cfg.ssm_state), dtype)}

    @staticmethod
    def window(p, x, cfg, state):
        """Returns (y, per-position states): conv inputs and ssm states at
        every window position, so the engine can rewind to its accept point."""
        B, W, D = x.shape
        y, _, hs, ext = Mamba._run(p, x, cfg, state["conv"], state["h"])
        # per-position conv states: after window pos t the last D_CONV-1
        # inputs end at t -> ext indices (t+1 .. t+D_CONV-1)
        idx = (jnp.arange(W)[:, None] + 1
               + jnp.arange(Mamba.D_CONV - 1)[None, :])
        conv_pp = ext[:, idx]          # (B, W, D_CONV-1, DI)
        return y, {"conv": conv_pp, "h": hs}

    @staticmethod
    def advance_state(p, x, cfg, state, accept):
        """Two-pass memory mode (§Perf C4): recompute the window and return
        ONLY the state after ``accept`` (B,) tokens — per-step updates are
        masked off once t >= accept, so no (B, W, DI, N) stack exists."""
        B, W, D = x.shape
        xz = Dense.apply(p["in_proj"], x)
        u, _ = jnp.split(xz, 2, axis=-1)
        u, _, ext = Mamba._conv(p, u, state["conv"])
        dt, Bm, Cm, A = Mamba._dt_b_c(p, u, cfg)

        def step(carry, inp):
            h, t = carry
            dt_t, B_t, u_t = inp
            dA = jnp.exp(dt_t[..., None] * A[None])
            h_new = dA * h + (dt_t[..., None] * B_t[:, None, :]
                              * u_t[..., None])
            live = (t < accept)[:, None, None]
            return (jnp.where(live, h_new, h), t + 1), None

        xs = tuple(jnp.moveaxis(a, 1, 0)
                   for a in (dt, Bm, u.astype(jnp.float32)))
        (h_fin, _), _ = jax.lax.scan(
            step, (state["h"].astype(jnp.float32), jnp.zeros((), jnp.int32)),
            xs)
        # conv state after `accept` tokens: ext indices accept..accept+2
        idx = (accept[:, None] + jnp.arange(Mamba.D_CONV - 1)[None, :])
        conv = jnp.take_along_axis(
            ext, idx[:, :, None].astype(jnp.int32), axis=1)
        return {"conv": conv, "h": h_fin.astype(x.dtype)}
