"""PixelCNN ARM with fully-categorical channel-autoregressive output.

Paper Appendix A.1 family: masked convolutions (mask A on the input, mask B
inside), gated residual blocks with concat_elu, one-hot input encoding, and a
categorical output distribution per (channel, row, col) in raster-scan order
with channel-minor flat index ``i = (h*W + w)*C + c``.

The network exposes ``apply -> (logits, h)`` where ``h`` is the penultimate
representation shared with forecasting modules (paper §2.2), and a flat ARM
interface for the predictive-sampling driver.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.core import MaskedConv2D, concat_elu, group_ids


@dataclass(frozen=True)
class PixelCNNConfig:
    height: int = 28
    width: int = 28
    channels: int = 1
    categories: int = 2           # K (2 = binary MNIST; 32 = 5-bit; 256 = 8-bit)
    filters: int = 60             # per-layer filters (paper: 60 MNIST, 162 default)
    n_res: int = 2                # gated residual blocks (paper: 2 MNIST, 5 default)
    kernel: int = 3
    first_kernel: int = 7

    @property
    def d(self) -> int:
        return self.height * self.width * self.channels

    def flat_to_chw(self, i):
        """flat index -> (c, h, w) under channel-minor raster order."""
        c = i % self.channels
        p = i // self.channels
        return c, p // self.width, p % self.width


class PixelCNN:
    @staticmethod
    def init(key, cfg: PixelCNNConfig, dtype=jnp.float32):
        C, K, F = cfg.channels, cfg.categories, cfg.filters
        assert F % C == 0, "filters must be divisible by channels for group-AR"
        keys = jax.random.split(key, 2 + 2 * cfg.n_res)
        # one-hot input: C*K channels, group id = data channel
        g_in = np.repeat(np.arange(C), K)
        g_f = group_ids(F, C)
        g_2f = np.concatenate([g_f, g_f])  # concat_elu duplicates groups
        params = {
            "in_conv": MaskedConv2D.init(
                keys[0], C * K, F, (cfg.first_kernel, cfg.first_kernel),
                mask_type="A", groups_in=g_in, groups_out=g_f, dtype=dtype),
            "res": [],
        }
        for r in range(cfg.n_res):
            params["res"].append({
                "conv1": MaskedConv2D.init(
                    keys[1 + 2 * r], 2 * F, F, (cfg.kernel, cfg.kernel),
                    mask_type="B", groups_in=g_2f, groups_out=g_f, dtype=dtype),
                "conv2": MaskedConv2D.init(
                    keys[2 + 2 * r], 2 * F, 2 * F, (cfg.kernel, cfg.kernel),
                    mask_type="B", groups_in=g_2f, groups_out=g_2f, dtype=dtype),
            })
        params["out_conv"] = MaskedConv2D.init(
            keys[-1], 2 * F, C * K, (1, 1), mask_type="B",
            groups_in=g_2f, groups_out=np.repeat(np.arange(C), K), dtype=dtype)
        return params

    @staticmethod
    def apply(params, x_onehot, cfg: PixelCNNConfig):
        """x_onehot: (B, H, W, C*K) float. Returns (logits (B,H,W,C,K),
        h (B,H,W,F)) — h is the shared representation (last residual out)."""
        C, K = cfg.channels, cfg.categories
        u = MaskedConv2D.apply(params["in_conv"], x_onehot)
        for blk in params["res"]:
            v = MaskedConv2D.apply(blk["conv1"], concat_elu(u))
            v = MaskedConv2D.apply(blk["conv2"], concat_elu(v))
            a, b = jnp.split(v, 2, axis=-1)
            u = u + a * jax.nn.sigmoid(b)
        h = u
        logits = MaskedConv2D.apply(params["out_conv"], concat_elu(h))
        B, H, W, _ = logits.shape
        return logits.reshape(B, H, W, C, K), h

    # ------------------------------------------------------------------
    # int-image helpers
    # ------------------------------------------------------------------
    @staticmethod
    def onehot(x_int, cfg: PixelCNNConfig):
        """(B, H, W, C) int -> (B, H, W, C*K) one-hot float."""
        oh = jax.nn.one_hot(x_int, cfg.categories, dtype=jnp.float32)
        B, H, W, C, K = oh.shape
        return oh.reshape(B, H, W, C * K)

    @staticmethod
    def forward_int(params, x_int, cfg: PixelCNNConfig):
        return PixelCNN.apply(params, PixelCNN.onehot(x_int, cfg), cfg)

    @staticmethod
    def log_likelihood(params, x_int, cfg: PixelCNNConfig):
        """Mean log-likelihood (nats per image) of int images (B, H, W, C)."""
        logits, _ = PixelCNN.forward_int(params, x_int, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, x_int[..., None], axis=-1)[..., 0]
        return jnp.sum(ll, axis=(1, 2, 3))

    @staticmethod
    def bpd(params, x_int, cfg: PixelCNNConfig):
        """Bits per dimension."""
        ll = PixelCNN.log_likelihood(params, x_int, cfg)
        return -jnp.mean(ll) / (cfg.d * jnp.log(2.0))

    # ------------------------------------------------------------------
    # Flat ARM interface for the predictive-sampling driver
    # ------------------------------------------------------------------
    @staticmethod
    def make_arm_fn(params, cfg: PixelCNNConfig):
        """Returns ``arm_fn(x_flat (B, d) int) -> (logits (B, d, K), h)`` with
        strict triangular dependence in the channel-minor raster order."""
        C, H, W = cfg.channels, cfg.height, cfg.width

        def arm_fn(x_flat):
            B = x_flat.shape[0]
            x_img = x_flat.reshape(B, H, W, C)
            logits, h = PixelCNN.forward_int(params, x_img, cfg)
            return logits.reshape(B, cfg.d, cfg.categories), h

        return arm_fn
