"""LM losses: next-token cross-entropy + optional forecasting-KL (the paper's
Eq. 9 integrated into training, weight 0.01) + MoE aux."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerLM


def next_token_xent(logits, tokens):
    """logits (B, S, V) over the TOKEN part of the sequence; tokens (B, S).
    Position s predicts token s+1 (last position unused)."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - true)


def lm_loss(params, cfg, tokens, prefix_embeddings=None,
            moe_aux_weight: float = 0.01, moe_capacity: float = 1.25,
            remat: bool = False):
    """Full training loss. Returns (loss, metrics dict). Training uses
    finite MoE capacity (dropping); inference paths use no-drop."""
    logits, h, aux = TransformerLM.apply(params, cfg, tokens,
                                         prefix_embeddings,
                                         moe_capacity=moe_capacity,
                                         remat=remat)
    n_pre = 0 if prefix_embeddings is None else prefix_embeddings.shape[1]
    tok_logits = logits[:, n_pre:]
    xent = next_token_xent(tok_logits, tokens)
    loss = xent + moe_aux_weight * aux
    metrics = {"xent": xent, "moe_aux": aux}

    if cfg.forecast_horizon and "forecast" in params:
        from repro.core.forecasting import TokenForecast, TokenForecastConfig
        fcfg = TokenForecastConfig(cfg.d_model, cfg.vocab,
                                   cfg.forecast_horizon, cfg.forecast_hidden)
        h_tok = h[:, n_pre:]
        fc_logits = TokenForecast.apply(params["forecast"], h_tok, fcfg)
        # arm_logits[s] = dist over token s given x_{<s}: shift LM logits
        arm = jnp.pad(tok_logits, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        kl = TokenForecast.kl_loss(fc_logits, arm)
        loss = loss + cfg.forecast_loss_weight * kl
        metrics["forecast_kl"] = kl

    metrics["loss"] = loss
    return loss, metrics
