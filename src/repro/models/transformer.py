"""Generic decoder stack assembled from a ModelConfig.

One model class covers all 10 assigned architectures: the per-layer spec
(mixer kind x FFN kind) is laid out as ``prefix + n_blocks * block + suffix``
so homogeneous segments compile as a single ``lax.scan`` body (essential —
the 61..88-layer dry-run configs would otherwise produce enormous HLO).

Modes:
* ``apply``         — full-sequence forward (training / prefill); returns
                      (logits, h, aux) where ``h`` is the shared penultimate
                      representation (paper §2.2) feeding forecasting/MTP
                      heads.
* ``decode_window`` — W verify tokens against per-layer caches/state
                      snapshots (predictive-sampling serving step).

Multimodal backbones (audio/VLM) consume stub frontend embeddings as a
prefix (see frontends.py and DESIGN.md carve-out).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import GQAttention, MLAttention
from repro.models.moe import MoE
from repro.models.ssm import Mamba, RWKV6ChannelMix, RWKV6TimeMix
from repro.nn.core import Dense, Embedding, RMSNorm
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

LayerSpec = tuple  # (mixer: str, ffn: str); mixer in {attn, local, mla,
#                    mamba, rwkv}; ffn in {dense, moe, rwkv_cmix}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    # layer layout
    layer_prefix: tuple = ()
    layer_block: tuple = (("attn", "dense"),)
    layer_suffix: tuple = ()
    # attention
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0             # for "local" mixer layers
    # MLP
    mlp_kind: str = "swiglu"            # swiglu|geglu|gelu
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    router_score: str = "softmax"       # softmax|sigmoid
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # SSM
    ssm_state: int = 16
    rwkv_head_dim: int = 64
    # embeddings / head
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma: h *= sqrt(d_model)
    # forecasting / MTP (the paper's learned-forecasting integration)
    forecast_horizon: int = 0
    forecast_hidden: int = 0
    forecast_loss_weight: float = 0.01  # paper Appendix A
    # multimodal stub frontend
    modality: str = "text"              # text|audio|vision
    n_prefix_tokens: int = 0            # frontend embedding count
    # numerics
    dtype: str = "float32"
    # documentation
    source: str = ""

    @property
    def n_blocks(self) -> int:
        per = len(self.layer_block)
        rem = self.n_layers - len(self.layer_prefix) - len(self.layer_suffix)
        assert rem % per == 0, (self.name, rem, per)
        return rem // per

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_specs(self):
        return (list(self.layer_prefix)
                + list(self.layer_block) * self.n_blocks
                + list(self.layer_suffix))


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

_MIXERS = {
    "attn": GQAttention,
    "local": GQAttention,
    "mla": MLAttention,
    "mamba": Mamba,
    "rwkv": RWKV6TimeMix,
}


def _layer_init(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    p = {"norm1": RMSNorm.init(cfg.d_model, dtype=dtype),
         "mixer": _MIXERS[mixer].init(k1, cfg, dtype=dtype),
         "norm2": RMSNorm.init(cfg.d_model, dtype=dtype)}
    if ffn == "dense":
        from repro.models.moe import _mlp_init
        p["ffn"] = _mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    elif ffn == "moe":
        p["ffn"] = MoE.init(k2, cfg, dtype=dtype)
    elif ffn == "rwkv_cmix":
        p["ffn"] = RWKV6ChannelMix.init(k2, cfg, dtype=dtype)
    else:
        raise ValueError(ffn)
    return p


def _layer_full(p, spec: LayerSpec, cfg: ModelConfig, h, aux,
                moe_capacity=None):
    mixer, ffn = spec
    u = RMSNorm.apply(p["norm1"], h)
    if mixer in ("attn", "local"):
        window = cfg.sliding_window if mixer == "local" else 0
        y = GQAttention.full(p["mixer"], u, cfg, window=window)
    elif mixer == "mla":
        y = MLAttention.full(p["mixer"], u, cfg)
    elif mixer == "mamba":
        y = Mamba.full(p["mixer"], u, cfg)
    elif mixer == "rwkv":
        y = RWKV6TimeMix.full(p["mixer"], u, cfg)
    h = h + y
    h = constrain(h, ("batch", "seq", "embed"))

    v = RMSNorm.apply(p["norm2"], h)
    if ffn == "dense":
        from repro.models.moe import _mlp_apply
        z = _mlp_apply(p["ffn"], v, cfg.mlp_kind)
    elif ffn == "moe":
        z, moe_aux = MoE.apply(p["ffn"], v, cfg, capacity_factor=moe_capacity)
        aux = aux + moe_aux
    elif ffn == "rwkv_cmix":
        z = RWKV6ChannelMix.full(p["ffn"], v, cfg)
    h = h + z
    h = constrain(h, ("batch", "seq", "embed"))
    return h, aux


def _layer_cache_init(spec: LayerSpec, cfg: ModelConfig, batch, max_len,
                      dtype):
    mixer, ffn = spec
    c = {}
    if mixer in ("attn", "local"):
        c["mixer"] = GQAttention.init_cache(cfg, batch, max_len, dtype)
    elif mixer == "mla":
        c["mixer"] = MLAttention.init_cache(cfg, batch, max_len, dtype)
    elif mixer == "mamba":
        c["mixer"] = Mamba.init_state(cfg, batch, dtype)
    elif mixer == "rwkv":
        c["mixer"] = RWKV6TimeMix.init_state(cfg, batch, dtype)
    if ffn == "rwkv_cmix":
        c["ffn"] = RWKV6ChannelMix.init_state(cfg, batch, dtype)
    return c


class PagedView(NamedTuple):
    """Block-table addressing for a paged decode step: attention cache leaves
    are the shared physical pools and each of the R view rows reads/writes
    through ``tables``; ``rows`` selects the batch slots whose (un-paged)
    recurrent states ride along. ``use_kernel`` picks the Pallas paged
    flash-decode kernel over the gather-view CPU-exact fallback."""
    tables: Any                        # (R, nb) physical block ids
    rows: Any                          # (R,) batch slots
    use_kernel: bool = False
    interpret: Optional[bool] = None


def _layer_window(p, spec: LayerSpec, cfg: ModelConfig, h, cache, cache_len,
                  state_mode: str = "per_position", accept=None,
                  paged: Optional[PagedView] = None):
    """Returns (h, new_cache).

    state_mode:
      * "per_position" — recurrent mixers return states at every window
        position (extra W axis); engine selects via ``select_states``.
      * "none"    — logits-only pass: recurrent caches pass through
        unchanged (per-position stacks are DCE'd). First pass of the
        two-pass low-memory decode (§Perf C4).
      * "advance" — recurrent mixers return ONLY the state after ``accept``
        (B,) tokens (freeze-masked scan; second pass of C4).

    With ``paged``, attention/local/mla cache entries are physical block
    pools addressed through ``paged.tables`` (recurrent mixers are identical
    in both modes — their per-slot states are never paged).
    """
    mixer, ffn = spec
    new_cache = {}
    u = RMSNorm.apply(p["norm1"], h)
    if mixer in ("attn", "local"):
        window = cfg.sliding_window if mixer == "local" else 0
        if paged is not None:
            y, new_cache["mixer"] = GQAttention.window_paged(
                p["mixer"], u, cfg, cache["mixer"], paged.tables, cache_len,
                window=window, use_kernel=paged.use_kernel,
                interpret=paged.interpret)
        else:
            y, new_cache["mixer"] = GQAttention.window(
                p["mixer"], u, cfg, cache["mixer"], cache_len, window=window)
    elif mixer == "mla":
        if paged is not None:
            y, new_cache["mixer"] = MLAttention.window_paged(
                p["mixer"], u, cfg, cache["mixer"], paged.tables, cache_len,
                use_kernel=paged.use_kernel, interpret=paged.interpret)
        else:
            y, new_cache["mixer"] = MLAttention.window(
                p["mixer"], u, cfg, cache["mixer"], cache_len)
    elif mixer == "mamba":
        y, st = Mamba.window(p["mixer"], u, cfg, cache["mixer"])
        if state_mode == "per_position":
            new_cache["mixer"] = st
        elif state_mode == "none":
            new_cache["mixer"] = cache["mixer"]
        else:
            new_cache["mixer"] = Mamba.advance_state(
                p["mixer"], u, cfg, cache["mixer"], accept)
    elif mixer == "rwkv":
        y, st = RWKV6TimeMix.window(p["mixer"], u, cfg, cache["mixer"])
        if state_mode == "per_position":
            new_cache["mixer"] = st
        elif state_mode == "none":
            new_cache["mixer"] = cache["mixer"]
        else:
            new_cache["mixer"] = RWKV6TimeMix.advance_state(
                p["mixer"], u, cfg, cache["mixer"], accept)
    h = h + y

    v = RMSNorm.apply(p["norm2"], h)
    if ffn == "dense":
        from repro.models.moe import _mlp_apply
        z = _mlp_apply(p["ffn"], v, cfg.mlp_kind)
    elif ffn == "moe":
        z, _ = MoE.apply(p["ffn"], v, cfg, capacity_factor=None)
    elif ffn == "rwkv_cmix":
        y2, st2 = RWKV6ChannelMix.window(p["ffn"], v, cfg, cache["ffn"])
        z = y2
        if state_mode == "per_position":
            new_cache["ffn"] = st2
        elif state_mode == "none":
            new_cache["ffn"] = cache["ffn"]
        else:
            new_cache["ffn"] = RWKV6ChannelMix.advance_state(
                p["ffn"], v, cfg, cache["ffn"], accept)
    h = h + z
    return h, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

class TransformerLM:
    @staticmethod
    def init(key, cfg: ModelConfig):
        dtype = cfg.param_dtype
        k_emb, k_pre, k_blk, k_suf, k_head, k_fc = jax.random.split(key, 6)
        params = {"embed": Embedding.init(k_emb, cfg.vocab, cfg.d_model,
                                          dtype=dtype)}
        params["prefix"] = [
            _layer_init(k, spec, cfg, dtype)
            for k, spec in zip(jax.random.split(k_pre,
                                                max(1, len(cfg.layer_prefix))),
                               cfg.layer_prefix)]
        if cfg.n_blocks:
            def init_block(k):
                ks = jax.random.split(k, len(cfg.layer_block))
                return [_layer_init(kk, spec, cfg, dtype)
                        for kk, spec in zip(ks, cfg.layer_block)]
            blocks = [init_block(k)
                      for k in jax.random.split(k_blk, cfg.n_blocks)]
            params["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *blocks)
        params["suffix"] = [
            _layer_init(k, spec, cfg, dtype)
            for k, spec in zip(jax.random.split(k_suf,
                                                max(1, len(cfg.layer_suffix))),
                               cfg.layer_suffix)]
        params["final_norm"] = RMSNorm.init(cfg.d_model, dtype=dtype)
        if not cfg.tie_embeddings:
            params["head"] = Dense.init(k_head, cfg.d_model, cfg.vocab,
                                        use_bias=False, dtype=dtype)
        if cfg.forecast_horizon:
            from repro.core.forecasting import TokenForecast, TokenForecastConfig
            params["forecast"] = TokenForecast.init(
                k_fc, TokenForecastConfig(cfg.d_model, cfg.vocab,
                                          cfg.forecast_horizon,
                                          cfg.forecast_hidden), dtype=dtype)
        return params

    # -- shared embedding / head -------------------------------------------
    @staticmethod
    def _embed(params, cfg, tokens, prefix_embeddings):
        h = Embedding.apply(params["embed"], tokens)
        if cfg.embed_scale:
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        if prefix_embeddings is not None:
            h = jnp.concatenate(
                [prefix_embeddings.astype(h.dtype), h], axis=1)
        return constrain(h, ("batch", "seq", "embed"))

    @staticmethod
    def _head(params, cfg, h):
        if cfg.tie_embeddings:
            logits = Embedding.attend(params["embed"], h)
        else:
            logits = Dense.apply(params["head"], h)
        return constrain(logits, ("batch", "seq", "vocab"))

    # -- full-sequence forward ----------------------------------------------
    @staticmethod
    def apply(params, cfg: ModelConfig, tokens, prefix_embeddings=None,
              moe_capacity=None, remat: bool = False):
        """tokens: (B, S) int. Returns (logits (B, S_tot, V), h, aux).

        ``moe_capacity=None`` = no-drop MoE (exact ARM semantics; inference
        default). Training passes a finite capacity factor. ``remat=True``
        checkpoints each block (activation memory ~ one layer boundary)."""
        h = TransformerLM._embed(params, cfg, tokens, prefix_embeddings)
        aux = jnp.zeros((), jnp.float32)

        def run_block(carry, block_p, specs):
            h, aux = carry
            for p, spec in zip(block_p, specs):
                h, aux = _layer_full(p, spec, cfg, h, aux, moe_capacity)
            return h, aux

        if remat:
            run_block = jax.checkpoint(run_block, static_argnums=(2,))

        h, aux = run_block((h, aux), params["prefix"], cfg.layer_prefix)

        if cfg.n_blocks:
            def body(carry, block_p):
                return run_block(carry, block_p, cfg.layer_block), None

            (h, aux), _ = jax.lax.scan(body, (h, aux), params["blocks"])

        h, aux = run_block((h, aux), params["suffix"], cfg.layer_suffix)

        h = RMSNorm.apply(params["final_norm"], h)
        logits = TransformerLM._head(params, cfg, h)
        return logits, h, aux

    # -- caches ---------------------------------------------------------------
    @staticmethod
    def init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None):
        dtype = dtype or cfg.param_dtype
        cache = {
            "prefix": [_layer_cache_init(s, cfg, batch, max_len, dtype)
                       for s in cfg.layer_prefix],
            "suffix": [_layer_cache_init(s, cfg, batch, max_len, dtype)
                       for s in cfg.layer_suffix],
        }
        if cfg.n_blocks:
            one = [_layer_cache_init(s, cfg, batch, max_len, dtype)
                   for s in cfg.layer_block]
            cache["blocks"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape), one)
        return cache

    # -- verify-window decode -------------------------------------------------
    @staticmethod
    def decode_window(params, cfg: ModelConfig, tokens, cache, cache_len,
                      state_mode: str = "per_position", accept=None,
                      paged: Optional[PagedView] = None):
        """tokens: (B, W) candidates; cache_len: (B,). Returns
        (logits (B, W, V), h, new_cache). See ``_layer_window`` for
        ``state_mode`` (per-position states vs the two-pass C4 modes).
        ``paged`` switches attention leaves to block-pool addressing — use
        ``decode_window_paged`` which also routes the recurrent rows."""
        h = TransformerLM._embed(params, cfg, tokens, None)
        new_cache = {"prefix": [], "suffix": []}

        for p, spec, c in zip(params["prefix"], cfg.layer_prefix,
                              cache["prefix"]):
            h, nc = _layer_window(p, spec, cfg, h, c, cache_len,
                                  state_mode, accept, paged)
            new_cache["prefix"].append(nc)

        if cfg.n_blocks:
            def body(h, xs):
                block_p, block_c = xs
                ncs = []
                for i, spec in enumerate(cfg.layer_block):
                    h, nc = _layer_window(block_p[i], spec, cfg, h,
                                          block_c[i], cache_len,
                                          state_mode, accept, paged)
                    ncs.append(nc)
                return h, ncs

            h, blocks_nc = jax.lax.scan(body, h,
                                        (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = blocks_nc

        for p, spec, c in zip(params["suffix"], cfg.layer_suffix,
                              cache["suffix"]):
            h, nc = _layer_window(p, spec, cfg, h, c, cache_len,
                                  state_mode, accept, paged)
            new_cache["suffix"].append(nc)

        h = RMSNorm.apply(params["final_norm"], h)
        logits = TransformerLM._head(params, cfg, h)
        return logits, h, new_cache

    @staticmethod
    def decode_window_paged(params, cfg: ModelConfig, tokens, paged_cache,
                            view: PagedView, cache_len,
                            state_mode: str = "per_position", accept=None):
        """Verify-window decode straight over the physical block pools — the
        paged-attention hot path. No dense attention K/V view is built and
        no standalone window scatter runs before the kernel: attention
        leaves stay (P, bs, ...) and each layer's single fused pallas_call
        attends through ``view.tables`` while committing its window K/V into
        the physical blocks as an aliased epilogue (gather-view fallback
        with the aliased ``paged_window_write`` per ``view.use_kernel``).
        Recurrent state leaves (un-paged, (B, ...) slot-indexed) are routed
        to the ``view.rows`` being decoded. Returns (logits, h, new_cache)
        where new_cache holds the updated pools for attention leaves and
        per-position states for recurrent leaves — feed it through
        ``select_states`` then ``adopt_states_paged``."""
        cache = TransformerLM._map_paged(
            cfg, (paged_cache,),
            lambda stacked, leaf: leaf,
            lambda stacked, leaf: (leaf[:, view.rows] if stacked
                                   else leaf[view.rows]))
        return TransformerLM.decode_window(params, cfg, tokens, cache,
                                           cache_len, state_mode, accept,
                                           paged=view)

    @staticmethod
    def adopt_states_paged(cfg: ModelConfig, paged_cache, sel, rows):
        """Merge a paged decode's outputs back into the pool pytree:
        attention pool leaves were already updated functionally by the
        per-layer window writes (take them from ``sel``); recurrent leaves
        adopt the selected per-row states at ``rows``."""
        def rec(stacked, pleaf, sleaf):
            if stacked:
                return pleaf.at[:, rows].set(sleaf)
            return pleaf.at[rows].set(sleaf)

        return TransformerLM._map_paged(
            cfg, (paged_cache, sel),
            lambda stacked, pleaf, sleaf: sleaf, rec)

    # -- paged (block-table) cache access ------------------------------------
    #
    # The serving runtime stores attention K/V (and MLA latents) in fixed-size
    # blocks of a shared physical pool instead of dense per-slot buffers:
    # leaf (B, S, ...) becomes (P, block_size, ...) plus a per-sequence block
    # table (B, S / block_size) of physical ids. Physical block 0 is reserved
    # as a write sink for masked scatter lanes and unallocated table entries —
    # its contents are garbage by design and are never read unmasked
    # (DESIGN.md §6). Recurrent mixer states (Mamba/RWKV) are tiny per-slot
    # snapshots, not paged; they stay batch-indexed.

    @staticmethod
    def _map_paged(cfg: ModelConfig, caches, fn_attn, fn_rec):
        """Walk one or more cache-shaped pytrees in lockstep, applying
        ``fn_attn(stacked, *leaves)`` to attention cache leaves and
        ``fn_rec(stacked, *leaves)`` to recurrent state leaves."""
        def per_layer(spec, entries, stacked):
            mixer, ffn = spec
            out = {}
            if mixer in ("attn", "local", "mla"):
                out["mixer"] = jax.tree.map(
                    lambda *ls: fn_attn(stacked, *ls),
                    *[e["mixer"] for e in entries])
            elif mixer in ("mamba", "rwkv"):
                out["mixer"] = jax.tree.map(
                    lambda *ls: fn_rec(stacked, *ls),
                    *[e["mixer"] for e in entries])
            if ffn == "rwkv_cmix":
                out["ffn"] = jax.tree.map(
                    lambda *ls: fn_rec(stacked, *ls),
                    *[e["ffn"] for e in entries])
            return out

        res = {"prefix": [per_layer(s, [c["prefix"][i] for c in caches],
                                    False)
                          for i, s in enumerate(cfg.layer_prefix)],
               "suffix": [per_layer(s, [c["suffix"][i] for c in caches],
                                    False)
                          for i, s in enumerate(cfg.layer_suffix)]}
        if cfg.n_blocks:
            res["blocks"] = [per_layer(s, [c["blocks"][i] for c in caches],
                                       True)
                             for i, s in enumerate(cfg.layer_block)]
        return res

    @staticmethod
    def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                         block_size: int, dtype=None):
        """Physical block pool: attention leaves (num_blocks, block_size, ...)
        (scanned segments keep their leading layer axis); recurrent state
        leaves stay (batch, ...) slot-indexed."""
        dtype = dtype or cfg.param_dtype
        tmpl = TransformerLM.init_cache(cfg, batch, block_size, dtype)

        def attn(stacked, leaf):
            if stacked:
                return jnp.zeros((leaf.shape[0], num_blocks)
                                 + leaf.shape[2:], leaf.dtype)
            return jnp.zeros((num_blocks,) + leaf.shape[1:], leaf.dtype)

        return TransformerLM._map_paged(cfg, (tmpl,), attn,
                                        lambda stacked, leaf: leaf)

    @staticmethod
    def paged_partition_specs(cfg: ModelConfig, paged, data_axis="data"):
        """PartitionSpec pytree for a mesh-sharded paged cache: every leaf's
        pool dim (attention: physical blocks) or slot dim (recurrent states:
        batch) is sharded over ``data_axis``; scanned segments keep their
        leading layer axis unsharded. These are the shard_map in/out specs
        of the mesh serving round (DESIGN.md §10) — each data shard owns a
        contiguous sub-pool and its tables hold shard-local block ids, so
        paged indirection never crosses shards."""
        from jax.sharding import PartitionSpec as P

        def spec(stacked, leaf):
            return P(None, data_axis) if stacked else P(data_axis)

        return TransformerLM._map_paged(cfg, (paged,), spec, spec)

    @staticmethod
    def gather_paged(cfg: ModelConfig, paged, tables, rows):
        """Materialize a dense cache view for ``decode_window``.

        tables: (R, nb) physical block ids per view row; rows: (R,) batch
        slots (selects recurrent states). View sequence length is
        ``nb * block_size``; table entries past a sequence's allocation point
        at block 0 — those positions are causally masked, so its garbage
        contents never reach an unmasked lane."""
        def attn(stacked, leaf):
            if stacked:
                g = leaf[:, tables]                    # (L, R, nb, bs, ...)
                return g.reshape((g.shape[0], g.shape[1],
                                  g.shape[2] * g.shape[3]) + g.shape[4:])
            g = leaf[tables]                           # (R, nb, bs, ...)
            return g.reshape((g.shape[0], g.shape[1] * g.shape[2])
                             + g.shape[3:])

        def rec(stacked, leaf):
            return leaf[:, rows] if stacked else leaf[rows]

        return TransformerLM._map_paged(cfg, (paged,), attn, rec)

    @staticmethod
    def scatter_paged(cfg: ModelConfig, paged, dense_new, tables, rows,
                      start, width: int, active):
        """Write a dense view's ``[start, start + width)`` positions back into
        the physical pool through the same aliased ``paged_window_write``
        kernel the fused round uses, so donation semantics are uniform: only
        blocks intersecting the written span are touched, the commit happens
        in place on the donated pool (no full-pool scatter temp), and lanes
        of inactive rows (and slots past the span) are routed to the
        reserved sink block 0. Recurrent state leaves are adopted
        unconditionally for every view row (mirrors the dense engine, where
        an inactive row's re-run reproduces its snapshot bit-for-bit)."""
        from repro.kernels.paged_attention.ops import paged_window_write

        act = active.astype(jnp.int32)

        def span(dleaf):
            # dense view values at [start, start + width): (R, width, ...)
            S = dleaf.shape[1]
            idx = jnp.clip(start[:, None] + jnp.arange(width)[None, :],
                           0, S - 1)
            idx = idx.reshape(idx.shape + (1,) * (dleaf.ndim - 2))
            return jnp.take_along_axis(dleaf, idx, axis=1)

        def attn(stacked, pleaf, dleaf):
            if stacked:
                def body(_, pd):
                    p_l, d_l = pd
                    return None, paged_window_write(p_l, span(d_l), tables,
                                                    start, act)
                _, out = jax.lax.scan(body, None, (pleaf, dleaf))
                return out
            return paged_window_write(pleaf, span(dleaf), tables, start, act)

        def rec(stacked, pleaf, dleaf):
            if stacked:
                return pleaf.at[:, rows].set(dleaf)
            return pleaf.at[rows].set(dleaf)

        return TransformerLM._map_paged(cfg, (paged, dense_new), attn, rec)

    @staticmethod
    def select_states(cfg: ModelConfig, new_cache, accept_idx):
        """Adopt the verify outputs: attention buffers are taken as-is (the
        rewound ``cache_len`` shields stale slots); recurrent per-position
        states are gathered at ``accept_idx - 1`` (B,) — the state after the
        last accepted token."""
        B = accept_idx.shape[0]
        gather = jnp.maximum(accept_idx - 1, 0)

        def per_layer(spec, new, stacked: bool):
            mixer, ffn = spec

            def pick(n):
                # n: (B, W, ...) or, for scanned blocks, (n_blocks, B, W, ...)
                if stacked:
                    return n[:, jnp.arange(B), gather]
                return n[jnp.arange(B), gather]

            out = {}
            if mixer in ("attn", "local", "mla"):
                out["mixer"] = new["mixer"]
            elif mixer in ("mamba", "rwkv"):
                out["mixer"] = jax.tree.map(pick, new["mixer"])
            if ffn == "rwkv_cmix":
                out["ffn"] = jax.tree.map(pick, new["ffn"])
            return out

        sel = {"prefix": [per_layer(s, n, False) for s, n in
                          zip(cfg.layer_prefix, new_cache["prefix"])],
               "suffix": [per_layer(s, n, False) for s, n in
                          zip(cfg.layer_suffix, new_cache["suffix"])]}
        if cfg.n_blocks:
            sel["blocks"] = [per_layer(s, new_cache["blocks"][i], True)
                             for i, s in enumerate(cfg.layer_block)]
        return sel
