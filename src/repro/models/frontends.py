"""Stub modality frontends (the one sanctioned carve-out — see DESIGN.md).

For [audio] (MusicGen over EnCodec tokens) and [vlm] (InternVL2) the assigned
architectures specify the TRANSFORMER BACKBONE only; ``prefix_embeddings``
stand in for the frozen conv-codec / ViT encoder outputs. These helpers
produce shape-correct embeddings (ShapeDtypeStructs for the dry-run, random
values for smoke tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def prefix_spec(cfg, batch: int):
    """ShapeDtypeStruct for the frontend embedding prefix, or None."""
    if cfg.n_prefix_tokens == 0:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.n_prefix_tokens, cfg.d_model),
                                cfg.param_dtype)


def random_prefix(key, cfg, batch: int):
    if cfg.n_prefix_tokens == 0:
        return None
    return 0.02 * jax.random.normal(
        key, (batch, cfg.n_prefix_tokens, cfg.d_model), cfg.param_dtype)
