"""Mixture-of-Experts FFN with top-k routing.

Baseline formulation: sort-based dispatch into per-expert capacity buffers
(E, C, D) -> batched expert matmuls -> weighted scatter-combine. Under GSPMD
the expert dimension is sharded over the "model" mesh axis (expert
parallelism); the §Perf hillclimb replaces the implicit resharding with an
explicit shard_map all-to-all (see sharding/moe_a2a.py).

Covers: DeepSeek-V3 (1 shared + 256 routed, top-8, sigmoid scoring +
normalized weights), DBRX (16 routed, top-4, softmax), Jamba (16, top-2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.core import Dense


def _mlp_init(key, d_model, d_ff, kind, dtype):
    ks = jax.random.split(key, 3)
    p = {"up": Dense.init(ks[0], d_model, d_ff, use_bias=False, dtype=dtype),
         "down": Dense.init(ks[1], d_ff, d_model, use_bias=False, dtype=dtype)}
    if kind in ("swiglu", "geglu"):
        p["gate"] = Dense.init(ks[2], d_model, d_ff, use_bias=False,
                               dtype=dtype)
    return p


def _mlp_apply(p, x, kind):
    u = Dense.apply(p["up"], x)
    if kind == "swiglu":
        u = u * jax.nn.silu(Dense.apply(p["gate"], x))
    elif kind == "geglu":
        u = u * jax.nn.gelu(Dense.apply(p["gate"], x))
    else:
        u = jax.nn.gelu(u)
    return Dense.apply(p["down"], u)


class MoE:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        E = cfg.n_experts
        D, F = cfg.d_model, cfg.moe_d_ff or cfg.d_ff
        k_r, k_e, k_s = jax.random.split(key, 3)
        ks = jax.random.split(k_e, 3)
        glu = cfg.mlp_kind in ("swiglu", "geglu")
        experts = {
            "up": 0.02 * jax.random.normal(ks[0], (E, D, F), dtype=dtype),
            "down": 0.02 * jax.random.normal(ks[1], (E, F, D), dtype=dtype),
        }
        if glu:
            experts["gate"] = 0.02 * jax.random.normal(ks[2], (E, D, F),
                                                       dtype=dtype)
        p = {
            "router": Dense.init(k_r, D, E, use_bias=False, dtype=dtype),
            "experts": experts,
        }
        if cfg.n_shared_experts:
            p["shared"] = _mlp_init(k_s, D,
                                    (cfg.moe_d_ff or cfg.d_ff)
                                    * cfg.n_shared_experts,
                                    cfg.mlp_kind, dtype)
        return p

    @staticmethod
    def route(p, x_flat, cfg):
        """x_flat: (N, D). Returns (expert_ids (N,k), weights (N,k), probs)."""
        logits = Dense.apply(p["router"], x_flat).astype(jnp.float32)  # (N, E)
        if cfg.router_score == "sigmoid":          # DeepSeek-V3
            scores = jax.nn.sigmoid(logits)
        else:
            scores = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(scores, cfg.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
        return ids.astype(jnp.int32), w, jax.nn.softmax(logits, axis=-1)

    @staticmethod
    def load_balance_loss(probs, ids, cfg):
        """Switch-style aux loss: E * sum_e f_e * p_e."""
        E = cfg.n_experts
        onehot = jax.nn.one_hot(ids, E)                  # (N, k, E)
        f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)    # fraction routed
        pbar = jnp.mean(probs, axis=0)                   # mean router prob
        return E * jnp.sum(f * pbar) / cfg.top_k

    @staticmethod
    def apply(p, x, cfg, capacity_factor: float | None = 1.25):
        """x: (B, T, D) -> (y (B, T, D), aux_loss scalar).

        ``capacity_factor=None`` => no-drop (C = N*k). REQUIRED for decode /
        predictive-sampling verify: token dropping makes a token's output
        depend on *other* tokens (capacity competition), which would break
        both causality and the exactness guarantee. Training may drop
        (standard efficiency trade).

        Under an active mesh (sharding rules context) this dispatches to the
        expert-parallel shard_map path (sharding/moe_shard.py)."""
        from repro.sharding.api import current_rules
        ctx = current_rules()
        if ctx is not None:
            mesh, rules = ctx
            if ("model" in mesh.axis_names
                    and cfg.n_experts % mesh.shape["model"] == 0):
                from repro.sharding.moe_shard import moe_apply_sharded
                ep_only = bool(rules.mapping.get("_moe_ep", False))
                return moe_apply_sharded(p, x, cfg, mesh, capacity_factor,
                                         ep_only=ep_only)
        B, T, D = x.shape
        E, k = cfg.n_experts, cfg.top_k
        N = B * T
        xf = x.reshape(N, D)
        ids, w, probs = MoE.route(p, xf, cfg)
        aux = MoE.load_balance_loss(probs, ids, cfg)

        if capacity_factor is None:
            C = N * k                      # no token can ever be dropped
        else:
            C = max(1, int(N * k * capacity_factor) // E)
        ids_flat = ids.reshape(N * k)
        w_flat = w.reshape(N * k)
        tok_flat = jnp.repeat(jnp.arange(N), k)

        order = jnp.argsort(ids_flat)
        ids_s = ids_flat[order]
        tok_s = tok_flat[order]
        w_s = w_flat[order]
        # position within each expert segment (sorted -> first-occurrence diff)
        first = jnp.searchsorted(ids_s, ids_s, side="left")
        pos = jnp.arange(N * k) - first
        keep = pos < C
        pos_c = jnp.where(keep, pos, C)  # C -> dropped via mode='drop'

        # dispatch: (E, C, D)
        buf = jnp.zeros((E, C, D), x.dtype)
        buf = buf.at[ids_s, pos_c].set(xf[tok_s], mode="drop")

        # expert MLPs, batched over E
        up = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["up"])
        if "gate" in p["experts"]:
            gate = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["gate"])
            act = (jax.nn.silu(gate) if cfg.mlp_kind == "swiglu"
                   else jax.nn.gelu(gate))
            hidden = up * act
        else:
            hidden = jax.nn.gelu(up)
        out = jnp.einsum("ecf,efd->ecd", hidden, p["experts"]["down"])

        # combine: weighted scatter-add back to tokens
        gathered = out.at[ids_s, pos_c].get(mode="fill", fill_value=0.0)
        contrib = gathered * jnp.where(keep, w_s, 0.0)[:, None]
        y = jnp.zeros((N, D), x.dtype).at[tok_s].add(contrib)

        if "shared" in p:
            y = y + _mlp_apply(p["shared"], xf, cfg.mlp_kind)
        return y.reshape(B, T, D), aux
