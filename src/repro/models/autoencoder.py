"""Discrete-latent autoencoder (paper §4.2, Appendix A.3).

Encoder: two 3x3 convs (half width) -> strided 4x4 s2 (half) -> strided 4x4
s2 (full) -> two residual blocks -> 1x1 to ``C_lat * K`` logits.
Quantization: argmax-of-softmax, one-hot, straight-through gradient.
Decoder mirrors the encoder. Loss: MSE (rate term handled by the separately
trained latent ARM, two-phase training as in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn.core import Conv2D


@dataclass(frozen=True)
class AutoencoderConfig:
    height: int = 32
    width: int = 32
    channels: int = 3          # image channels
    width_filters: int = 512   # "width" parameter (paper: 512)
    latent_channels: int = 4   # C_lat (paper: 4)
    latent_categories: int = 128  # K (paper: 128)

    @property
    def latent_hw(self) -> tuple[int, int]:
        return self.height // 4, self.width // 4


def _resblock_init(key, ch, dtype):
    k1, k2 = jax.random.split(key)
    return {"conv1": Conv2D.init(k1, ch, ch, (3, 3), dtype=dtype),
            "conv2": Conv2D.init(k2, ch, ch, (3, 3), dtype=dtype)}


def _resblock_apply(params, x):
    u = jax.nn.relu(Conv2D.apply(params["conv1"], jax.nn.relu(x)))
    u = Conv2D.apply(params["conv2"], u)
    return x + u


class DiscreteAutoencoder:
    @staticmethod
    def init(key, cfg: AutoencoderConfig, dtype=jnp.float32):
        W, hw = cfg.width_filters, cfg.width_filters // 2
        CL, K = cfg.latent_channels, cfg.latent_categories
        ks = jax.random.split(key, 14)
        enc = {
            "c1": Conv2D.init(ks[0], cfg.channels, hw, (3, 3), dtype=dtype),
            "c2": Conv2D.init(ks[1], hw, hw, (3, 3), dtype=dtype),
            "s1": Conv2D.init(ks[2], hw, hw, (4, 4), dtype=dtype),
            "s2": Conv2D.init(ks[3], hw, W, (4, 4), dtype=dtype),
            "r1": _resblock_init(ks[4], W, dtype),
            "r2": _resblock_init(ks[5], W, dtype),
            "head": Conv2D.init(ks[6], W, CL * K, (1, 1), dtype=dtype),
        }
        dec = {
            "embed": Conv2D.init(ks[7], CL * K, W, (1, 1), dtype=dtype),
            "r1": _resblock_init(ks[8], W, dtype),
            "r2": _resblock_init(ks[9], W, dtype),
            "t1": Conv2D.init(ks[10], W, hw, (4, 4), dtype=dtype),
            "t2": Conv2D.init(ks[11], hw, hw, (4, 4), dtype=dtype),
            "c1": Conv2D.init(ks[12], hw, hw, (3, 3), dtype=dtype),
            "c2": Conv2D.init(ks[13], hw, cfg.channels, (3, 3), dtype=dtype),
        }
        return {"enc": enc, "dec": dec}

    # -- encoder -----------------------------------------------------------
    @staticmethod
    def encode_logits(params, x, cfg: AutoencoderConfig):
        """x: (B, H, W, C) float in [-1, 1] -> latent logits (B, h, w, CL, K)."""
        e = params["enc"]
        u = jax.nn.relu(Conv2D.apply(e["c1"], x))
        u = jax.nn.relu(Conv2D.apply(e["c2"], u))
        u = jax.nn.relu(Conv2D.apply(e["s1"], u, stride=(2, 2)))
        u = jax.nn.relu(Conv2D.apply(e["s2"], u, stride=(2, 2)))
        u = _resblock_apply(e["r1"], u)
        u = _resblock_apply(e["r2"], u)
        logits = Conv2D.apply(e["head"], u)
        B, h, w, _ = logits.shape
        return logits.reshape(B, h, w, cfg.latent_channels,
                              cfg.latent_categories)

    @staticmethod
    def quantize(logits):
        """Straight-through argmax-of-softmax: returns (z_int, z_onehot_st)."""
        z = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        hard = jax.nn.one_hot(z, logits.shape[-1], dtype=logits.dtype)
        soft = jax.nn.softmax(logits, axis=-1)
        st = soft + jax.lax.stop_gradient(hard - soft)
        return z, st

    # -- decoder -----------------------------------------------------------
    @staticmethod
    def decode(params, z_onehot, cfg: AutoencoderConfig):
        """z_onehot: (B, h, w, CL, K) -> reconstruction (B, H, W, C)."""
        d = params["dec"]
        B, h, w, CL, K = z_onehot.shape
        u = Conv2D.apply(d["embed"], z_onehot.reshape(B, h, w, CL * K))
        u = _resblock_apply(d["r1"], u)
        u = _resblock_apply(d["r2"], u)
        u = jax.nn.relu(Conv2D.apply(d["t1"], u, stride=(2, 2), transpose=True))
        u = jax.nn.relu(Conv2D.apply(d["t2"], u, stride=(2, 2), transpose=True))
        u = jax.nn.relu(Conv2D.apply(d["c1"], u))
        return jnp.tanh(Conv2D.apply(d["c2"], u))

    @staticmethod
    def reconstruct(params, x, cfg: AutoencoderConfig):
        logits = DiscreteAutoencoder.encode_logits(params, x, cfg)
        z, st = DiscreteAutoencoder.quantize(logits)
        xhat = DiscreteAutoencoder.decode(params, st, cfg)
        return xhat, z

    @staticmethod
    def mse_loss(params, x, cfg: AutoencoderConfig):
        xhat, _ = DiscreteAutoencoder.reconstruct(params, x, cfg)
        return jnp.mean(jnp.square(x - xhat))
