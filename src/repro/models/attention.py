"""Attention mixers: GQA/MQA (optional qk-norm, sliding window) and MLA.

Two execution modes, shared weights:

* ``full``   — whole-sequence causal attention (training / prefill).
* ``window`` — W query tokens against a KV cache with per-sequence lengths
  ``cache_len (B,)``; used by the predictive-sampling verify step (W = the
  forecast window; W=1 recovers vanilla decode). Writes the window's K/V into
  the cache at per-sequence offsets and returns the updated cache. On partial
  accepts the engine simply rewinds ``cache_len`` — stale slots are never
  read (mask is ``key_pos <= query_pos``) and get overwritten next verify.

MLA (DeepSeek-V3) caches the compressed latent ``c_kv`` (+ decoupled RoPE
key) instead of per-head K/V, and uses the absorbed-matrix formulation in
window mode so decode touches only ``r + rope_dim`` bytes per cached token.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_latent_attention,
                                               paged_window_write)
from repro.kernels.paged_attention.ref import gather_view
from repro.nn.core import Dense, RMSNorm
from repro.nn.rope import apply_rope

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def write_window(buf, new, cache_len):
    """Write W new entries into a cache at per-sequence offsets.

    buf: (B, S, ...); new: (B, W, ...); cache_len: (B,).
    Formulated as mask+gather+where (NOT dynamic_update_slice): under a
    sequence-sharded cache this is fully local — the per-sequence DUS
    variant forces GSPMD to all-gather the cache (§Perf C3).
    """
    B, S = buf.shape[:2]
    W = new.shape[1]
    off = jnp.arange(S)[None, :] - cache_len[:, None]        # (B, S)
    in_win = (off >= 0) & (off < W)
    idx = jnp.clip(off, 0, W - 1)
    idx = idx.reshape(idx.shape + (1,) * (buf.ndim - 2))
    vals = jnp.take_along_axis(new, idx, axis=1)             # (B, S, ...)
    mask = in_win.reshape(in_win.shape + (1,) * (buf.ndim - 2))
    return jnp.where(mask, vals, buf)


def _causal_mask(q_pos, k_pos, window: int = 0):
    """(..., Q, K) boolean mask: key visible iff k <= q (and within sliding
    window when ``window > 0``)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def _sdpa(q, k, v, mask, scale):
    """q: (B, Q, H, hd), k/v: (B, K, KV, hd) grouped; mask (B, Q, K) or (Q, K)."""
    B, Q, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Q, KV, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(B, Q, H, hd)


# Above this sequence length, full-sequence attention processes queries in
# chunks (transient (B, H, CHUNK, S) score tiles instead of (B, H, S, S)) —
# XLA-level flash-style tiling; the Pallas kernel is the TPU fast path.
CHUNKED_THRESHOLD = 2048
QUERY_CHUNK = 512


def _pick_chunk(T: int, target: int = QUERY_CHUNK) -> int:
    """Largest divisor of T that is <= target (handles prefix-extended
    sequence lengths like 4096 + 256 frontend tokens)."""
    for c in range(min(target, T), 0, -1):
        if T % c == 0:
            return c
    return T


def _sdpa_chunked(q, k, v, scale, window: int = 0):
    """Causal chunked attention over full sequences. q: (B, T, H, hd);
    k/v: (B, T, KV, hd). Scans query chunks; keys stay resident."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    cq = _pick_chunk(T)
    n_chunks = T // cq
    qc = q.reshape(B, n_chunks, cq, H, hd)
    k_pos = jnp.arange(T)

    # §Perf A3: checkpoint each chunk so the scan backward recomputes the
    # (B, H, cq, T) softmax weights instead of storing them per chunk
    @jax.checkpoint
    def one_chunk(i, q_i):
        q_pos = i * cq + jnp.arange(cq)
        mask = _causal_mask(q_pos, k_pos, window)    # (cq, T)
        return _sdpa(q_i, k, v, mask, scale)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n_chunks), jnp.moveaxis(qc, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, T, H, hd)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

class GQAttention:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ks = jax.random.split(key, 6)
        p = {
            "wq": Dense.init(ks[0], D, H * hd, use_bias=False, dtype=dtype),
            "wk": Dense.init(ks[1], D, KV * hd, use_bias=False, dtype=dtype),
            "wv": Dense.init(ks[2], D, KV * hd, use_bias=False, dtype=dtype),
            "wo": Dense.init(ks[3], H * hd, D, use_bias=False, dtype=dtype),
        }
        if cfg.qk_norm:
            p["q_norm"] = RMSNorm.init(hd, dtype=dtype)
            p["k_norm"] = RMSNorm.init(hd, dtype=dtype)
        return p

    @staticmethod
    def _qkv(p, x, cfg, positions):
        B, T, D = x.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = Dense.apply(p["wq"], x).reshape(B, T, H, hd)
        k = Dense.apply(p["wk"], x).reshape(B, T, KV, hd)
        v = Dense.apply(p["wv"], x).reshape(B, T, KV, hd)
        if "q_norm" in p:
            q = RMSNorm.apply(p["q_norm"], q)
            k = RMSNorm.apply(p["k_norm"], k)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    @staticmethod
    def full(p, x, cfg, window: int = 0):
        """x: (B, T, D) -> (B, T, D); causal (optionally sliding-window)."""
        B, T, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        q, k, v = GQAttention._qkv(p, x, cfg, pos)
        scale = 1.0 / math.sqrt(cfg.head_dim)
        if T > CHUNKED_THRESHOLD:
            out = _sdpa_chunked(q, k, v, scale, window)
        else:
            mask = _causal_mask(pos, pos, window)
            out = _sdpa(q, k, v, mask, scale)
        return Dense.apply(p["wo"], out.reshape(B, T, -1))

    @staticmethod
    def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32):
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, max_len, KV, hd), dtype),
                "v": jnp.zeros((batch, max_len, KV, hd), dtype)}

    @staticmethod
    def window(p, x, cfg, cache, cache_len, window: int = 0):
        """x: (B, W, D) verify-window queries; cache_len: (B,) valid lengths.

        Returns (y, new_cache). Key positions are absolute; sliding-window
        masking composes with the cache mask.
        """
        B, W, _ = x.shape
        S = cache["k"].shape[1]
        pos = cache_len[:, None] + jnp.arange(W)[None, :]  # (B, W)
        q, k_new, v_new = GQAttention._qkv(p, x, cfg, pos)

        k = write_window(cache["k"], k_new, cache_len)
        v = write_window(cache["v"], v_new, cache_len)
        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = _causal_mask(pos, k_pos, window)
        out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(cfg.head_dim))
        y = Dense.apply(p["wo"], out.reshape(B, W, -1))
        return y, {"k": k, "v": v}

    @staticmethod
    def window_paged(p, x, cfg, pool, tables, cache_len, window: int = 0,
                     use_kernel: bool = False,
                     interpret: Optional[bool] = None):
        """Paged counterpart of ``window``: the cache is the physical block
        pool ``{"k","v"}: (P, bs, KV, hd)`` plus per-sequence ``tables
        (B, nb)`` — no dense per-sequence view is gathered or scattered, and
        no standalone window scatter runs before the kernel: the fused
        Pallas kernel commits the W fresh K/V rows into their physical
        blocks as an aliased epilogue while the queries attend through the
        table (one dispatch). The CPU fallback commits through the same
        aliased ``paged_window_write`` kernel, then gathers the view and
        reuses ``_sdpa`` so it is bit-identical to the dense engine path."""
        B, W, _ = x.shape
        pos = cache_len[:, None] + jnp.arange(W)[None, :]  # (B, W)
        q, k_new, v_new = GQAttention._qkv(p, x, cfg, pos)

        if use_kernel:
            out, pk, pv = paged_attention(q, pool["k"], pool["v"], k_new,
                                          v_new, tables, cache_len,
                                          window=window, interpret=interpret)
        else:
            pk = paged_window_write(pool["k"], k_new, tables, cache_len,
                                    interpret=interpret)
            pv = paged_window_write(pool["v"], v_new, tables, cache_len,
                                    interpret=interpret)
            k, v = gather_view(pk, tables), gather_view(pv, tables)
            k_pos = jnp.broadcast_to(jnp.arange(k.shape[1]), (B, k.shape[1]))
            mask = _causal_mask(pos, k_pos, window)
            out = _sdpa(q, k, v, mask, 1.0 / math.sqrt(cfg.head_dim))
        y = Dense.apply(p["wo"], out.reshape(B, W, -1))
        return y, {"k": pk, "v": pv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3)
# ---------------------------------------------------------------------------

class MLAttention:
    @staticmethod
    def init(key, cfg, dtype=jnp.float32):
        D, H = cfg.d_model, cfg.n_heads
        r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        ks = jax.random.split(key, 8)
        return {
            "wq_a": Dense.init(ks[0], D, r_q, use_bias=False, dtype=dtype),
            "q_norm": RMSNorm.init(r_q, dtype=dtype),
            "wq_b": Dense.init(ks[1], r_q, H * (dn + dr), use_bias=False,
                               dtype=dtype),
            "wkv_a": Dense.init(ks[2], D, r_kv + dr, use_bias=False,
                                dtype=dtype),
            "kv_norm": RMSNorm.init(r_kv, dtype=dtype),
            "wk_b": Dense.init(ks[3], r_kv, H * dn, use_bias=False,
                               dtype=dtype),
            "wv_b": Dense.init(ks[4], r_kv, H * dv, use_bias=False,
                               dtype=dtype),
            "wo": Dense.init(ks[5], H * dv, D, use_bias=False, dtype=dtype),
        }

    @staticmethod
    def _q(p, x, cfg, positions):
        B, T, _ = x.shape
        H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
        q = Dense.apply(p["wq_b"], RMSNorm.apply(
            p["q_norm"], Dense.apply(p["wq_a"], x)))
        q = q.reshape(B, T, H, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        return q_nope, q_rope

    @staticmethod
    def _latent(p, x, cfg, positions):
        """Compressed KV latent + decoupled rope key (shared across heads)."""
        r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
        kv = Dense.apply(p["wkv_a"], x)
        c_kv = RMSNorm.apply(p["kv_norm"], kv[..., :r_kv])
        k_rope = kv[..., None, r_kv:]  # (B, T, 1, dr) single shared head
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
        return c_kv, k_rope[..., 0, :]

    @staticmethod
    def _attend_absorbed(p, q_nope, q_rope, c_kv, k_rope, mask, cfg):
        """Absorbed-matrix attention over the latent cache.

        q_nope: (B, Q, H, dn); c_kv: (B, S, r); k_rope: (B, S, dr).
        scores = q_nope^T W_uk c + q_rope . k_rope; out via W_uv on the
        attention-weighted latent (never materializes per-head K/V).
        """
        B, Q, H, dn = q_nope.shape
        r = c_kv.shape[-1]
        dv = cfg.v_head_dim
        wk_b = p["wk_b"]["w"].reshape(r, H, dn)
        wv_b = p["wv_b"]["w"].reshape(r, H, dv)
        scale = 1.0 / math.sqrt(dn + cfg.qk_rope_dim)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
        logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv)
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
        logits = logits.astype(jnp.float32) * scale
        if mask.ndim == 2:
            mask = mask[None]
        logits = jnp.where(mask[:, None], logits, NEG_INF)
        pattn = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", pattn, c_kv)
        out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv_b)
        return Dense.apply(p["wo"], out.reshape(B, Q, H * dv))

    @staticmethod
    def full(p, x, cfg, window: int = 0):
        B, T, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        q_nope, q_rope = MLAttention._q(p, x, cfg, pos)
        c_kv, k_rope = MLAttention._latent(p, x, cfg, pos)
        if T > CHUNKED_THRESHOLD:
            cq = _pick_chunk(T)
            n_chunks = T // cq
            k_pos = jnp.arange(T)

            @jax.checkpoint
            def one_chunk(i, qn_i, qr_i):
                q_pos = i * cq + jnp.arange(cq)
                mask = _causal_mask(q_pos, k_pos, window)
                return MLAttention._attend_absorbed(p, qn_i, qr_i, c_kv,
                                                    k_rope, mask, cfg)

            qn = jnp.moveaxis(q_nope.reshape(B, n_chunks, cq, *q_nope.shape[2:]), 1, 0)
            qr = jnp.moveaxis(q_rope.reshape(B, n_chunks, cq, *q_rope.shape[2:]), 1, 0)
            out = jax.lax.map(lambda a: one_chunk(*a),
                              (jnp.arange(n_chunks), qn, qr))
            return jnp.moveaxis(out, 0, 1).reshape(B, T, -1)
        mask = _causal_mask(pos, pos, window)
        return MLAttention._attend_absorbed(p, q_nope, q_rope, c_kv, k_rope,
                                            mask, cfg)

    @staticmethod
    def init_cache(cfg, batch: int, max_len: int, dtype=jnp.float32):
        return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}

    @staticmethod
    def window(p, x, cfg, cache, cache_len, window: int = 0):
        B, W, _ = x.shape
        S = cache["c_kv"].shape[1]
        pos = cache_len[:, None] + jnp.arange(W)[None, :]
        q_nope, q_rope = MLAttention._q(p, x, cfg, pos)
        c_new, kr_new = MLAttention._latent(p, x, cfg, pos)

        c_kv = write_window(cache["c_kv"], c_new, cache_len)
        k_rope = write_window(cache["k_rope"], kr_new, cache_len)
        k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = _causal_mask(pos, k_pos, window)
        y = MLAttention._attend_absorbed(p, q_nope, q_rope, c_kv, k_rope,
                                         mask, cfg)
        return y, {"c_kv": c_kv, "k_rope": k_rope}

    @staticmethod
    def window_paged(p, x, cfg, pool, tables, cache_len,
                     use_kernel: bool = False,
                     interpret: Optional[bool] = None):
        """Paged MLA decode: the latent cache ``{"c_kv": (P, bs, r),
        "k_rope": (P, bs, dr)}`` is written and read through the block
        tables. The kernel path absorbs W_uk into the query and streams the
        latent pool once (the merged c_kv tile is both key and value) while
        committing both latent pools as the fused aliased epilogue — no
        standalone scatter before the pallas_call; the CPU fallback commits
        through the same aliased ``paged_window_write`` kernel, then gathers
        the view and reuses ``_attend_absorbed`` bit-for-bit with the dense
        engine path."""
        B, W, _ = x.shape
        pos = cache_len[:, None] + jnp.arange(W)[None, :]
        q_nope, q_rope = MLAttention._q(p, x, cfg, pos)
        c_new, kr_new = MLAttention._latent(p, x, cfg, pos)

        if use_kernel:
            H, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
            r = pool["c_kv"].shape[-1]
            wk_b = p["wk_b"]["w"].reshape(r, H, dn)
            wv_b = p["wv_b"]["w"].reshape(r, H, dv)
            q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
            ctx, pc, pkr = paged_latent_attention(
                q_lat, q_rope, pool["c_kv"], pool["k_rope"], c_new, kr_new,
                tables, cache_len,
                scale=1.0 / math.sqrt(dn + cfg.qk_rope_dim),
                interpret=interpret)
            out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv_b)
            y = Dense.apply(p["wo"], out.reshape(B, W, -1))
        else:
            pc = paged_window_write(pool["c_kv"], c_new, tables, cache_len,
                                    interpret=interpret)
            pkr = paged_window_write(pool["k_rope"], kr_new, tables,
                                     cache_len, interpret=interpret)
            c_kv, k_rope = gather_view(pc, tables), gather_view(pkr, tables)
            S = c_kv.shape[1]
            k_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
            mask = _causal_mask(pos, k_pos)
            y = MLAttention._attend_absorbed(p, q_nope, q_rope, c_kv, k_rope,
                                             mask, cfg)
        return y, {"c_kv": pc, "k_rope": pkr}
