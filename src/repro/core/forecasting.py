"""Learned forecasting modules (paper §2.4, Appendix A.2).

Two instantiations:

* ``PixelForecast`` — the paper's module verbatim: one strictly-triangular
  3x3 masked convolution over the shared ARM representation ``h``, followed
  by a 1x1 convolution to ``T * C * K`` channels. Output at pixel ``p``
  forecasts all channels of pixels ``p .. p+T-1``, conditioned only on
  ``h`` from pixels strictly before ``p`` (hence on valid samples).

* ``TokenForecast`` — the token-LM adaptation (and the modern MTP
  correspondence, cf. DeepSeek-V3): per-offset heads on the decoder's
  penultimate states, shifted so the forecast for position ``s+t`` reads
  ``h[s-1]`` (valid prefix only).

Both are trained with the paper's objective (Eq. 9):
  ``KL[ stop_grad(P_ARM(x_{i+t} | x_{<i+t})) || P_F^(t)(x_{i+t} | x_{<i}) ]``
down-weighted by 0.01 so the ARM likelihood is unaffected; ``h`` is shared
and receives the (small) student-side gradient.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.core import Conv2D, Dense, MaskedConv2D


# ---------------------------------------------------------------------------
# Image-ARM forecasting module (paper Appendix A.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PixelForecastConfig:
    channels: int      # data channels C
    categories: int    # K
    horizon: int       # T, in pixels (paper: 20 MNIST, 1/5 otherwise)
    filters: int       # forecasting filters (paper: 60 MNIST, 162 default)
    in_filters: int    # width of the shared ARM representation h


class PixelForecast:
    @staticmethod
    def init(key, cfg: PixelForecastConfig, dtype=jnp.float32):
        k1, k2 = jax.random.split(key)
        C, K, T = cfg.channels, cfg.categories, cfg.horizon
        return {
            "tri_conv": MaskedConv2D.init(
                k1, cfg.in_filters, cfg.filters, (3, 3), mask_type="T",
                dtype=dtype),
            "out_conv": Conv2D.init(k2, cfg.filters, T * C * K, (1, 1),
                                    dtype=dtype),
        }

    @staticmethod
    def apply(params, h, cfg: PixelForecastConfig):
        """h: (B, H, W, F) -> forecast logits (B, H*W, T*C, K).

        Anchor = pixel (raster index); window = T*C flat positions starting at
        the anchor's own first channel.
        """
        C, K, T = cfg.channels, cfg.categories, cfg.horizon
        u = MaskedConv2D.apply(params["tri_conv"], h)
        u = jax.nn.elu(u)
        out = Conv2D.apply(params["out_conv"], u)  # (B, H, W, T*C*K)
        B, H, W, _ = out.shape
        return out.reshape(B, H * W, T * C, K)

    @staticmethod
    def module_fn(params, cfg: PixelForecastConfig):
        """Per-sample ``module_fn(h) -> (n_anchors, window, K)`` for
        ``predictive_sampling.make_learned_forecast`` (group = C)."""
        def fn(h):
            return PixelForecast.apply(params, h[None], cfg)[0]
        return fn

    @staticmethod
    def kl_loss(fc_logits, arm_logits, cfg: PixelForecastConfig):
        """Paper Eq. 9. fc_logits: (B, P, T*C, K) (P = H*W anchors);
        arm_logits: (B, P, C, K) ARM outputs (will be stop-gradient'd).
        Target for anchor p / offset (t, c) is the ARM distribution at pixel
        p+t, channel c."""
        C, K, T = cfg.channels, cfg.categories, cfg.horizon
        B, P = arm_logits.shape[:2]
        tgt = jax.lax.stop_gradient(arm_logits)  # (B, P, C, K)
        # build shifted targets: tgt_shift[p, t] = tgt[p + t]
        idx = jnp.arange(P)[:, None] + jnp.arange(T)[None, :]  # (P, T)
        valid = idx < P
        idx = jnp.minimum(idx, P - 1)
        tgt_sh = tgt[:, idx]                       # (B, P, T, C, K)
        fc = fc_logits.reshape(B, P, T, C, K)
        logp_t = jax.nn.log_softmax(tgt_sh, axis=-1)
        logp_f = jax.nn.log_softmax(fc, axis=-1)
        kl = jnp.sum(jnp.exp(logp_t) * (logp_t - logp_f), axis=-1)  # (B,P,T,C)
        w = jnp.broadcast_to(valid[None, :, :, None], kl.shape).astype(kl.dtype)
        return jnp.sum(kl * w) / (jnp.sum(w) + 1e-9)


# ---------------------------------------------------------------------------
# Token-LM forecasting heads (TPU/LLM adaptation; MTP correspondence)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TokenForecastConfig:
    d_model: int
    vocab: int
    horizon: int           # T offsets
    hidden: int = 0        # 0 = linear heads; else bottleneck MLP width


class TokenForecast:
    @staticmethod
    def init(key, cfg: TokenForecastConfig, dtype=jnp.float32):
        keys = jax.random.split(key, 2 * cfg.horizon)
        heads = []
        for t in range(cfg.horizon):
            if cfg.hidden:
                heads.append({
                    "proj": Dense.init(keys[2 * t], cfg.d_model, cfg.hidden,
                                       dtype=dtype),
                    "out": Dense.init(keys[2 * t + 1], cfg.hidden, cfg.vocab,
                                      dtype=dtype),
                })
            else:
                heads.append({
                    "out": Dense.init(keys[2 * t + 1], cfg.d_model, cfg.vocab,
                                      dtype=dtype),
                })
        return {"heads": heads}

    @staticmethod
    def apply(params, h, cfg: TokenForecastConfig):
        """h: (B, S, D) decoder states (state at s encodes x_{<=s}).

        Returns logits (B, S, T, V): position s, offset t forecasts token
        x_{s+t} conditioned on h[s-1] (shifted -> valid prefix x_{<s})."""
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # h[s-1]
        outs = []
        for head in params["heads"]:
            u = h_prev
            if "proj" in head:
                u = jax.nn.gelu(Dense.apply(head["proj"], u))
            outs.append(Dense.apply(head["out"], u))
        return jnp.stack(outs, axis=2)

    @staticmethod
    def module_fn(params, cfg: TokenForecastConfig):
        """Per-sample module for ``make_learned_forecast`` (group = 1)."""
        def fn(h):
            return TokenForecast.apply(params, h[None], cfg)[0]
        return fn

    @staticmethod
    def kl_loss(fc_logits, arm_logits):
        """fc_logits (B, S, T, V); arm_logits (B, S, V) where arm_logits[s]
        is the ARM distribution over x_s given x_{<s} (stop-gradient'd).
        Target for (s, t) is arm_logits[s + t]."""
        B, S, T, V = fc_logits.shape
        tgt = jax.lax.stop_gradient(arm_logits)
        idx = jnp.arange(S)[:, None] + jnp.arange(T)[None, :]
        valid = idx < S
        idx = jnp.minimum(idx, S - 1)
        tgt_sh = tgt[:, idx]  # (B, S, T, V)
        logp_t = jax.nn.log_softmax(tgt_sh, axis=-1)
        logp_f = jax.nn.log_softmax(fc_logits, axis=-1)
        kl = jnp.sum(jnp.exp(logp_t) * (logp_t - logp_f), axis=-1)
        w = jnp.broadcast_to(valid[None], kl.shape).astype(kl.dtype)
        return jnp.sum(kl * w) / (jnp.sum(w) + 1e-9)
