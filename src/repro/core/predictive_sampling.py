"""Predictive sampling (paper Algorithms 1 & 2), batched, in pure JAX.

The ARM is abstracted as ``arm_fn(x) -> (logits, h)`` over *flattened* int
sequences ``x: (B, d)`` with strict triangular dependence: ``logits[:, p]``
(the distribution over x_p) may depend only on ``x[:, :p]``. ``h`` is the
shared penultimate representation (paper §2.2 "Shared Representation"),
forwarded to forecasting functions at zero extra cost.

Forecasters implement
    ``forecast_fn(x, h, prev_out, eps, i) -> (d,) int forecasts``
(per-sample; the driver vmaps them). Positions ``< i`` are ignored.

The driver ``predictive_sample`` is Algorithm 1 generalized; with
``fpi_forecast`` it is exactly ARM fixed-point iteration (Algorithm 2 with
early exit — see ``fixed_point_sample`` for the literal Alg-2 form and the
equivalence test in tests/core/test_predictive_sampling.py).

Exactness guarantee: with shared Gumbel noise ``eps``, every sampler here
returns *bit-identical* output to naive ancestral sampling — the paper's
central claim 3) "samples from the true model distribution".
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.reparam import reparam_argmax


class SampleStats(NamedTuple):
    """Bookkeeping from a sampling run.

    arm_calls:        scalar int — batch-level ARM forward passes (the paper's
                      headline metric; slowest sample dominates, Table 1 note).
    per_sample_calls: (B,) — ARM calls until each sample finished (what a
                      per-sequence scheduler would pay; engine/ uses this).
    converge_iter:    (B, d) — iteration at which each position became valid
                      (paper Figure 6).
    """
    arm_calls: jnp.ndarray
    per_sample_calls: jnp.ndarray
    converge_iter: jnp.ndarray


# ---------------------------------------------------------------------------
# Forecasting functions (paper §2.2, §2.3, §4.1 baselines)
# ---------------------------------------------------------------------------

def fpi_forecast(x, h, prev_out, eps, i):
    """ARM fixed-point iteration (§2.3): reuse previous ARM outputs."""
    return prev_out


def zeros_forecast(x, h, prev_out, eps, i):
    """Baseline 'Forecast zeros' (Table 1)."""
    return jnp.zeros_like(prev_out)


def predict_last_forecast(x, h, prev_out, eps, i):
    """Baseline 'Predict last' (Table 1): repeat x_{i-1} for all future."""
    last = jnp.where(i > 0, x[jnp.maximum(i - 1, 0)], 0)
    return jnp.full_like(prev_out, last)


def make_learned_forecast(module_fn, window: int, group: int = 1,
                          use_reparam_noise: bool = True,
                          takes_x: bool = False):
    """Learned forecasting (§2.4).

    ``module_fn(h) -> (n_anchors, window, K)`` logits, where anchor ``a``
    (conditioned only on h from strictly-before anchor ``a``, i.e. triangular)
    forecasts the ``window`` flat positions ``[a*group, a*group + window)``.
    For token LMs ``group == 1`` (anchor == position); for channel-AR image
    models ``group == C`` (anchor == pixel, window == T_pix * C).

    Positions past the window fall back to the ARM's own outputs ("forecasts
    for all remaining future timesteps are taken from the ARM output").
    Reparametrized with the *same* eps as the verifier (Eq. 10);
    ``use_reparam_noise=False`` is the Table-3 reparametrization ablation
    (plain argmax) and ``takes_x=True`` (module over x instead of the shared
    representation h) is the representation-sharing ablation.
    """
    def forecast(x, h, prev_out, eps, i):
        d = prev_out.shape[0]
        a = i // group
        fc_logits = module_fn(x) if takes_x else module_fn(h)
        logits_a = jax.lax.dynamic_index_in_dim(fc_logits, a, axis=0,
                                                keepdims=False)  # (window, K)
        pos = jnp.arange(d)
        off = jnp.clip(pos - a * group, 0, window - 1)
        noise = eps if use_reparam_noise else jnp.zeros_like(eps)
        cand = reparam_argmax(logits_a[off], noise)  # (d,)
        in_window = (pos >= i) & (pos < a * group + window)
        return jnp.where(in_window, cand, prev_out)

    return forecast


# ---------------------------------------------------------------------------
# Naive ancestral sampling (the baseline: d ARM calls)
# ---------------------------------------------------------------------------

def ancestral_sample(arm_fn: Callable, eps: jnp.ndarray) -> tuple[jnp.ndarray, SampleStats]:
    """Sequential reference sampler: ``x_p = argmax(mu_p(x_{<p}) + eps_p)``.

    eps: (B, d, K). Returns (x, stats) with arm_calls == d.
    """
    B, d, K = eps.shape

    def body(p, x):
        logits, _ = arm_fn(x)  # (B, d, K)
        xp = reparam_argmax(logits[:, p], eps[:, p])  # (B,)
        return x.at[:, p].set(xp)

    x0 = jnp.zeros((B, d), jnp.int32)
    x = jax.lax.fori_loop(0, d, body, x0)
    stats = SampleStats(
        arm_calls=jnp.asarray(d, jnp.int32),
        per_sample_calls=jnp.full((B,), d, jnp.int32),
        converge_iter=jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), (B, d)),
    )
    return x, stats


# ---------------------------------------------------------------------------
# Predictive sampling (Algorithm 1, generalized over forecasters)
# ---------------------------------------------------------------------------

def predictive_sample(arm_fn: Callable, forecast_fn: Callable,
                      eps: jnp.ndarray, max_iters: int | None = None
                      ) -> tuple[jnp.ndarray, SampleStats]:
    """Algorithm 1. eps: (B, d, K) Gumbel noise (the reparametrization).

    Each loop iteration costs ONE batched ARM call. Guaranteed to terminate in
    <= d iterations (strict triangular dependence: position i is always valid
    after the call, so i advances by >= 1).
    """
    B, d, K = eps.shape
    max_iters = d if max_iters is None else max_iters

    def build_input(x, h, prev_out, i):
        fc = jax.vmap(forecast_fn, in_axes=(0, 0, 0, 0, 0))(x, h, prev_out, eps, i)
        pos = jnp.arange(d)[None, :]
        return jnp.where(pos < i[:, None], x, fc)

    def cond(state):
        x, h, prev_out, i, n, per_calls, conv = state
        return jnp.any(i < d) & (n < max_iters)

    def body(state):
        x, h, prev_out, i, n, per_calls, conv = state
        xin = build_input(x, h, prev_out, i)
        logits, h_new = arm_fn(xin)               # ONE batched ARM call
        out = reparam_argmax(logits, eps)          # (B, d) deterministic g
        pos = jnp.arange(d)[None, :]

        # accept run: leading positions >= i where input forecast == output
        match = (xin == out) | (pos < i[:, None])  # prefix < i always matches
        # first mismatch index per row (d if none)
        first_bad = jnp.argmin(match, axis=1)
        first_bad = jnp.where(jnp.all(match, axis=1), d, first_bad)
        # output at the first mismatch is ALSO valid (conditioning was valid)
        new_i = jnp.minimum(jnp.maximum(first_bad + 1, i), d)
        new_i = jnp.where(i >= d, i, new_i)        # finished rows stay put

        x_new = jnp.where(pos < new_i[:, None], out, x)
        active = i < d
        n_new = n + 1
        per_calls_new = per_calls + active.astype(jnp.int32)
        newly = (pos >= i[:, None]) & (pos < new_i[:, None])
        conv_new = jnp.where(newly, n_new, conv)
        return (x_new, h_new, out, new_i, n_new, per_calls_new, conv_new)

    # initial forecast is the zero vector (paper §2.2)
    x0 = jnp.zeros((B, d), jnp.int32)
    # h must exist before the first forecast; paper: initial forecast is zeros,
    # so prev_out=0 and h=0 works for all forecasters at i=0. h may be any
    # pytree with a leading batch axis (e.g. PixelCNN's (B, H, W, F) maps).
    h_shape = jax.eval_shape(arm_fn, jax.ShapeDtypeStruct((B, d), jnp.int32))[1]
    h0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), h_shape)
    state = (x0, h0, jnp.zeros((B, d), jnp.int32), jnp.zeros((B,), jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((B,), jnp.int32),
             jnp.zeros((B, d), jnp.int32))
    x, h, prev_out, i, n, per_calls, conv = jax.lax.while_loop(cond, body, state)
    return x, SampleStats(n, per_calls, conv)


# ---------------------------------------------------------------------------
# ARM fixed-point iteration in its literal Algorithm-2 form
# ---------------------------------------------------------------------------

def fixed_point_sample(arm_fn: Callable, eps: jnp.ndarray,
                       max_iters: int | None = None
                       ) -> tuple[jnp.ndarray, SampleStats]:
    """Algorithm 2: iterate ``x <- g(x, eps)`` until a fixed point.

    Identical output to ``predictive_sample(..., fpi_forecast)``; call count
    differs by at most one (Alg 2 pays one extra pass to *observe* the fixed
    point, Alg 1 exits once the valid prefix covers d).
    """
    B, d, K = eps.shape
    max_iters = (d + 1) if max_iters is None else max_iters

    def g(x):
        logits, _ = arm_fn(x)
        return reparam_argmax(logits, eps)

    def cond(state):
        x, x_prev, n, conv, changed = state
        return changed & (n < max_iters)

    def body(state):
        x, x_prev, n, conv, changed = state
        x_new = g(x)
        n_new = n + 1
        conv_new = jnp.where(x_new != x, n_new, conv)
        return (x_new, x, n_new, conv_new,
                jnp.any(x_new != x))

    x0 = jnp.zeros((B, d), jnp.int32)
    state = (g(x0), x0, jnp.asarray(1, jnp.int32),
             jnp.ones((B, d), jnp.int32), jnp.asarray(True))
    x, _, n, conv, _ = jax.lax.while_loop(cond, body, state)
    per = jnp.max(conv, axis=1) + 1  # each sample done one pass after last change
    return x, SampleStats(n, jnp.minimum(per, n), conv)
