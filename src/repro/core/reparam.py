"""Reparametrization of discrete sampling (paper §2.2 + Appendix B).

The paper's key insight: ancestral sampling ``x_i ~ Cat(softmax(mu_i))`` can be
rewritten as the *deterministic* map ``x_i = argmax_c(mu_{i,c} + eps_{i,c})``
with fixed Gumbel noise ``eps ~ G^{d x K}`` (Gumbel-max trick). Isolating the
stochasticity this way is what lets forecasts be *exactly* right, which the
ablation (paper Table 3) shows is the difference between 25.9% and 97.2% of
ARM calls.

Everything here is shift-invariant in ``mu``: raw (unnormalized) logits work
identically to log-probabilities, so we never materialize a log-softmax
(a deliberate TPU adaptation — argmax over vocab is LSE-shift invariant).

Appendix B: to train forecasting modules on *data* samples (not slow model
samples), we need noise from the posterior ``p(eps | x)``. Using the
independence of a Gumbel max and its argmax (Maddison et al. 2014):
  b           = max value ~ Gumbel(logsumexp(mu))      (argmax-independent)
  eps_{i,x_i} = b - mu_{x_i}
  eps_{i,c}   = TruncGumbel(mu_c | b) - mu_c           for c != x_i.
(The paper's Eq. 14 writes "eps_{x_i} ~ G", which is exact only for
normalized mu with a single effective category; the max-value law
Gumbel(LSE) is the correct conditional — verified by the marginalization
test: mixing x ~ softmax(mu) with eps ~ p(eps|x) must recover iid standard
Gumbel noise.) The resulting noise satisfies
``argmax_c(mu_c + eps_c) == x_i`` *exactly*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.hotpath import hot_path


def gumbel(key, shape, dtype=jnp.float32):
    """Standard Gumbel(0, 1) noise."""
    return jax.random.gumbel(key, shape, dtype=dtype)


@hot_path
def reparam_argmax(logits, eps):
    """Deterministic sample ``g(mu, eps) = argmax_c(mu_c + eps_c)``.

    logits: (..., K) unnormalized log-probabilities.
    eps:    (..., K) Gumbel noise.
    Returns int32 categories of shape (...,).
    """
    return jnp.argmax(logits + eps, axis=-1).astype(jnp.int32)


def categorical_sample(key, logits):
    """Reference ancestral sample via explicit Gumbel-max (same as
    jax.random.categorical, kept explicit so tests can share noise)."""
    eps = gumbel(key, logits.shape, dtype=jnp.float32)
    return reparam_argmax(logits.astype(jnp.float32), eps)


def _trunc_gumbel_value(key, mu, b):
    """Value ``v = mu + TruncGumbel-noise`` with ``v <= b`` and
    ``v ~ Gumbel(mu)`` truncated at ``b``.

    Uses v = -logaddexp(-b, -(mu + g0)), g0 ~ Gumbel(0).
    """
    g0 = gumbel(key, mu.shape, dtype=mu.dtype)
    return -jnp.logaddexp(-b, -(mu + g0))


def posterior_gumbel(key, logits, x):
    """Sample ``eps ~ p(eps | x)`` for the Gumbel-max reparametrization.

    logits: (..., K) float logits (any shift).
    x:      (...,)  int categories (the observed/data sample).
    Returns eps of shape (..., K) with ``reparam_argmax(logits, eps) == x``.
    """
    logits = logits.astype(jnp.float32)
    K = logits.shape[-1]
    k_max, k_rest = jax.random.split(key)
    onehot = jax.nn.one_hot(x, K, dtype=bool)

    mu_x = jnp.take_along_axis(logits, x[..., None], axis=-1)  # (..., 1)
    lse = jax.nn.logsumexp(logits, axis=-1, keepdims=True)     # (..., 1)
    g0 = gumbel(k_max, x.shape, dtype=jnp.float32)[..., None]  # (..., 1)
    b = lse + g0            # max value ~ Gumbel(LSE), independent of argmax
    eps_max = b - mu_x      # noise at the argmax location

    v_rest = _trunc_gumbel_value(k_rest, logits, b)  # (..., K), values < b
    eps_rest = v_rest - logits

    eps = jnp.where(onehot, eps_max, eps_rest)
    return eps
