"""Procedural dataset stand-ins (container is offline — see DESIGN.md §7).

* ``binary_strokes``  — MNIST surrogate: random smooth pen strokes on a
  black canvas, binarized. Controls: stroke count/length. Spatially regular,
  mostly-background — the regime where predictive sampling shines (paper
  Fig. 3: background forecast correctly, edges not).
* ``quantized_textures`` — SVHN/CIFAR surrogate: smooth random fields
  (low-res Gaussian noise, bilinear-upsampled, channel-mixed) quantized to
  ``K`` levels. Controls: category count (1-bit vs 5-bit vs 8-bit — the
  paper's main axis of difficulty) and smoothness.
* ``synthetic_tokens`` — LM surrogate: Markov text with strong local
  structure + copy motifs, so learned models have predictable continuations.

All generators are numpy-based (host-side data pipeline), deterministic in
their seed, and stream batches — mirroring a real input pipeline.
"""
from __future__ import annotations

import numpy as np


def _smooth_field(rng, n, h, w, c, low=4):
    """Low-frequency random fields in [0, 1]: (n, h, w, c)."""
    base = rng.standard_normal((n, low, low, c)).astype(np.float32)
    # bilinear upsample low -> (h, w)
    ys = np.linspace(0, low - 1, h)
    xs = np.linspace(0, low - 1, w)
    y0 = np.floor(ys).astype(int)
    x0 = np.floor(xs).astype(int)
    y1 = np.minimum(y0 + 1, low - 1)
    x1 = np.minimum(x0 + 1, low - 1)
    wy = (ys - y0)[None, :, None, None]
    wx = (xs - x0)[None, None, :, None]
    f = (base[:, y0][:, :, x0] * (1 - wy) * (1 - wx)
         + base[:, y1][:, :, x0] * wy * (1 - wx)
         + base[:, y0][:, :, x1] * (1 - wy) * wx
         + base[:, y1][:, :, x1] * wy * wx)
    f = (f - f.min(axis=(1, 2, 3), keepdims=True))
    f = f / (f.max(axis=(1, 2, 3), keepdims=True) + 1e-8)
    return f


def binary_strokes(n: int, height: int = 28, width: int = 28,
                   seed: int = 0) -> np.ndarray:
    """(n, H, W, 1) int {0,1} stroke images (MNIST stand-in)."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, height, width), np.int32)
    for i in range(n):
        strokes = rng.integers(1, 4)
        for _ in range(strokes):
            # random smooth quadratic stroke
            p0 = rng.uniform(0.15, 0.85, 2) * (height, width)
            p1 = rng.uniform(0.15, 0.85, 2) * (height, width)
            pc = (p0 + p1) / 2 + rng.normal(0, height / 5, 2)
            ts = np.linspace(0, 1, 64)[:, None]
            pts = ((1 - ts) ** 2 * p0 + 2 * ts * (1 - ts) * pc + ts ** 2 * p1)
            ys = np.clip(pts[:, 0].astype(int), 0, height - 1)
            xs = np.clip(pts[:, 1].astype(int), 0, width - 1)
            imgs[i, ys, xs] = 1
            # thicken
            imgs[i, np.minimum(ys + 1, height - 1), xs] = 1
            imgs[i, ys, np.minimum(xs + 1, width - 1)] = 1
    return imgs[..., None]


def quantized_textures(n: int, height: int = 32, width: int = 32,
                       channels: int = 3, categories: int = 32,
                       seed: int = 0, low: int = 4) -> np.ndarray:
    """(n, H, W, C) int in [0, K) smooth-texture images (CIFAR stand-in)."""
    rng = np.random.default_rng(seed)
    f = _smooth_field(rng, n, height, width, channels, low=low)
    # channel correlation (natural-image-like)
    mix = np.eye(channels) * 0.7 + 0.3 / channels
    f = np.clip(f @ mix, 0.0, 1.0)
    q = np.minimum((f * categories).astype(np.int32), categories - 1)
    return q


def synthetic_tokens(n: int, seq_len: int, vocab: int, seed: int = 0,
                     order: int = 2) -> np.ndarray:
    """(n, S) int Markov token streams with copy motifs (LM stand-in)."""
    rng = np.random.default_rng(seed)
    eff = min(vocab, 256)  # active sub-vocabulary
    # sparse peaked transition table over hash of last `order` tokens
    n_ctx = 997
    table = rng.dirichlet(np.full(eff, 0.05), size=n_ctx).astype(np.float32)
    out = np.zeros((n, seq_len), np.int64)
    state = rng.integers(0, eff, (n, order))
    for s in range(seq_len):
        ctx = (state * np.array([31 ** i for i in range(order)])).sum(1) % n_ctx
        u = rng.random((n, 1))
        cdf = np.cumsum(table[ctx], axis=1)
        nxt = (u > cdf).sum(axis=1)
        out[:, s] = nxt
        state = np.concatenate([state[:, 1:], nxt[:, None]], axis=1)
    return (out % vocab).astype(np.int32)


def repetitive_tokens(n: int, seq_len: int, vocab: int, seed: int = 0,
                      motif_len: int = 8, mutate: float = 0.05) -> np.ndarray:
    """(n, S) token streams of repeated motifs with rare mutations — the
    weakly-coupled regime where speculative/predictive decoding shines
    (boilerplate/code-like text). Strong-coupling Markov chains (see
    ``synthetic_tokens``) are the paper's 'cascading errors' worst case."""
    rng = np.random.default_rng(seed)
    eff = min(vocab, 64)
    out = np.zeros((n, seq_len), np.int64)
    for i in range(n):
        motif = rng.integers(0, eff, motif_len)
        reps = -(-seq_len // motif_len)
        stream = np.tile(motif, reps)[:seq_len]
        flips = rng.random(seq_len) < mutate
        stream[flips] = rng.integers(0, eff, flips.sum())
        out[i] = stream
    return (out % vocab).astype(np.int32)


def image_batches(generator, n_total: int, batch: int, seed: int = 0, **kw):
    """Infinite batch stream over a fixed generated dataset (epoch shuffled)."""
    data = generator(n_total, seed=seed, **kw)
    rng = np.random.default_rng(seed + 1)
    while True:
        idx = rng.permutation(n_total)
        for s in range(0, n_total - batch + 1, batch):
            yield data[idx[s:s + batch]]


def token_batches(n_total: int, batch: int, seq_len: int, vocab: int,
                  seed: int = 0):
    data = synthetic_tokens(n_total, seq_len, vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        idx = rng.permutation(n_total)
        for s in range(0, n_total - batch + 1, batch):
            yield data[idx[s:s + batch]]
