from repro.data.synthetic import (binary_strokes, quantized_textures,
                                  synthetic_tokens, image_batches,
                                  token_batches)

__all__ = ["binary_strokes", "quantized_textures", "synthetic_tokens",
           "image_batches", "token_batches"]
