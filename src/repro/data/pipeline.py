"""Host -> device data pipeline: shards host batches onto the active mesh."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import batch_sharding


class ShardedTokenPipeline:
    """Wraps a host batch generator; places batches with the mesh's batch
    sharding (the multi-host generalization point: swap device_put for
    make_array_from_process_local_data)."""

    def __init__(self, host_iter, mesh=None):
        self.host_iter = host_iter
        self.sharding = batch_sharding(mesh) if mesh is not None else None

    def __iter__(self):
        return self

    def __next__(self):
        batch = np.asarray(next(self.host_iter))
        if self.sharding is not None:
            return jax.device_put(batch, self.sharding)
        return jnp.asarray(batch)
