"""Post-SPMD HLO analysis helpers (no jax side effects on import)."""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO."""
    totals = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            marker = f" {coll}("
            if marker not in stripped:
                continue
            # result type(s) appear between '=' and the op name
            lhs = stripped.split(marker)[0]
            if "=" not in lhs:
                continue
            type_part = lhs.split("=", 1)[1]
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(type_part):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            totals[coll]["bytes"] += nbytes
            totals[coll]["count"] += 1
            break
    return totals


