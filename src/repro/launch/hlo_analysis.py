"""Back-compat shim: the HLO/jaxpr parsing helpers moved to
``repro.analysis.hlo`` (DESIGN.md §17), where they serve as the
measurement backend of the contract engine — and where
``parse_collective_bytes`` gained the async (``-start``) collective
forms the old sync-only parser missed. Import from ``repro.analysis``
in new code; this module re-exports the old names unchanged for
external callers."""
from repro.analysis.hlo import (count_jaxpr_primitives, find_collectives,
                                find_jaxpr_primitives, parse_collective_bytes,
                                parse_shape_bytes)

__all__ = ["count_jaxpr_primitives", "find_collectives",
           "find_jaxpr_primitives", "parse_collective_bytes",
           "parse_shape_bytes"]
