"""Post-SPMD HLO analysis helpers (no jax side effects on import)."""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO."""
    totals = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            marker = f" {coll}("
            if marker not in stripped:
                continue
            # result type(s) appear between '=' and the op name
            lhs = stripped.split(marker)[0]
            if "=" not in lhs:
                continue
            type_part = lhs.split("=", 1)[1]
            nbytes = 0
            for dt, dims in _SHAPE_RE.findall(type_part):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            totals[coll]["bytes"] += nbytes
            totals[coll]["count"] += 1
            break
    return totals




def count_jaxpr_primitives(closed_jaxpr, names, min_rank: int = 0):
    """Count primitive occurrences (by name) in a ClosedJaxpr, recursing
    into sub-jaxprs (scan/while/pjit/pallas bodies). ``min_rank`` filters to
    equations whose first output has at least that many dims — e.g.
    ``count_jaxpr_primitives(jaxpr, ("scatter",), min_rank=3)`` counts
    pool-shaped scatters (the standalone window-writeback the fused kernel
    epilogue eliminates) while ignoring small per-row bookkeeping updates.

    The fused-round acceptance gate (DESIGN.md §11): a verify round's jaxpr
    must contain ZERO pool-ranked scatter eqns — every physical-pool write
    happens inside a pallas_call as an aliased epilogue."""
    counts = {n: 0 for n in names}

    def visit(jaxpr):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim in counts:
                outs = eqn.outvars
                rank = max((len(getattr(v.aval, "shape", ()))
                            for v in outs), default=0)
                if rank >= min_rank:
                    counts[prim] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    visit(sub)
    _visit_closed(closed_jaxpr, visit)
    return counts


def _sub_jaxprs(value):
    """Yield any jaxprs nested inside an eqn param value."""
    import jax.extend.core as jex_core  # deferred: no import side effects

    vals = value if isinstance(value, (list, tuple)) else [value]
    for v in vals:
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jex_core.Jaxpr):
            yield v


def _visit_closed(closed_jaxpr, visit):
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    visit(jaxpr)
