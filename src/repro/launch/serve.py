"""Serving launcher: predictive sampling through the paged serving runtime.

``python -m repro.launch.serve --arch qwen3-1.7b --reduced --requests 6``

Drives ``repro.serving.ServingEngine`` (paged KV blocks, prefix cache,
adaptive speculation window, telemetry). ``--no-adaptive`` pins the window;
``--no-prefix-cache`` disables block sharing; ``--mesh data[,model]`` runs
the engine on a device mesh (``ServingTopology``: per-data-shard slot
ranges + block sub-pools, shard_map round step; params replicated over
data and — when model > 1 — tensor-sharded via
``serving_param_shardings``); ``--no-donate`` disables round-buffer
donation (A/B for the copy-per-round cost); ``--lookahead`` /
``--max-head-bypass`` / ``--no-preempt`` / ``--preempt-floor`` /
``--no-rebalance`` tune the saturation-safe scheduler (DESIGN.md §12:
lookahead admission, priority preemption with exact resume, shard
rebalancing by sequence migration); ``--staging-slots`` /
``--adaptive-rounds`` turn on device-resident continuous batching
(DESIGN.md §15: pre-staged prompts adopted into freed rows inside the
round loop, rounds_per_sync retuned from idle row-rounds);
``--durable-dir`` / ``--journal-fsync-every`` / ``--no-disk-tier`` turn on
crash-safe serving (DESIGN.md §16: write-ahead request journal, scheduler
checkpoints, disk tier below the host arena — a relaunched engine with the
same ``--durable-dir`` recovers every accepted request bitwise-exactly).

Also exports ``make_serve_step`` — the W-token verify step the multi-pod
dry-run lowers for the decode shapes (decode_32k / long_500k).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.reparam import reparam_argmax
from repro.models.transformer import TransformerLM
from repro.serving import (FaultPlan, Request, ServingEngine,
                           ServingTopology)


def make_serve_step(cfg, window: int = 8, low_memory: bool = False):
    """One predictive-sampling verify round (dry-run unit for decode shapes).

    Args: params, cand (B, W), cache, cache_len (B,), eps (B, W, V).
    Returns (out tokens (B, W), accept (B,), new_cache).

    ``low_memory`` (§Perf C4): two-pass variant for recurrent/hybrid archs —
    pass 1 computes logits without materializing per-position states
    (DCE'd); pass 2 re-advances the states with a freeze-masked scan to the
    accept point. Trades ~2x decode compute for O(layers x B x W x state)
    memory (the 101 GB/dev jamba-decode term).
    """
    def serve_step(params, cand, cache, cache_len, eps):
        logits, h, new_cache = TransformerLM.decode_window(
            params, cfg, cand, cache, cache_len,
            state_mode="none" if low_memory else "per_position")
        out = reparam_argmax(logits.astype(jnp.float32), eps)
        match = cand[:, 1:] == out[:, :-1]
        accept = 1 + jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                             axis=1)
        if low_memory:
            _, _, adv = TransformerLM.decode_window(
                params, cfg, cand, cache, cache_len,
                state_mode="advance", accept=accept)
            return out, accept, adv
        sel = TransformerLM.select_states(cfg, new_cache, accept)
        return out, accept, sel

    return serve_step


def make_serving_topology(mesh_arg: str):
    """``--mesh data[,model]`` -> ``ServingTopology`` over a host mesh.

    Requires ``data * model`` visible devices (force with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU)."""
    from repro.launch.mesh import make_host_mesh

    try:
        parts = [int(p) for p in mesh_arg.split(",")]
    except ValueError:
        parts = []
    if not 1 <= len(parts) <= 2:
        raise SystemExit(f"--mesh wants DATA or DATA,MODEL, got {mesh_arg!r}")
    data, model = (parts + [1])[:2]
    n = len(jax.devices())
    if data * model > n:
        raise SystemExit(
            f"--mesh {mesh_arg} needs {data * model} devices, have {n} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=...)")
    return ServingTopology(make_host_mesh(data, model))


def place_params(params, topo: ServingTopology):
    """Replicate params over data; tensor-shard over model when present."""
    if topo.mesh is None:
        return params
    from repro.sharding.rules import replicated, serving_param_shardings

    if all(topo.mesh.shape[a] == 1 for a in topo.auto_axes):
        return jax.device_put(params, replicated(topo.mesh))
    shapes = jax.eval_shape(lambda: params)
    return jax.device_put(params, serving_param_shardings(shapes, topo.mesh))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=8,
                    help="max verify window W (adaptive controller's bound)")
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV-cache block size (tokens per physical block)")
    ap.add_argument("--no-adaptive", action="store_true",
                    help="pin W instead of adapting it to acceptance")
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="DATA[,MODEL]",
                    help="run on a device mesh, e.g. --mesh 2 or --mesh 4,2")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable round-buffer donation (keeps the old "
                         "copy-per-round behaviour; for A/B measurement)")
    ap.add_argument("--rounds-per-sync", type=int, default=4,
                    help="device-resident verify rounds per host sync "
                         "(lax.while_loop trip bound; 1 = host-driven; "
                         "with --adaptive-rounds this is the k_max bound)")
    ap.add_argument("--staging-slots", type=int, default=0,
                    help="queued requests pre-staged per shard for "
                         "in-loop slot adoption (DESIGN.md §15: freed "
                         "rows adopt staged work mid-loop, no sync to "
                         "refill); 0 = host-only admission, compiles the "
                         "legacy round program byte-identically")
    ap.add_argument("--adaptive-rounds", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="retune rounds_per_sync from the idle row-round "
                         "EWMA the way W is retuned from acceptance "
                         "(default: on exactly when staging is on; "
                         "requires --staging-slots > 0)")
    ap.add_argument("--lookahead", type=int, default=8,
                    help="admission lookahead depth: queued requests "
                         "scanned past an unroutable head (1 = the old "
                         "head-of-line-blocking admission)")
    ap.add_argument("--max-head-bypass", type=int, default=16,
                    help="aging bound: admissions allowed to jump the "
                         "queue head before admission goes head-only")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable priority preemption (parking lower-"
                         "priority slots for a higher-priority head)")
    ap.add_argument("--preempt-floor", type=float, default=0.75,
                    help="progress floor: running slots past this fraction "
                         "of their generation target are never preempted")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="disable shard rebalancing (sequence migration "
                         "between block sub-pools at admission)")
    ap.add_argument("--host-cache-mb", type=float, default=None,
                    metavar="MB",
                    help="host cache tier byte budget in MiB (DESIGN.md "
                         "§13: spilled prefix blocks, parked sequences, "
                         "recurrent-state snapshots share one bounded LRU "
                         "arena); default: REPRO_HOST_CACHE_MB or 256")
    ap.add_argument("--no-host-cache", action="store_true",
                    help="disable the host cache tier (evicted prefix "
                         "blocks drop, parked payloads stay raw host "
                         "copies, recurrent archs never prefix-hit)")
    ap.add_argument("--max-request-seconds", type=float, default=None,
                    metavar="S",
                    help="per-request wall-time bound (DESIGN.md §14): a "
                         "request running past this fails with a "
                         "structured 'timeout' error instead of holding "
                         "its slot forever")
    ap.add_argument("--request-retries", type=int, default=0,
                    help="re-admissions granted after a retryable "
                         "per-request failure (quarantined row, admission "
                         "fault) before the request fails for good")
    ap.add_argument("--no-integrity-checks", action="store_true",
                    help="skip host-tier checksum stamping/verification "
                         "(DESIGN.md §14; corruption then goes undetected "
                         "— A/B for the checksum cost)")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault-injection plan, e.g. "
                         "'seed=7,alloc=@2;5,arena_corrupt=0.05,poison=3' "
                         "(default: REPRO_FAULT_PLAN env)")
    ap.add_argument("--durable-dir", default=None, metavar="DIR",
                    help="crash-safety root (DESIGN.md §16): write-ahead "
                         "request journal, scheduler checkpoints at sync "
                         "boundaries, and the disk tier below the host "
                         "arena live here; a restarted engine with the "
                         "same DIR recovers every accepted request "
                         "bitwise-exactly. Default: volatile engine")
    ap.add_argument("--journal-fsync-every", type=int, default=1,
                    metavar="N",
                    help="fsync the request journal every N records "
                         "(1 = an accepted submit is durable before "
                         "submit() returns; larger batches the fsync cost "
                         "with an exposure window of at most N-1 records "
                         "past the last sync boundary)")
    ap.add_argument("--no-disk-tier", action="store_true",
                    help="with --durable-dir: keep journal + checkpoint "
                         "but skip the disk tier (arena LRU victims drop "
                         "instead of spilling; restarts re-prefill every "
                         "prefix instead of re-hitting it on disk)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    topo = ServingTopology() if args.mesh is None \
        else make_serving_topology(args.mesh)
    params = place_params(params, topo)
    engine = ServingEngine(cfg, params, batch=args.batch,
                           window_max=args.window, max_len=args.max_len,
                           eps_key=jax.random.PRNGKey(1),
                           block_size=args.block_size,
                           adaptive=not args.no_adaptive,
                           prefix_cache=not args.no_prefix_cache,
                           topology=topo, donate=not args.no_donate,
                           rounds_per_sync=args.rounds_per_sync,
                           staging_slots=args.staging_slots,
                           adaptive_rounds=args.adaptive_rounds,
                           lookahead=args.lookahead,
                           max_head_bypass=args.max_head_bypass,
                           preempt=not args.no_preempt,
                           preempt_floor=args.preempt_floor,
                           rebalance=not args.no_rebalance,
                           host_cache_mb=(0 if args.no_host_cache
                                          else args.host_cache_mb),
                           max_request_seconds=args.max_request_seconds,
                           request_retries=args.request_retries,
                           integrity_checks=not args.no_integrity_checks,
                           faults=(FaultPlan.parse(args.fault_plan)
                                   if args.fault_plan else None),
                           durable_dir=args.durable_dir,
                           journal_fsync_every=args.journal_fsync_every,
                           disk_tier=not args.no_disk_tier)
    if args.durable_dir:
        recovered = engine.restore()
        if recovered:
            print(f"recovered {recovered} journaled requests from "
                  f"{args.durable_dir}")
    if topo.mesh is not None:
        print(f"serving on {topo}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(2, 8))),
            new_tokens=args.new_tokens))
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    engine.close()
    m = engine.export_metrics()
    total_new = sum(r.new_tokens for r in done)
    print(f"served {len(done)} requests / {total_new} tokens "
          f"in {m['rounds']} verify rounds ({dt:.1f}s)")
    print(f"ARM calls vs ancestral baseline: "
          f"{100.0 * m['arm_calls_vs_ancestral']:.1f}% "
          f"(paged engine, W<= {args.window}, "
          f"adaptive={not args.no_adaptive})")
    print("telemetry: " + json.dumps(
        {k: (round(v, 4) if isinstance(v, float) else v)
         for k, v in m.items()}, indent=2))
    for r in done[:3]:
        print(f"  req {r.uid}: calls={r.calls_used} "
              f"prefill={r.prefill_calls} tokens={r.result[:12]}…")


if __name__ == "__main__":
    main()
