"""Serving launcher: predictive-sampling generation with continuous batching.

``python -m repro.launch.serve --arch qwen3-1.7b --reduced --requests 6``

Also exports ``make_serve_step`` — the W-token verify step the multi-pod
dry-run lowers for the decode shapes (decode_32k / long_500k).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.reparam import reparam_argmax
from repro.engine import ContinuousBatcher, PredictiveSampler, Request
from repro.models.transformer import TransformerLM


def make_serve_step(cfg, window: int = 8, low_memory: bool = False):
    """One predictive-sampling verify round (dry-run unit for decode shapes).

    Args: params, cand (B, W), cache, cache_len (B,), eps (B, W, V).
    Returns (out tokens (B, W), accept (B,), new_cache).

    ``low_memory`` (§Perf C4): two-pass variant for recurrent/hybrid archs —
    pass 1 computes logits without materializing per-position states
    (DCE'd); pass 2 re-advances the states with a freeze-masked scan to the
    accept point. Trades ~2x decode compute for O(layers x B x W x state)
    memory (the 101 GB/dev jamba-decode term).
    """
    def serve_step(params, cand, cache, cache_len, eps):
        logits, h, new_cache = TransformerLM.decode_window(
            params, cfg, cand, cache, cache_len,
            state_mode="none" if low_memory else "per_position")
        out = reparam_argmax(logits.astype(jnp.float32), eps)
        match = cand[:, 1:] == out[:, :-1]
        accept = 1 + jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                             axis=1)
        if low_memory:
            _, _, adv = TransformerLM.decode_window(
                params, cfg, cand, cache, cache_len,
                state_mode="advance", accept=accept)
            return out, accept, adv
        sel = TransformerLM.select_states(cfg, new_cache, accept)
        return out, accept, sel

    return serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    sampler = PredictiveSampler(cfg, params, window=args.window,
                                max_len=args.max_len,
                                eps_key=jax.random.PRNGKey(1))
    batcher = ContinuousBatcher(sampler, batch=args.batch)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        batcher.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab,
                                       size=int(rng.integers(2, 8))),
            new_tokens=args.new_tokens))
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    total_rounds = int(np.asarray(batcher.state.rounds))
    total_new = sum(r.new_tokens for r in done)
    print(f"served {len(done)} requests / {total_new} tokens "
          f"in {total_rounds} verify rounds ({dt:.1f}s)")
    print(f"ARM calls vs ancestral baseline: "
          f"{100.0 * total_rounds / total_new:.1f}% "
          f"(continuous batching + window={args.window})")
    for r in done[:3]:
        print(f"  req {r.uid}: calls={r.calls_used} tokens={r.result[:12]}…")


if __name__ == "__main__":
    main()
