import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   Set ONLY here — smoke tests and benches see the real single CPU device.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) pair, lower + compile the real step
function against the production mesh — 16x16 single-pod AND 2x16x16
multi-pod — with abstract (ShapeDtypeStruct) params: no allocation, but full
SPMD partitioning, memory analysis and cost analysis. Failures here
(sharding mismatch, OOM at compile, unsupported collective) are bugs.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
(flops, bytes, per-collective byte totals, memory analysis) — the roofline
analysis (benchmarks/roofline.py) consumes them.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.train import make_optimizer, shard_jit_train_step
from repro.launch.serve import make_serve_step
from repro.models import frontends
from repro.models.transformer import TransformerLM
from repro.sharding import use_rules
from repro.sharding.rules import (batch_sharding, cache_shardings,
                                  default_activation_rules,
                                  param_shardings, replicated)
from jax.sharding import NamedSharding, PartitionSpec as P

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

DECODE_WINDOW = 8

from repro.analysis import parse_collective_bytes


def abstract_params(cfg):
    return jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))


def lower_train(cfg, shape, mesh):
    opt = make_optimizer(cfg)
    jitted, args, _ = shard_jit_train_step(
        cfg, opt, mesh, (shape.global_batch, shape.seq_len), remat=True)
    return jitted.lower(*args)


def lower_prefill(cfg, shape, mesh):
    params_shape = abstract_params(cfg)
    p_shard = param_shardings(params_shape, mesh)
    B = shape.global_batch
    b_shard = batch_sharding(mesh)

    def prefill_step(params, tokens, prefix_emb=None):
        # prefill uses bounded MoE capacity (2.0): no-drop C=N*k at 1M-token
        # prefill is a 100x memory/flops blowup; the engine's decode windows
        # (small N) stay exact no-drop. See EXPERIMENTS.md §Dry-run.
        logits, h, _ = TransformerLM.apply(params, cfg, tokens, prefix_emb,
                                           moe_capacity=2.0)
        return logits[:, -1]

    args = [params_shape,
            jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)]
    in_sh = [p_shard, b_shard]
    if cfg.n_prefix_tokens:
        args.append(frontends.prefix_spec(cfg, B))
        in_sh.append(b_shard)
    vshard = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
    jitted = jax.jit(prefill_step, in_shardings=tuple(in_sh),
                     out_shardings=NamedSharding(
                         mesh, P(_dp(mesh) if B % _dp_size(mesh) == 0
                                 else None, vshard)))
    return jitted.lower(*args)


def _dp(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _dp_size(mesh):
    if "pod" in mesh.axis_names:
        return mesh.shape["pod"] * mesh.shape["data"]
    return mesh.shape["data"]


def lower_decode(cfg, shape, mesh):
    from repro.sharding import rules as rules_mod
    rules_mod.MOE_INFERENCE_LAYOUT = (
        os.environ.get("REPRO_MOE_EP", "1") == "1")
    params_shape = abstract_params(cfg)
    p_shard = param_shardings(params_shape, mesh)
    rules_mod.MOE_INFERENCE_LAYOUT = False
    B, S, W = shape.global_batch, shape.seq_len, DECODE_WINDOW
    dtype = cfg.param_dtype
    # §Perf C1: round the cache length up to a multiple of 256 so the
    # sequence dim is mesh-divisible -> caches shard over "model" on S
    # (flash-decode/sequence-parallel attention) instead of being gathered.
    S_cache = -(-(S + W) // 256) * 256
    cache_shape = jax.eval_shape(
        lambda: TransformerLM.init_cache(cfg, B, S_cache, dtype))
    c_shard = cache_shardings(cache_shape, mesh, B)
    dp_ok = B % _dp_size(mesh) == 0
    bspec = P(_dp(mesh)) if dp_ok else P(None)
    lowmem = os.environ.get("REPRO_LOWMEM_DECODE", "0") == "1"
    step = make_serve_step(cfg, window=W, low_memory=lowmem)
    args = [params_shape,
            jax.ShapeDtypeStruct((B, W), jnp.int32),
            cache_shape,
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B, W, cfg.vocab), jnp.float32)]
    in_sh = (p_shard,
             NamedSharding(mesh, P(*bspec, None)),
             c_shard,
             NamedSharding(mesh, bspec),
             NamedSharding(mesh, P(*bspec, None,
                                   "model" if cfg.vocab
                                   % mesh.shape["model"] == 0 else None)))
    out_cache_shape = (cache_shape if lowmem else
                       jax.eval_shape(lambda c: TransformerLM.select_states(
                           cfg, c, jnp.ones((B,), jnp.int32)),
                           _window_cache_shape(cfg, B, S_cache, W, dtype)))
    out_sh = (NamedSharding(mesh, P(*bspec, None)),
              NamedSharding(mesh, bspec),
              cache_shardings(out_cache_shape, mesh, B))
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    return jitted.lower(*args)


def _window_cache_shape(cfg, B, S, W, dtype):
    """Shape of decode_window's new_cache (per-position recurrent states)."""
    cache = jax.eval_shape(
        lambda: TransformerLM.init_cache(cfg, B, S, dtype))
    return jax.eval_shape(
        lambda p, c: TransformerLM.decode_window(
            p, cfg, jnp.zeros((B, W), jnp.int32), c,
            jnp.zeros((B,), jnp.int32))[2],
        abstract_params(cfg), cache)


def run_pair(arch: str, shape_name: str, multi_pod: bool, out_dir: str):
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, tag + ".json")
    ok, reason = shape_applicable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {tag}: {reason}")
        return rec

    cfg = get_config(arch)
    kb = os.environ.get("REPRO_OVERRIDE_BLOCKS")
    if kb is not None:
        # roofline scan-correction probe: same config at k scanned blocks
        import dataclasses
        k = int(kb)
        cfg = dataclasses.replace(
            cfg, n_layers=(len(cfg.layer_prefix) + k * len(cfg.layer_block)
                           + len(cfg.layer_suffix)))
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_activation_rules(
        mesh, shard_embed=os.environ.get("REPRO_SHARD_EMBED") == "1",
        no_tp=os.environ.get("REPRO_NO_TP") == "1")
    if (shape.kind == "decode"
            and os.environ.get("REPRO_MOE_EP", "1") == "1"):
        m = dict(rules.mapping)
        m["_moe_ep"] = True
        from repro.sharding.api import Rules
        rules = Rules(m)
    t0 = time.time()
    try:
        with mesh, use_rules(mesh, rules):
            if shape.kind == "train":
                lowered = lower_train(cfg, shape, mesh)
            elif shape.kind == "prefill":
                lowered = lower_prefill(cfg, shape, mesh)
            else:
                lowered = lower_decode(cfg, shape, mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # noqa: BLE001
            mem_rec = {"error": str(e)}
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok",
            "n_devices": int(mesh.devices.size),
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "collectives": coll,
            "memory": mem_rec,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "decode_window": DECODE_WINDOW if shape.kind == "decode" else None,
        }
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[ok] {tag}: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} "
              f"coll={sum(c['bytes'] for c in coll.values()):.3e}B "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        return rec
    except Exception as e:  # noqa: BLE001
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": str(e)[:2000],
               "trace": traceback.format_exc()[-4000:]}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[ERR] {tag}: {str(e)[:200]}")
        return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all (arch, shape) x both meshes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(ART_DIR))
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        jobs = [(a, s, mp)
                for a in ARCHS for s in SHAPES
                for mp in (False, True)]
    else:
        assert args.arch and args.shape
        jobs = [(args.arch, args.shape, args.multi_pod)]

    n_err = 0
    for arch, shape_name, mp in jobs:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        path = os.path.join(args.out,
                            f"{arch}__{shape_name}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            rec = json.load(open(path))
            if rec.get("status") in ("ok", "skipped"):
                print(f"[cached] {arch}__{shape_name}__{mesh_name}")
                continue
        rec = run_pair(arch, shape_name, mp, args.out)
        n_err += rec["status"] == "error"
    print(f"dry-run sweep complete; errors: {n_err}")
    return n_err


if __name__ == "__main__":
    raise SystemExit(main())
