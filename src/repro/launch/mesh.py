"""Production meshes (TPU v5e). Single pod: 256 chips as (data=16, model=16);
multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512
host devices via XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — for sharding unit
    tests with xla_force_host_platform_device_count."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # bytes/s
ICI_BW = 50e9                    # bytes/s per link
